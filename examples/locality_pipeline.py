"""Irregular-workload example (paper §8.2.2) + the hybrid addressing story.

Runs histogram-equalization — the paper's reduction-heavy irregular app —
through the kernel layer, and demonstrates the p_local effect: the same
logical computation placed with SEQUENTIAL vs INTERLEAVED region policies,
with the traffic difference predicted by the interconnect model.

The kernel call goes through a kernel-only `Cluster` (no model attached):
its scoped `KernelPolicy` picks the blocking (autotuned, registry-cached)
and records the dispatch traffic.

    PYTHONPATH=src python examples/locality_pipeline.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.cluster import Cluster
from repro.core.interconnect import TOP_H, TopologyModel
from repro.kernels import ops


def histogram_equalization(img: jax.Array, bins: int = 256) -> jax.Array:
    """Paper §8.2.2: contrast enhancement via the intensity CDF.

    Reductions (histogram) + serial step (CDF) + parallel map (LUT apply) —
    the structure that stresses synchronization on MemPool.
    """
    flat = img.reshape(-1)
    hist = jnp.zeros((bins,), jnp.int32).at[flat].add(1)     # reduction
    cdf = jnp.cumsum(hist)                                   # serial scan
    cdf_min = cdf[jnp.argmax(cdf > 0)]
    denom = jnp.maximum(flat.size - cdf_min, 1)
    lut = jnp.round((cdf - cdf_min) / denom * (bins - 1)).astype(jnp.uint8)
    return lut[flat].reshape(img.shape)                      # parallel map


def main():
    key = jax.random.PRNGKey(0)
    # synthetic low-contrast image
    img = jnp.clip(
        (jax.random.normal(key, (512, 512)) * 20 + 100), 0, 255
    ).astype(jnp.int32)
    eq = jax.jit(histogram_equalization)(img)
    spread_before = int(img.max() - img.min())
    spread_after = int(eq.max() - eq.min())
    print(f"histogram equalization: intensity spread {spread_before} -> "
          f"{spread_after} (full range = 255)")
    assert spread_after > spread_before

    # follow with the paper's 2dconv on the equalized image, dispatched
    # through a kernel-only Cluster's policy (autotuned blocking on miss)
    cluster = Cluster()
    w = jnp.asarray([[1, 2, 1], [2, 4, 2], [1, 2, 1]], jnp.float32) / 16
    with cluster.policy("tuned") as pol:
        smoothed = ops.tuned_call("conv2d", eq.astype(jnp.float32), w)
    print(f"smoothed via Pallas conv2d: mean {float(smoothed.mean()):.1f} "
          f"(policy={pol.mode}, stats={dict(pol.stats)})")

    # the p_local story on this workload: the LUT-apply phase is fully
    # local (SEQUENTIAL region); the histogram reduction is all-remote
    # (INTERLEAVED). The interconnect model quantifies the difference:
    m = TopologyModel(TOP_H)
    for phase, p_local in [("lut_apply (sequential)", 0.95),
                           ("histogram (interleaved)", 0.02)]:
        lat = m.avg_latency(0.3, p_local=p_local)
        acc = m.accepted_load(1.0, p_local=p_local)
        print(f"  {phase:28s} p_local={p_local:.2f} -> "
              f"latency={lat:.1f}cyc, accepted={acc:.2f} req/core/cyc")


if __name__ == "__main__":
    main()
