"""Batched serving: decode a batch of requests against a shared KV cache.

A thin wrapper over the Cluster façade: one `ServeProgram` handles the
token-by-token prompt ingest (continuous-batching style) and the greedy
generation loop, with optional EOS-based early stop per slot.

    PYTHONPATH=src python examples/serve_batched.py --batch 8 --new 32
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax

from repro.cluster import Cluster, ServeProgram


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop a slot once it emits this token id")
    args = ap.parse_args()

    cluster = Cluster(args.arch + "-smoke")
    cfg = cluster.arch
    program = cluster.compile(ServeProgram(batch=args.batch, max_seq=64,
                                           max_new=args.new,
                                           eos_id=args.eos_id))

    prompt = jax.random.randint(jax.random.PRNGKey(0), (args.batch, 8), 0,
                                cfg.vocab)
    out = program.run(prompt=prompt)
    stats = out["stats"]
    print(f"arch={cfg.name} batch={args.batch} generated {args.new} "
          f"tokens/slot")
    print(f"p50={stats['p50_ms']:.1f}ms p99={stats['p99_ms']:.1f}ms "
          f"{stats['tokens_per_s_per_slot']:.1f} tok/s/slot")
    if "finished_slots" in stats:
        print(f"finished at eos: {stats['finished_slots']}/{args.batch}, "
              f"emitted={stats['emitted_per_slot']}")
    print("sample:", out["tokens"][0][:16].tolist())


if __name__ == "__main__":
    main()
