"""Batched serving: decode a batch of requests against a shared KV cache.

A thin wrapper over the Cluster façade: one `ServeProgram` handles the
token-by-token prompt ingest (continuous-batching style) and the greedy
generation loop. Generation runs on the device-resident execution engine —
`--chunk K` decode steps are compiled into one `lax.scan` program with
donated cache/token buffers, so the host syncs once per K tokens instead
of per token (`--chunk 1` falls back to the per-token loop; the tokens are
bit-identical either way). The stats line reports the StallClock ledger:
host-sync count and `stall_pct`, the host-side dispatch gap as a fraction
of wall time — the paper's execution-stall figure.

    PYTHONPATH=src python examples/serve_batched.py --batch 8 --new 32

This is the fixed-batch path: every slot runs to the slowest request.
For request-level serving — submit/stream/cancel against a slot pool with
continuous batching — see `examples/serve_continuous.py` (ServeSession).
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax

from repro.cluster import Cluster, ServeProgram


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--chunk", type=int, default=16,
                    help="decode steps per host sync (1 = per-token loop)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop a slot once it emits this token id")
    args = ap.parse_args()

    cluster = Cluster(args.arch + "-smoke")
    cfg = cluster.arch
    program = cluster.compile(ServeProgram(batch=args.batch, max_seq=64,
                                           max_new=args.new,
                                           chunk=args.chunk,
                                           eos_id=args.eos_id))

    prompt = jax.random.randint(jax.random.PRNGKey(0), (args.batch, 8), 0,
                                cfg.vocab)
    out = program.run(prompt=prompt)
    stats = out["stats"]
    stall = stats["stall"]
    print(f"arch={cfg.name} batch={args.batch} generated {args.new} "
          f"tokens/slot (chunk={stats['chunk']})")
    print(f"p50={stats['p50_ms']:.1f}ms p99={stats['p99_ms']:.1f}ms "
          f"{stats['tokens_per_s_per_slot']:.1f} tok/s/slot")
    print(f"engine: {stall['host_syncs']} host syncs, "
          f"stall={stall['stall_pct']:.1f}% "
          f"(dispatch gap {stall['dispatch_gap_s'] * 1e3:.1f}ms over "
          f"{stall['wall_s'] * 1e3:.0f}ms)")
    if "finished_slots" in stats:
        print(f"finished at eos: {stats['finished_slots']}/{args.batch}, "
              f"emitted={stats['emitted_per_slot']}")
    print("sample:", out["tokens"][0][:16].tolist())


if __name__ == "__main__":
    main()
