"""Batched serving: decode a batch of requests against a shared KV cache.

    PYTHONPATH=src python examples/serve_batched.py --batch 8 --new 32
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.models import steps
from repro.runtime import ServeLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--new", type=int, default=32)
    args = ap.parse_args()

    cfg = get(args.arch + "-smoke")
    max_seq = 64
    key = jax.random.PRNGKey(0)
    params = steps.init_params(cfg, key, max_seq=max_seq)

    # prefill the prompt token-by-token (continuous-batching style ingest)
    prompt = jax.random.randint(key, (args.batch, 8), 0, cfg.vocab)
    cache = steps.init_cache(cfg, args.batch,
                             steps.decode_cache_len(cfg, max_seq))
    decode = jax.jit(steps.make_decode_step(cfg, max_seq=max_seq))
    tok = None
    for t in range(prompt.shape[1]):
        cache, tok = decode(params, cache,
                            {"tokens": prompt[:, t:t + 1],
                             "pos": jnp.asarray(t, jnp.int32)})

    serve = ServeLoop(decode, params, cache, batch_size=args.batch)
    out = serve.generate(np.asarray(tok), max_new=args.new,
                         start_pos=prompt.shape[1])
    stats = serve.stats()
    print(f"arch={cfg.name} batch={args.batch} generated {args.new} tokens/slot")
    print(f"p50={stats['p50_ms']:.1f}ms p99={stats['p99_ms']:.1f}ms "
          f"{stats['tokens_per_s_per_slot']:.1f} tok/s/slot")
    print("sample:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
