"""Sharded serving: a cluster of session cells behind one submit/poll.

MemPool scales past one cluster by tiling the hierarchy — PEs into
tiles, tiles into groups — and routing traffic so most accesses stay
local. This example runs the serving-side analogue: `--groups` full
session cells (each with its own slot pool, paged KV pool and prefix
cache) behind a single `ShardedServeSession`, with the two-level
scheduler placing every arrival by modeled latency: measured
prefix-cache overlap is the local-access probability, occupancy the
injected load.

About 60% of the prompts open with a shared hot preamble (a system
prompt, in serving terms). Once one request carrying it finishes in
some group, that group's prefix cache holds the preamble pages — and
the mesh scheduler starts steering preamble-carrying arrivals there,
where prefill can be skipped copy-on-write. The placement ledger at the
end shows the effect: `locality rate` is the fraction of placements
that went to a group with measured page overlap.

Run under forced host devices so every group gets its own device:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/serve_sharded.py --groups 4
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.cluster import Cluster, ShardedServeSessionProgram


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--slots", type=int, default=2,
                    help="slot-pool size per group")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=12.0,
                    help="mean request arrivals per second (Poisson)")
    ap.add_argument("--hot", type=float, default=0.6,
                    help="fraction of prompts opening with the shared "
                         "preamble")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cluster = Cluster(args.arch + "-smoke")
    cfg = cluster.arch
    program = cluster.compile(ShardedServeSessionProgram(
        groups=args.groups, slots=args.slots, max_seq=32, max_prompt=8,
        chunk=4, paged=True, page_size=4))
    session = program.open()

    rng = np.random.default_rng(args.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    preamble = rng.integers(0, cfg.vocab, size=5).astype(np.int32)
    prompts, hot_flags = [], []
    for _ in range(args.requests):
        hot = rng.random() < args.hot
        tail_len = int(rng.integers(1, 4))
        tail = rng.integers(0, cfg.vocab, size=tail_len).astype(np.int32)
        prompts.append(np.concatenate([preamble, tail]) if hot else tail)
        hot_flags.append(hot)
    out_lens = rng.choice([4, 8, 12, 16], size=args.requests)

    print(f"arch={cfg.name} groups={args.groups} slots={args.slots}/group "
          f"paged page_size=4 — {args.requests} requests, "
          f"~{args.rate}/s Poisson, {sum(hot_flags)} share the hot "
          f"preamble ({len(preamble)} tokens)")

    # Warm-up: run the preamble through once so some group's prefix
    # cache holds its pages before the Poisson wave arrives (otherwise
    # every arrival lands cold while the first batch is still decoding).
    warm = session.submit(preamble, 2)
    session.drain()
    print(f"warm-up: preamble published in group {warm.group}'s "
          f"prefix cache")

    t0 = time.perf_counter()
    next_up = 0
    while next_up < args.requests or session.busy:
        now = time.perf_counter() - t0
        while next_up < args.requests and arrivals[next_up] <= now:
            h = session.submit(prompts[next_up], int(out_lens[next_up]))
            tag = "hot " if hot_flags[next_up] else "cold"
            print(f"  req {h.id} ({tag}, {prompts[next_up].size} tok) "
                  f"-> group {h.group}")
            next_up += 1
        events = session.poll()
        for handle, _toks, done in events:
            if done:
                print(f"  req {handle.id} [g{handle.group}] done: "
                      f"{handle.tokens.size} tokens, "
                      f"latency {handle.latency_s * 1e3:.0f}ms")
        if not events and next_up < args.requests:
            time.sleep(min(0.005, max(arrivals[next_up] - now, 0.0)))

    st = session.stats()
    pl = st["placement"]
    print(f"\ndone: {st['requests_done']} requests, "
          f"{st['emitted_total']} tokens at {st['tokens_per_s']:.1f} tok/s "
          f"across {st['n_groups']} groups")
    print(f"placement: {pl['placed']} per group — "
          f"{pl['locality_hits']}/{pl['placements']} placements had warm "
          f"prefix pages (locality rate {pl['locality_rate']:.0%})")
    for gid in sorted(st["groups"]):
        g = st["groups"][gid]
        kv = g.get("kv", {})
        print(f"  group {gid}: {g['requests_done']} done, "
              f"occupancy {g['occupancy_pct']:.0f}%, "
              f"prefix hits {kv.get('prefix_hits', 0)}, "
              f"prefill skipped {kv.get('prefill_skipped_tokens', 0)} tok")
    kv = st.get("kv", {})
    stall = st["stall"]
    print(f"fleet: kv occupancy {kv.get('occupancy_pct', 0.0):.0f}%, "
          f"{kv.get('prefill_skipped_tokens', 0)} prompt tokens never "
          f"prefilled, stall {stall['stall_pct']:.1f}% "
          f"(load-average over {st['n_groups']} groups)")
    session.close()


if __name__ == "__main__":
    main()
