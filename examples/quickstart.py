"""Quickstart: the framework in ~50 lines, through the Cluster façade.

One `Cluster` owns the architecture, the mesh, the hybrid addressing plan
(weights INTERLEAVED, state SEQUENTIAL), and the kernel policy; programs
compiled on it train and decode — the whole public API surface.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.cluster import Cluster, ServeProgram, TrainProgram

# 1. one object for the substrate: arch + mesh + addressing + kernel policy
cluster = Cluster("qwen3-14b-smoke")
cfg = cluster.arch
print(f"arch={cfg.name}: {cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab}")
print(f"kernel policy: {cluster.kernel_policy.mode}")

# 2. the hybrid addressing plan: logical axes -> mesh placement, per param
plan = cluster.plan()
ffn = next(v for k, v in plan.items() if k.endswith("w_gate"))
norm = next(v for k, v in plan.items() if k == "ln_f")
print(f"ffn weight {ffn['shape']}: {ffn['spec']} ({ffn['region']})")
print(f"final norm {norm['shape']}: {norm['spec']} ({norm['region']})")

# 3. train a few steps on the synthetic stream
train = cluster.compile(TrainProgram(num_steps=5, batch=4, seq=32,
                                     log_every=1,
                                     checkpoint_dir="/tmp/repro-quickstart"))
report = train.run()
for m in report["metrics"]:
    print(f"step {m['step']}: loss={m['loss']:.4f}")

# 4. greedy decode with a KV cache, reusing the trained params
serve = cluster.compile(ServeProgram(batch=4, max_seq=32, max_new=8))
out = serve.run(params=report["params"])
print("decoded:", out["tokens"][0].tolist())

# 5. every program self-describes: spec + policy + compile-cache traffic
print("program report:", {k: train.report()[k]
                          for k in ("kind", "arch", "mesh", "policy")})
