"""Quickstart: the framework in ~60 lines.

Builds a reduced qwen3-family model, places it with the hybrid addressing
plan (weights INTERLEAVED, state SEQUENTIAL), runs a few train steps, and
decodes — the whole public API surface.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.core import addressing
from repro.core import compat
from repro.models import steps

# 1. pick an architecture (any of the ten; -smoke = reduced same-family)
cfg = get("qwen3-14b-smoke")
print(f"arch={cfg.name}: {cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab}")

# 2. the hybrid addressing plan: logical axes -> mesh placement
mesh = compat.make_mesh((1, 1), ("data", "model"))
rules = addressing.default_rules(mesh)
print("ffn weight spec:", rules.spec_for(("embed", "ffn"), (64, 128), mesh),
      "(INTERLEAVED region)")
print("batch spec:     ", rules.spec_for(("batch", "seq"), (4, 32), mesh),
      "(SEQUENTIAL region)")

# 3. train a few steps on random tokens
key = jax.random.PRNGKey(0)
S = 32
state = steps.init_train_state(cfg, key, max_seq=S)
train_step = jax.jit(steps.make_train_step(cfg))
batch = {"tokens": jax.random.randint(key, (4, S), 0, cfg.vocab),
         "labels": jax.random.randint(key, (4, S), 0, cfg.vocab)}
for i in range(5):
    state, metrics = train_step(state, batch)
    print(f"step {i}: loss={float(metrics['loss']):.4f} "
          f"gnorm={float(metrics['grad_norm']):.3f}")

# 4. greedy decode with a KV cache
cache = steps.init_cache(cfg, 4, S)
decode = jax.jit(steps.make_decode_step(cfg, max_seq=S))
tok = jnp.zeros((4, 1), jnp.int32)
out = [tok]
for pos in range(8):
    cache, tok = decode(state["params"], cache,
                        {"tokens": tok, "pos": jnp.asarray(pos, jnp.int32)})
    out.append(tok)
print("decoded:", jnp.concatenate(out, axis=1)[0].tolist())
