"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

A thin wrapper over the Cluster façade. The `TrainProgram` composes every
substrate layer: splitter/distributor data feed with double-buffered
prefetch (the DMA analogue — `double_buffer=True`), region-planned
shardings, compiled train step, async checkpointing with resume, straggler
detection.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import dataclasses
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.cluster import Cluster, TrainProgram
from repro.configs import get


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro-train-lm")
    ap.add_argument("--fast", action="store_true",
                    help="27M CI-speed variant instead of ~100M")
    args = ap.parse_args()

    # a ~100M-parameter xlstm-family model (8L, d=768, 32k vocab);
    # pass --fast for a 27M variant (CI-speed)
    if args.fast:
        cfg = dataclasses.replace(
            get("xlstm-125m"), n_layers=4, vocab=8192, attn_chunk=128)
    else:
        cfg = dataclasses.replace(
            get("xlstm-125m"), n_layers=8, vocab=32768, attn_chunk=128)
    print(f"model: {cfg.name} variant, {cfg.n_params() / 1e6:.1f}M params")

    cluster = Cluster(cfg)          # a custom ArchConfig works directly
    program = cluster.compile(TrainProgram(
        num_steps=args.steps, batch=args.batch, seq=args.seq,
        checkpoint_dir=args.ckpt, checkpoint_every=100,
        log_every=max(min(25, args.steps // 4), 1), warmup=20,
        double_buffer=True, resume=True))

    t0 = time.time()
    report = program.run()

    losses = [m["loss"] for m in report["metrics"]]
    print(f"\n{report['final_step']} steps in {time.time() - t0:.0f}s "
          f"({report['final_step'] / max(time.time() - t0, 1):.2f} steps/s)")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(must decrease on the zipfian stream)")
    print(f"stragglers flagged: {len(report['straggler_events'])}")
    if report["final_step"] >= 100:   # inside warmup the lr is ~0
        assert losses[-1] < losses[0], "loss did not improve"


if __name__ == "__main__":
    main()
