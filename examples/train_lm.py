"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Composes every substrate layer: splitter/distributor data feed with
double-buffered prefetch (the DMA analogue), region-planned shardings,
compiled train step, async checkpointing with resume, straggler detection.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import dataclasses
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import get
from repro.core import addressing
from repro.core import compat
from repro.data import DoubleBufferedFeed, Distributor, Splitter, SyntheticLMStream
from repro.data.pipeline import BatchSpec
from repro.models import steps
from repro.runtime import TrainLoop, TrainLoopConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro-train-lm")
    ap.add_argument("--fast", action="store_true",
                    help="27M CI-speed variant instead of ~100M")
    args = ap.parse_args()

    # a ~100M-parameter xlstm-family model (8L, d=768, 32k vocab);
    # pass --fast for a 27M variant (CI-speed)
    if args.fast:
        cfg = dataclasses.replace(
            get("xlstm-125m"), n_layers=4, vocab=8192, attn_chunk=128)
    else:
        cfg = dataclasses.replace(
            get("xlstm-125m"), n_layers=8, vocab=32768, attn_chunk=128)
    n = cfg.n_params()
    print(f"model: {cfg.name} variant, {n / 1e6:.1f}M params")

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    rules = addressing.default_rules(mesh, overrides=cfg.rules_overrides)

    state = steps.init_train_state(cfg, jax.random.PRNGKey(0),
                                   max_seq=args.seq)
    train_step = jax.jit(steps.make_train_step(
        cfg, schedule_kwargs={"warmup": 20, "total": args.steps}),
        donate_argnums=0)

    spec = BatchSpec(args.batch, args.seq, cfg.vocab)
    stream = SyntheticLMStream(spec, seed=0)
    dist = Distributor(mesh, Splitter(mesh, ("data",)))
    sh = jax.sharding.NamedSharding(
        mesh, rules.spec_for(("batch", "seq"), (args.batch, args.seq), mesh))
    feed = DoubleBufferedFeed(lambda s: dist.materialize(stream, s, sh),
                              depth=2)

    loop = TrainLoop(
        TrainLoopConfig(total_steps=args.steps, checkpoint_every=100,
                        log_every=max(min(25, args.steps // 4), 1),
                        checkpoint_dir=args.ckpt),
        train_step, state, feed)
    t0 = time.time()
    report = loop.run()
    feed.close()

    losses = [m["loss"] for m in report["metrics"]]
    print(f"\n{report['final_step']} steps in {time.time() - t0:.0f}s "
          f"({report['final_step'] / max(time.time() - t0, 1):.2f} steps/s)")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(must decrease on the zipfian stream)")
    print(f"stragglers flagged: {len(report['straggler_events'])}")
    if report["final_step"] >= 100:   # inside warmup the lr is ~0
        assert losses[-1] < losses[0], "loss did not improve"


if __name__ == "__main__":
    main()
