"""Continuous serving: Poisson request arrivals through a ServeSession.

Requests with mixed prompt/output lengths arrive on a Poisson clock and
flow through a fixed slot pool: the scheduler admits each one into the
first recycled slot (per-slot prompt prefill and position reset happen
inside the compiled chunk), so short requests finish and free their slot
while long ones keep decoding — no slot waits for a batch to drain. The
fixed-batch equivalent (`examples/serve_batched.py`, ServeProgram) still
works unchanged for the one-rectangular-batch case.

Prints per-request TTFT/latency as requests complete, then the session
stats: slot occupancy (the MemPool PE-utilization analogue), tokens/s,
and the StallClock ledger.

    PYTHONPATH=src python examples/serve_continuous.py --slots 4 --requests 12

`--groups N` (N > 1) shards the session across N serving groups
(`ShardedServeSessionProgram`): each group owns a full slot pool on its
own device slice and a two-level scheduler places arrivals — run it
under `XLA_FLAGS=--xla_force_host_platform_device_count=8` to give every
group its own host device. The drive loop is unchanged: the sharded
session speaks the same submit/poll/stats API.
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.cluster import (Cluster, ServeSessionProgram,
                           ShardedServeSessionProgram)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--slots", type=int, default=4,
                    help="slot-pool size (per group when --groups > 1)")
    ap.add_argument("--groups", type=int, default=1,
                    help="serving groups; > 1 shards the session")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="mean request arrivals per second (Poisson)")
    ap.add_argument("--chunk", type=int, default=4,
                    help="decode steps per host sync")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cluster = Cluster(args.arch + "-smoke")
    cfg = cluster.arch
    common = dict(slots=args.slots, max_seq=64, max_prompt=8,
                  chunk=args.chunk)
    program = cluster.compile(
        ShardedServeSessionProgram(groups=args.groups, **common)
        if args.groups > 1 else ServeSessionProgram(**common))
    session = program.open()

    rng = np.random.default_rng(args.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    prompts = [rng.integers(0, cfg.vocab, size=rng.integers(1, 9))
               .astype(np.int32) for _ in range(args.requests)]
    out_lens = rng.choice([8, 12, 16, 24, 32, 48], size=args.requests)

    shard = f" groups={args.groups}" if args.groups > 1 else ""
    print(f"arch={cfg.name} slots={args.slots} chunk={args.chunk}{shard} — "
          f"{args.requests} requests, ~{args.rate}/s Poisson arrivals, "
          f"prompts 1-8, outputs {sorted(set(out_lens.tolist()))}")
    t0 = time.perf_counter()
    next_up = 0
    while next_up < args.requests or session.busy:
        now = time.perf_counter() - t0
        while next_up < args.requests and arrivals[next_up] <= now:
            session.submit(prompts[next_up], int(out_lens[next_up]))
            next_up += 1
        events = session.poll()
        for handle, _toks, done in events:
            if done:
                where = (f" [g{handle.group}]"
                         if handle.group is not None else "")
                print(f"  req {handle.id}{where}: "
                      f"{handle.tokens.size} tokens, "
                      f"ttft {handle.ttft_s * 1e3:.0f}ms, "
                      f"latency {handle.latency_s * 1e3:.0f}ms")
        if not events and next_up < args.requests:
            time.sleep(min(0.005, max(arrivals[next_up] - now, 0.0)))

    st = session.stats()
    stall = st["stall"]
    print(f"done: {st['requests_done']} requests, "
          f"{st['emitted_total']} tokens at {st['tokens_per_s']:.1f} tok/s")
    print(f"slot occupancy {st['occupancy_pct']:.0f}%  "
          f"ttft p50={st['ttft_ms']['p50']:.0f}ms "
          f"p99={st['ttft_ms']['p99']:.0f}ms  "
          f"latency p99={st['latency_ms']['p99']:.0f}ms")
    print(f"engine: {stall['host_syncs']} host syncs, "
          f"stall={stall['stall_pct']:.1f}%, queue peak {st['queue_peak']}")
    if args.groups > 1:
        placed = st["placement"]["placed"]
        print(f"placement: {placed} per group, "
              f"locality rate {st['placement']['locality_rate']:.0%}, "
              f"quarantined {st['placement']['quarantined_groups']}")


if __name__ == "__main__":
    main()
