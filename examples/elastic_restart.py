"""Elastic restart: survive a node failure and resume on a smaller mesh.

Simulates the 1000-node failure path end-to-end on CPU, driving the
compiled step of a Cluster `TrainProgram` by hand:
  1. train on mesh A, async-checkpointing;
  2. "lose a host" (Coordinator event) mid-run -> preemption checkpoint;
  3. re-plan the mesh for the survivors (model axis kept, data axis shrunk);
  4. restore the same logical state onto the new mesh and keep training —
     the data stream is stateless-resumable, so not a single batch repeats.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.cluster import Cluster, TrainProgram
from repro.core import compat
from repro.runtime.coordination import Coordinator, replan_mesh_shape

CKPT = "/tmp/repro-elastic"


def make_batches(cfg, seq, start):
    step = start
    key = jax.random.PRNGKey(0)
    while True:
        k = jax.random.fold_in(key, step)          # stateless: f(seed, step)
        yield {"tokens": jax.random.randint(k, (4, seq), 0, cfg.vocab),
               "labels": jax.random.randint(k, (4, seq), 0, cfg.vocab)}
        step += 1


def main():
    seq = 32
    cluster = Cluster("qwen3-14b-smoke")
    cfg = cluster.arch
    # compile once; drive the program's step function by hand so the
    # failure/restore choreography stays explicit
    program = cluster.compile(TrainProgram(num_steps=10, seq=seq, seed=1,
                                           checkpoint_dir=CKPT))
    state, _ = program.init_state(seed=1)
    train_step = program.step
    mgr = CheckpointManager(CKPT, keep=2)

    # phase 1: run on the "big" mesh, checkpoint every 3 steps
    coord = Coordinator(n_hosts=64)
    batches = make_batches(cfg, seq, 0)
    step = 0
    for _ in range(7):
        state, metrics = train_step(state, next(batches))
        step += 1
        if step % 3 == 0:
            mgr.save(step, state)
    print(f"phase 1: reached step {step}, loss={float(metrics['loss']):.4f}")

    # phase 2: a host dies -> coordinator replans the mesh
    coord.emit("leave", "host-17")
    new_shape = replan_mesh_shape(
        (coord.n_hosts) * 4, model_parallel=1)       # 4 chips/host, toy scale
    print(f"host lost: {coord.n_hosts} hosts remain -> new mesh {new_shape}")
    mgr.save(step, state, block=True)                # preemption checkpoint

    # phase 3: fresh process view — restore the LOGICAL state onto the
    # survivors' mesh (here: 1-device CPU mesh; layout is mesh-independent)
    latest = mgr.latest_step()
    mesh = compat.make_mesh((1,), ("data",))
    sh = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(mesh,
                                             jax.sharding.PartitionSpec()),
        state)
    restored = mgr.restore(latest, jax.tree.map(jnp.zeros_like, state), sh)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float64),
                                      np.asarray(b, np.float64))
    print(f"restored step {latest} bit-exactly onto the new mesh")

    # phase 4: continue where we left off — stream is a pure f(seed, step)
    batches = make_batches(cfg, seq, latest)
    state = restored
    for _ in range(3):
        state, metrics = train_step(state, next(batches))
        latest += 1
    print(f"resumed to step {latest}, loss={float(metrics['loss']):.4f} — "
          f"no data repeated, no optimizer state lost")


if __name__ == "__main__":
    main()
