"""Chaos serving: scripted faults against a live ServeSession.

MemPool's robustness claim is architectural — one stalled core never
wedges the cluster, a dead core only costs its own lanes. This example
exercises the serving analogue end to end: a Poisson arrival stream of
mixed-priority requests runs twice through the same compiled session
cell, once fault-free (the reference) and once under a scripted
`FaultPlan` that kills a slot mid-decode (quarantine + requeue), NaN-
corrupts another slot's cache rows (sentinel scan + recycle + requeue),
and wedges a device wait (watchdog -> `SessionWedged` ->
`recover_wedged()` pool rebuild). The recovery contract is then checked
bit for bit: every request that completes under chaos must produce
exactly the tokens the fault-free run produced. Exit code 1 on any
divergence — this is the CI chaos-smoke job's assertion.

Prints a `# chaos:` summary line with fault/recovery counts.

    PYTHONPATH=src python examples/serve_chaos.py --requests 16

`--crash` is the crash-restart drill: a child process serves the same
workload with the durability layer on (journal + periodic snapshots)
and SIGKILLs itself mid-decode; the parent verifies the kill, restores
a session from the durable directory, drains it, and asserts that the
union of journal-committed (pre-crash) and post-restore deliveries
equals the fault-free reference exactly — every token once,
bit-identical. Prints a `# chaos-crash:` line with the measured MTTR.

    PYTHONPATH=src python examples/serve_chaos.py --crash
"""

import argparse
import os
import signal
import subprocess
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.cluster import Cluster, ServeSessionProgram
from repro.runtime import FaultPlan, SessionWedged

CLASS_MIX = ("latency", "throughput", "throughput", "best_effort")


def run_workload(program, params, prompts, out_lens, arrivals, plan=None):
    """Drive one session over the workload; returns (handles, stats,
    wedge_recoveries). Faults raise `SessionWedged` mid-poll; the driver
    recovers and keeps serving — the stream never dies."""
    session = program.open(params=params, faults=plan)
    handles = []
    wedges = 0
    t0 = time.perf_counter()
    next_up = 0
    n = len(prompts)
    while next_up < n or session.scheduler.busy:
        now = time.perf_counter() - t0
        while next_up < n and arrivals[next_up] <= now:
            handles.append(session.submit(
                prompts[next_up], int(out_lens[next_up]),
                klass=CLASS_MIX[next_up % len(CLASS_MIX)]))
            next_up += 1
        try:
            events = session.poll()
        except SessionWedged as e:
            print(f"  wedged at chunk {e.chunk} (watchdog "
                  f"{e.timeout_s:.2f}s) — rebuilding the pool")
            session.recover_wedged()
            wedges += 1
            continue
        if not events and next_up < n:
            time.sleep(min(0.005, max(arrivals[next_up] - now, 0.0)))
    return handles, session.stats(), wedges


def crash_setup(args):
    """Deterministic program + workload shared by the crash-drill parent
    and its SIGKILL'd child (both must submit the identical request
    stream so journal rids line up)."""
    cluster = Cluster(args.arch + "-smoke")
    cfg = cluster.arch
    program = cluster.compile(ServeSessionProgram(
        slots=args.slots, max_seq=64, max_prompt=8, chunk=args.chunk,
        snapshot_every=3))
    params = program.init_params()
    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(0, cfg.vocab, size=rng.integers(1, 9))
               .astype(np.int32) for _ in range(args.requests)]
    out_lens = rng.choice([8, 12, 16, 24], size=args.requests)
    return program, params, prompts, out_lens


def run_crash_child(args):
    """Serve with durability on and SIGKILL ourselves at the scripted
    chunk — the unflushed tail dies with us; only fsync'd journal state
    survives for the parent to recover."""
    from repro.runtime.journal import Journal  # noqa: F401  (import check)

    program, params, prompts, out_lens = crash_setup(args)
    plan = FaultPlan().crash(at_chunk=args.crash_at)
    sess = program.open(
        params=params, durable_dir=args.dir, faults=plan,
        crash_hook=lambda chunk: os.kill(os.getpid(), signal.SIGKILL))
    for p, n in zip(prompts, out_lens):
        sess.submit(p, int(n))
    sess.drain()        # never completes: the crash hook kills -9 first
    raise SystemExit("crash fault never fired — workload too short")


def run_crash_drill(args):
    """Parent side: fault-free reference, SIGKILL'd child, restore +
    drain, exactly-once bit-identical verification."""
    from repro.runtime.journal import read_events, replay

    program, params, prompts, out_lens = crash_setup(args)
    print("reference run (fault-free, in-process):")
    ref = program.open(params=params)
    ref_handles = [ref.submit(p, int(n))
                   for p, n in zip(prompts, out_lens)]
    ref.drain()
    expected = {h.id: [int(t) for t in h.result()] for h in ref_handles}
    print(f"  {len(expected)} done, "
          f"{sum(len(t) for t in expected.values())} tokens")

    with tempfile.TemporaryDirectory() as d:
        child_args = [sys.executable, __file__, "--crash-child",
                      "--dir", d, "--arch", args.arch,
                      "--slots", str(args.slots),
                      "--requests", str(args.requests),
                      "--chunk", str(args.chunk),
                      "--seed", str(args.seed),
                      "--crash-at", str(args.crash_at)]
        print(f"child run (SIGKILL at chunk {args.crash_at}):")
        proc = subprocess.run(child_args, env=dict(
            os.environ, PYTHONPATH=str(
                Path(__file__).resolve().parents[1] / "src")))
        if proc.returncode != -signal.SIGKILL:
            print(f"  child exited {proc.returncode}, expected "
                  f"{-signal.SIGKILL} (SIGKILL) — crash never fired")
            raise SystemExit(1)
        print(f"  child killed -9, journal + snapshots left in {d}")

        committed = {rid: list(r.committed) for rid, r in
                     replay(read_events(Path(d) / "journal.jsonl"))
                     .requests.items()}
        pre_crash = sum(len(t) for t in committed.values())
        sess = program.restore(d, params=params)
        du = sess.stats()["durability"]
        final = {rid: list(toks) for rid, toks in committed.items()}
        for h, toks, done in sess.stream():
            final.setdefault(h.id, []).extend(int(t) for t in toks)

        mismatches = dupes = 0
        for rid, want in expected.items():
            got = final.get(rid, [])
            if got != want:
                tag = ("over-delivered"
                       if got[:len(want)] == want else "DIVERGED")
                if tag == "over-delivered":
                    dupes += 1
                else:
                    mismatches += 1
                print(f"  req {rid}: {tag} "
                      f"({len(got)} vs {len(want)} tokens)")
        identical = "yes" if mismatches == 0 else "NO"
        exactly_once = "yes" if dupes == 0 else "NO"
        print(f"# chaos-crash: crash_at={args.crash_at} "
              f"committed_pre_crash={pre_crash} "
              f"replayed={du['replayed_requests']} "
              f"resubmitted={du['resubmitted']} "
              f"recovered_terminal={du['recovered_terminal']} "
              f"deduped={sess.stats()['durability']['deduped_tokens']} "
              f"snapshot_step={du['restored_step']} "
              f"mttr_ms={du['restore_s'] * 1e3:.1f} "
              f"bit_identical={identical} exactly_once={exactly_once}")
        if mismatches or dupes:
            raise SystemExit(1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=40.0,
                    help="mean request arrivals per second (Poisson)")
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--watchdog", type=float, default=0.5,
                    help="per-chunk device-wait bound (seconds)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--crash", action="store_true",
                    help="crash-restart drill: SIGKILL'd child + "
                         "journal/snapshot restore (see module docstring)")
    ap.add_argument("--crash-at", type=int, default=6,
                    help="chunk boundary the child crashes at")
    ap.add_argument("--crash-child", action="store_true",
                    help=argparse.SUPPRESS)       # internal: child mode
    ap.add_argument("--dir", default=None,
                    help=argparse.SUPPRESS)       # internal: durable dir
    args = ap.parse_args()

    if args.crash_child:
        run_crash_child(args)
        return
    if args.crash:
        run_crash_drill(args)
        return

    cluster = Cluster(args.arch + "-smoke")
    cfg = cluster.arch
    program = cluster.compile(ServeSessionProgram(
        slots=args.slots, max_seq=64, max_prompt=8, chunk=args.chunk,
        watchdog_s=args.watchdog, max_retries=3, retry_backoff_s=0.01))
    params = program.init_params()

    rng = np.random.default_rng(args.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    prompts = [rng.integers(0, cfg.vocab, size=rng.integers(1, 9))
               .astype(np.int32) for _ in range(args.requests)]
    out_lens = rng.choice([8, 12, 16, 24, 32], size=args.requests)

    # one of each failure mode, spread over the run's chunk timeline
    plan = (FaultPlan()
            .kill_slot(at_chunk=3, slot=1)
            .corrupt_nan(at_chunk=5, slot=2)
            .wedge(at_chunk=8))

    print(f"arch={cfg.name} slots={args.slots} chunk={args.chunk} — "
          f"{args.requests} requests, ~{args.rate}/s Poisson, "
          f"faults: kill@3/slot1, nan@5/slot2, wedge@8")
    print("reference run (fault-free):")
    ref_handles, ref_stats, _ = run_workload(program, params, prompts,
                                             out_lens, arrivals)
    print(f"  {ref_stats['requests_done']} done, "
          f"{ref_stats['emitted_total']} tokens")
    print("chaos run:")
    handles, stats, wedges = run_workload(program, params, prompts,
                                          out_lens, arrivals, plan=plan)

    survivors = mismatches = 0
    for i, (h, ref) in enumerate(zip(handles, ref_handles)):
        if not h.ok:
            print(f"  req {i}: not completed under chaos "
                  f"({h.state}{': ' + h.fail_reason if h.fail_reason else ''})")
            continue
        survivors += 1
        if not (ref.ok and np.array_equal(h.tokens, ref.tokens)):
            mismatches += 1
            print(f"  req {i}: DIVERGED from the fault-free run "
                  f"({h.tokens.size} vs {ref.tokens.size} tokens)")

    fired = plan.summary()["by_kind"]
    identical = "yes" if mismatches == 0 else "NO"
    print(f"# chaos: kills={fired['kill_slot']} "
          f"corruptions={fired['corrupt_nan']} wedges={wedges} "
          f"refill_errors={fired['refill_error']} "
          f"retries={stats['retries']} preemptions={stats['preemptions']} "
          f"failed={stats['requests_failed']} "
          f"quarantined={len(stats['quarantined_slots'])} "
          f"survivors={survivors}/{args.requests} bit_identical={identical}")
    if mismatches or not plan.exhausted:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
