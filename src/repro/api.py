"""High-level API — the "OpenMP layer" of the three programming models.

MemPool offers bare-metal C (full control), OpenMP (fork-join convenience),
and Halide (declarative). This framework mirrors that:

  bare-metal : repro.models.steps + explicit PartitionSpecs / shard_map
  OpenMP     : repro.cluster — Cluster + program specs; THIS module keeps
               the legacy one-call train/serve/plan signatures as thin
               deprecating shims over it
  Halide     : the config-driven launcher (repro.launch.train CLI)

New code should build a `repro.cluster.Cluster` and compile programs on it;
these wrappers exist so old call sites keep working with identical return
shapes.
"""

from __future__ import annotations

import warnings
from typing import Any

import jax

from repro.cluster import Cluster, ServeSessionProgram, TrainProgram

_UNSET = object()


def plan(arch: str, mesh: jax.sharding.Mesh) -> dict[str, Any]:
    """The hybrid addressing plan for an architecture on a mesh:
    {tree path: (logical axes, PartitionSpec, region)} for every parameter.

    Shim over `Cluster(arch, mesh).plan()`.
    """
    return Cluster(arch, mesh).plan()


def train(arch: str, *, num_steps: int | None = None, steps_=_UNSET,
          batch: int = 4, seq: int = 128, smoke: bool = True,
          checkpoint_dir: str = "/tmp/repro-api-train",
          mesh: jax.sharding.Mesh | None = None, seed: int = 0) -> dict:
    """One-call training on the synthetic stream. Returns the loop report.

    Shim over `Cluster(...).compile(TrainProgram(...)).run()`. `steps_` is a
    deprecated alias for `num_steps` (kept for one release).
    """
    if steps_ is not _UNSET:
        warnings.warn("api.train(steps_=...) is deprecated; use num_steps=",
                      DeprecationWarning, stacklevel=2)
        if num_steps is None:
            num_steps = steps_
    if num_steps is None:
        num_steps = 100
    cluster = Cluster(arch + ("-smoke" if smoke else ""), mesh)
    program = cluster.compile(TrainProgram(
        num_steps=num_steps, batch=batch, seq=seq, seed=seed,
        checkpoint_dir=checkpoint_dir))
    return program.run()


def serve(arch: str, params=None, *, batch: int = 4, max_seq: int = 64,
          max_new: int = 16, smoke: bool = True, seed: int = 0,
          chunk: int = 1) -> dict:
    """One-call batched greedy decoding. Returns tokens + latency stats.

    Shim over the request-level serving API: opens a `ServeSession`
    (`Cluster(...).compile(ServeSessionProgram(...))`), submits one batch
    of requests (one per slot), and drains — the legacy return shape
    (tokens array + ServeLoop-style stats) is preserved, and the decoded
    tokens are bit-identical to the old fixed-batch `ServeProgram` path.
    `chunk` is the decode-steps-per-host-sync knob (1 = one sync per
    token, the legacy default; K > 1 buries K steps in one device
    program). New code should open a session directly and use
    `submit`/`stream`/`drain`.
    """
    cluster = Cluster(arch + ("-smoke" if smoke else ""))
    program = cluster.compile(ServeSessionProgram(
        slots=batch, max_seq=max_seq, max_new=max_new, seed=seed,
        chunk=chunk))
    return program.run(params=params)
