"""High-level API — the "OpenMP layer" of the three programming models.

MemPool offers bare-metal C (full control), OpenMP (fork-join convenience),
and Halide (declarative). This framework mirrors that:

  bare-metal : repro.models.steps + explicit PartitionSpecs / shard_map
  OpenMP     : THIS module — one-call train/serve with the region plan applied
  Halide     : the config-driven launcher (repro.launch.train CLI)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Iterator

import jax

from repro.configs import get
from repro.core import addressing, compat
from repro.data import Distributor, Splitter, SyntheticLMStream
from repro.data.pipeline import BatchSpec
from repro.models import steps
from repro.runtime import ServeLoop, TrainLoop, TrainLoopConfig


def plan(arch: str, mesh: jax.sharding.Mesh) -> dict[str, Any]:
    """The hybrid addressing plan for an architecture on a mesh:
    {tree path: (logical axes, PartitionSpec, region)} for every parameter."""
    cfg = get(arch)
    rules = addressing.default_rules(mesh, overrides=cfg.rules_overrides)
    p_sds, p_log = steps.abstract_params(cfg)
    out = {}
    for (path, sds), (_, logical) in zip(
            jax.tree_util.tree_flatten_with_path(p_sds)[0],
            jax.tree_util.tree_flatten_with_path(
                p_log, is_leaf=lambda x: isinstance(x, tuple))[0]):
        key = "/".join(str(getattr(k, "key", k)) for k in path)
        spec = rules.spec_for(logical, sds.shape, mesh)
        region = ("REPLICATED" if not [s for s in spec if s] else
                  "INTERLEAVED" if any(n in ("embed", "ffn", "heads",
                                             "kv_heads", "vocab", "expert")
                                       for n in logical if n) else
                  "SEQUENTIAL")
        out[key] = {"logical": logical, "spec": spec, "region": region,
                    "shape": sds.shape}
    return out


def train(arch: str, *, steps_: int = 100, batch: int = 4, seq: int = 128,
          smoke: bool = True, checkpoint_dir: str = "/tmp/repro-api-train",
          mesh: jax.sharding.Mesh | None = None, seed: int = 0) -> dict:
    """One-call training on the synthetic stream. Returns the loop report."""
    cfg = get(arch + ("-smoke" if smoke else ""))
    mesh = mesh or compat.make_mesh((jax.device_count(), 1),
                                    ("data", "model"))
    rules = addressing.default_rules(mesh, overrides=cfg.rules_overrides)

    state = steps.init_train_state(cfg, jax.random.PRNGKey(seed), max_seq=seq)
    train_step = jax.jit(steps.make_train_step(
        cfg, schedule_kwargs={"warmup": max(steps_ // 10, 1),
                              "total": steps_}), donate_argnums=0)

    stream = SyntheticLMStream(BatchSpec(batch, seq, cfg.vocab), seed=seed)
    dist = Distributor(mesh, Splitter(mesh, ("data",)))
    sh = jax.sharding.NamedSharding(
        mesh, rules.spec_for(("batch", "seq"), (batch, seq), mesh))

    def batches() -> Iterator[dict]:
        step = 0
        while True:
            yield dist.materialize(stream, step, sh)
            step += 1

    loop = TrainLoop(
        TrainLoopConfig(total_steps=steps_,
                        checkpoint_every=max(steps_ // 2, 1),
                        log_every=max(steps_ // 10, 1),
                        checkpoint_dir=checkpoint_dir),
        train_step, state, batches())
    report = loop.run(start_step=0)
    report["params"] = loop.state["params"]
    return report


def serve(arch: str, params=None, *, batch: int = 4, max_seq: int = 64,
          max_new: int = 16, smoke: bool = True, seed: int = 0) -> dict:
    """One-call batched greedy decoding. Returns tokens + latency stats."""
    import numpy as np

    cfg = get(arch + ("-smoke" if smoke else ""))
    if params is None:
        params = steps.init_params(cfg, jax.random.PRNGKey(seed),
                                   max_seq=max_seq)
    cache = steps.init_cache(cfg, batch, steps.decode_cache_len(cfg, max_seq))
    decode = jax.jit(steps.make_decode_step(cfg, max_seq=max_seq))
    loop = ServeLoop(decode, params, cache, batch_size=batch)
    out = loop.generate(np.zeros((batch, 1), np.int32), max_new=max_new)
    return {"tokens": out, "stats": loop.stats()}
