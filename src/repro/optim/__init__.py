from .adamw import adam_init, adam_update, AdamConfig  # noqa: F401
from .schedule import warmup_cosine  # noqa: F401
