"""Gradient compression for the data-parallel reduction.

At 1000+ nodes the DP gradient all-reduce crosses DCN; compressing it is a
first-order lever. Two schemes:

  bf16     — cast-to-bf16 reduce (2x wire saving, negligible quality loss;
             the production default).
  int8_ef  — per-tensor scaled int8 quantization with error feedback: the
             quantization residual is carried and added to the next step's
             gradient, making the scheme unbiased over time (1-bit-Adam
             style). 4x wire saving.

`compressed_psum` runs inside shard_map over the data axis; the train-step
integration is the shard_map DP wrapper in examples/train_lm.py (the GSPMD
path fuses its reduction into backward, where a cast is the only hook).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_decompress(g, method: str = "bf16"):
    """Local lossy round-trip (what the wire sees), for EF bookkeeping."""
    if method == "bf16":
        return g.astype(jnp.bfloat16).astype(g.dtype)
    if method == "int8_ef":
        q, s = quantize_int8(g)
        return dequantize_int8(q, s).astype(g.dtype)
    raise ValueError(method)


def compressed_psum(grads: Any, axis_name: str, method: str = "bf16",
                    error_state: Any = None):
    """psum(compress(g + e)) with new error state. Call under shard_map."""
    if error_state is None:
        error_state = jax.tree.map(jnp.zeros_like, grads)

    def one(g, e):
        corrected = g + e
        sent = compress_decompress(corrected, method)
        new_e = corrected - sent
        red = jax.lax.psum(sent.astype(jnp.bfloat16)
                           if method == "bf16" else sent, axis_name)
        return red.astype(g.dtype), new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    reduced = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_err = jax.tree.unflatten(treedef, [o[1] for o in out])
    return reduced, new_err


def wire_bytes(grads, method: str) -> float:
    """Bytes each chip puts on the wire per all-reduce (for §Perf tables)."""
    per = {"none": 4.0, "bf16": 2.0, "int8_ef": 1.0}[method]
    return sum(g.size * per for g in jax.tree.leaves(grads))
