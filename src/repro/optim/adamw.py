"""AdamW with dtype-configurable moments and ZeRO-style placement.

Moments inherit each parameter's logical axes, so under the hybrid addressing
plan (core/addressing.py) they live in the SEQUENTIAL region: sharded over
(data x model) exactly like the FSDP weights, touched only by their owner —
ZeRO-1 falls out of the region policy rather than being a special code path.

Update math runs in fp32 regardless of storage dtype (bf16 moments are the
large-model configuration, e.g. grok-1-314b).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


def adam_init(params, cfg: AdamConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adam_update(params, grads, opt_state, cfg: AdamConfig, lr_scale=1.0):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m32 / bc1
        vhat = v32 / bc2
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (step_ + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}
