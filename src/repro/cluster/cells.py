"""Program-cell assembly: (arch x shape) -> (fn, abstract args, shardings).

Shared by the Cluster programs (cluster/session.py) and the multi-pod
dry-run CLI (launch/dryrun.py). Lives here — not in launch/dryrun — because
importing dryrun has a deliberate import-time side effect (forcing the XLA
host device count before jax initializes) that library code must not pay.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding

from repro.configs import input_specs
from repro.core import addressing
from repro.models import steps


def batch_logical(cfg, shape) -> dict:
    log = {"tokens": ("batch", "seq")}
    if shape.kind == "train":
        log["labels"] = ("batch", "seq")
    if shape.kind == "decode":
        log["tokens"] = ("batch", None)
        log["pos"] = ()
    if cfg.family == "encdec":
        log["enc_embeds"] = ("batch", None, None)
    if cfg.family == "vlm":
        log["img_embeds"] = ("batch", None, None)
    return log


def shardings_for(tree_sds, tree_logical, mesh, rules):
    def one(sds, logical):
        spec = rules.spec_for(logical, sds.shape, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree.map(
        one, tree_sds, tree_logical,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def layer_gather_specs(cfg, mesh, rules):
    """PartitionSpecs for ONE super-block's weights with the `data` axis
    removed — forcing FSDP all-gathers inside the scan (variant fsdpgather)."""
    gather_rules = addressing.default_rules(mesh, fsdp=False,
                                            overrides=cfg.rules_overrides)
    p_sds, p_log = steps.abstract_params(cfg)

    def one(sds, logical):
        # strip the leading stacked "layers" dim
        return gather_rules.spec_for(logical[1:], sds.shape[1:], mesh)

    return jax.tree.map(
        one, p_sds["blocks"], p_log["blocks"],
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def group_devices(mesh, n_groups: int) -> tuple:
    """Slice a mesh's devices into per-group assignments (tiles -> groups).

    The sharded serving session builds one session cell per group and
    pins each group's params/state to its device, so group g's decode
    chunks run concurrently with every other group's. Devices are taken
    in the mesh's data-axis order; with fewer devices than groups the
    assignment wraps round-robin — groups share a device (degraded but
    functional: the scheduler semantics are unchanged, only the compute
    overlap is lost), which is what single-device CPU smoke runs hit.
    """
    if n_groups < 1:
        raise ValueError(f"n_groups must be >= 1, got {n_groups}")
    try:
        import numpy as np
        devs = [d for d in np.asarray(mesh.devices).reshape(-1)]
    except AttributeError:
        devs = list(jax.devices())
    if not devs:
        devs = list(jax.devices())
    return tuple(devs[g % len(devs)] for g in range(n_groups))


def build_cell(cfg, shape, mesh, rules, fsdp_gather: bool = False,
               policy=None, decode_chunk: int = 1, session: bool = False,
               max_prompt: int = 8, paged: bool = False,
               page_size: int = 16):
    """Returns (fn, args_sds, in_shardings, out_shardings, donate).

    `decode_chunk > 1` (decode shapes only) builds the execution-engine
    cell instead of the single-step one: K decode steps rolled into one
    `lax.scan` with donated cache/token/flag buffers — the program the
    dry-run lowers then mirrors what `ServeProgram(chunk=K)` runs.

    `session=True` (decode shapes) builds the continuous-batching session
    cell instead: the K-step slot-scheduled chunk over the donated pool
    state (per-slot positions, prompt buffers, budgets — see
    `engine.session_chunk_fn`), mirroring what a compiled
    `ServeSessionProgram` steps between refills. `paged=True` (session
    shapes) lowers the shared-paged-KV variant of that cell: pageable
    K/V leaves become the global page pool and the state carries the
    per-slot page tables (`ServeSessionProgram(paged=True)`).
    """
    batch_sds = input_specs(cfg, shape)
    batch_log = batch_logical(cfg, shape)
    batch_sh = shardings_for(batch_sds, batch_log, mesh, rules)

    if shape.kind == "train":
        wsc = layer_gather_specs(cfg, mesh, rules) if fsdp_gather else None
        fn = steps.make_train_step(cfg, layer_wsc=wsc, policy=policy)
        state_sds, state_log = steps.abstract_train_state(cfg, shape.seq_len)
        state_sh = shardings_for(state_sds, state_log, mesh, rules)
        out_sh = (state_sh, None)
        return fn, (state_sds, batch_sds), (state_sh, batch_sh), out_sh, (0,)

    params_sds, params_log = steps.abstract_params(cfg, shape.seq_len)
    params_sh = shardings_for(params_sds, params_log, mesh, rules)

    if shape.kind == "prefill":
        fn = steps.make_prefill_step(cfg, policy=policy)
        tok_sh = NamedSharding(
            mesh, rules.spec_for(("batch",), (shape.global_batch,), mesh))
        return (fn, (params_sds, batch_sds), (params_sh, batch_sh),
                tok_sh, ())

    # decode
    cache_len = steps.decode_cache_len(cfg, shape.seq_len)
    cache_sds, cache_log = steps.abstract_cache(cfg, shape.global_batch,
                                                cache_len)
    cache_sh = shardings_for(cache_sds, cache_log, mesh, rules)
    tok_sh = NamedSharding(
        mesh, rules.spec_for(("batch", None), (shape.global_batch, 1), mesh))
    if session:
        from repro.runtime import engine

        step = steps.make_decode_step(cfg, max_seq=shape.seq_len,
                                      policy=policy)
        fn = engine.session_chunk_fn(step, decode_chunk)
        B = shape.global_batch
        pps = None
        if paged:
            # pageable K/V leaves move into the shared pool; the state
            # grows a (B, pages_per_slot) page-table row
            pps = -((shape.seq_len + 1) // -page_size)   # ceil
            cache_sds, cache_log = steps.abstract_paged_cache(
                cfg, B, cache_len, n_pages=B * pps + 1,
                page_size=page_size)
            cache_sh = shardings_for(cache_sds, cache_log, mesh, rules)
        # the pool-state spec is whatever init_session_state builds — one
        # source of truth, so engine-side field changes propagate here
        state_sds = jax.eval_shape(
            lambda c: engine.init_session_state(c, B, max_prompt,
                                                pages_per_slot=pps),
            cache_sds)
        slot_sh = NamedSharding(mesh, rules.spec_for(("batch",), (B,), mesh))
        buf_sh = lambda n: NamedSharding(
            mesh, rules.spec_for(("batch", None), (B, n), mesh))
        state_sh = {k: (cache_sh if k == "cache" else
                        buf_sh(1) if k == "tok" else
                        buf_sh(max_prompt) if k == "prompt_buf" else
                        buf_sh(pps) if k == "pages" else slot_sh)
                    for k in state_sds}
        scalar_sh = NamedSharding(mesh, jax.sharding.PartitionSpec())
        out_sh = (state_sh, buf_sh(decode_chunk), buf_sh(decode_chunk),
                  slot_sh, scalar_sh)
        return fn, (params_sds, state_sds), (params_sh, state_sh), out_sh, (1,)
    if decode_chunk > 1:
        from repro.runtime import engine
        step = steps.make_decode_step(cfg, max_seq=shape.seq_len,
                                      policy=policy)
        fn = engine.decode_chunk_fn(step, decode_chunk)
        B = shape.global_batch
        i32 = jax.ShapeDtypeStruct((), jax.numpy.int32)
        slot_sds = lambda dt: jax.ShapeDtypeStruct((B,), dt)
        slot_sh = NamedSharding(
            mesh, rules.spec_for(("batch",), (B,), mesh))
        args = (params_sds, cache_sds, batch_sds["tokens"],
                slot_sds(jax.numpy.bool_), slot_sds(jax.numpy.int32),
                i32, i32)
        scalar_sh = NamedSharding(mesh, jax.sharding.PartitionSpec())
        in_sh = (params_sh, cache_sh, batch_sh["tokens"], slot_sh, slot_sh,
                 scalar_sh, scalar_sh)
        toks_sh = NamedSharding(
            mesh, rules.spec_for(("batch", None), (B, decode_chunk), mesh))
        out_sh = (cache_sh, batch_sh["tokens"], slot_sh, slot_sh, scalar_sh,
                  scalar_sh, scalar_sh, toks_sh)
        return fn, args, in_sh, out_sh, (1, 2, 3, 4)
    fn = steps.make_decode_step(cfg, max_seq=shape.seq_len, policy=policy)
    return (fn, (params_sds, cache_sds, batch_sds),
            (params_sh, cache_sh, batch_sh), (cache_sh, tok_sh), (1,))


def model_flops(cfg, shape) -> dict:
    n = cfg.n_params()
    n_act = cfg.n_active_params()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        mf = 6.0 * n_act * d
    elif shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        mf = 2.0 * n_act * d
    else:
        d = shape.global_batch
        mf = 2.0 * n_act * d
    return {"n_params": n, "n_active_params": n_act, "tokens": d,
            "model_flops": mf}
