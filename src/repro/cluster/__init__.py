"""repro.cluster — the Cluster/Session façade and the kernel policy.

`KernelPolicy` (policy.py) is imported eagerly: it is dependency-light and
the kernel layer (kernels/ops.py) and model stack read it at dispatch time.
The Cluster + program classes (session.py) pull in the whole model/runtime
stack, so they load lazily on first attribute access — `import
repro.cluster` from a kernel module stays cheap and cycle-free.
"""

from repro.cluster.policy import (KernelPolicy, as_policy,  # noqa: F401
                                  current_policy, default_policy, scoped,
                                  use_policy)
from repro.kernels.tunedb import TuneDB  # noqa: F401  (dependency-light)

_SESSION_EXPORTS = ("Cluster", "Program", "TrainProgram", "ServeProgram",
                    "ServeSessionProgram", "ShardedServeSessionProgram",
                    "DryRunProgram", "BenchProgram",
                    "CompiledTrain", "CompiledServe", "CompiledServeSession",
                    "CompiledShardedServeSession",
                    "CompiledDryRun", "CompiledBench")

__all__ = list(_SESSION_EXPORTS) + [
    "KernelPolicy", "TuneDB", "as_policy", "current_policy",
    "default_policy", "scoped", "use_policy",
]


def __getattr__(name):
    if name in _SESSION_EXPORTS:
        from repro.cluster import session
        return getattr(session, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
