"""Cluster/Session façade — one object owning mesh, addressing, kernel
policy, and compiled programs.

MemPool's programmability claim is that 256 cores with one shared L1 view
are driven through multiple runtimes over a *single* substrate; the
follow-up "Flavors" work configures that one substrate per workload. This
module is the substrate object for the TPU translation:

    cluster = Cluster("qwen3-14b-smoke")            # arch + mesh + rules
    with cluster.policy("fused"):                   # kernel policy scope
        train = cluster.compile(TrainProgram(num_steps=100))
    report = train.run()                            # .plan() / .report() too

`Cluster` owns the ArchConfig, the mesh, the hybrid-addressing rules, the
KERNEL_TUNES view, and a CompileCache; `cluster.compile(spec)` turns a
program spec (TrainProgram / ServeProgram / DryRunProgram / BenchProgram)
into a Program with `.run()`, `.plan()`, and `.report()`. Every entrypoint
(`repro.api`, `launch/train.py`, `launch/dryrun.py`, `benchmarks/run.py`,
the examples) is a thin wrapper over these objects, so later subsystems
(continuous batching, multi-cluster, backend selection) plug into one
place instead of five.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster import cells
from repro.cluster.policy import KernelPolicy, as_policy, use_policy
from repro.configs import get as get_arch
from repro.configs.registry import (ArchConfig, SHAPES, cell_supported,
                                    kernel_tunes)
from repro.core import addressing, compat
from repro.kernels import tunedb
from repro.models import steps
from repro.runtime import (CompileCache, ServeLoop, TrainLoop,
                           TrainLoopConfig, engine)


# ----------------------------------------------------------------------------
# Program specs — frozen descriptions, compiled by Cluster.compile
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrainProgram:
    """A training run on the synthetic stream, region-planned on the mesh."""

    num_steps: int = 100
    batch: int = 4
    seq: int = 128
    seed: int = 0
    checkpoint_dir: str = "/tmp/repro-train"
    checkpoint_every: int | None = None    # None -> max(num_steps // 2, 1)
    log_every: int | None = None           # None -> max(num_steps // 10, 1)
    warmup: int | None = None              # None -> max(num_steps // 10, 1)
    resume: bool = False                   # restore latest checkpoint first
    double_buffer: bool = False            # prefetch feed (DMA analogue)
    steps_per_sync: int = 1                # steps per scan-compiled chunk
    #   (> 1: host syncs once per chunk; straggler/logging sample at chunk
    #   granularity; state donated through the chunk — engine.py)


@dataclasses.dataclass(frozen=True)
class ServeProgram:
    """Batched greedy decoding against a KV cache."""

    batch: int = 4
    max_seq: int = 64
    max_new: int = 16
    seed: int = 0
    eos_id: int | None = None
    chunk: int = 16                        # decode steps per host sync:
    #   1 = per-token host loop; K > 1 = scan-compiled K-step engine with
    #   donated cache/token buffers (runtime/engine.py)


@dataclasses.dataclass(frozen=True)
class ServeSessionProgram:
    """Request-level serving: a slot pool with continuous batching.

    Compiles to a `CompiledServeSession`; `open()` returns a live
    `ServeSession` with `submit(prompt, max_new, klass=..., deadline_s=...)
    -> RequestHandle`, `poll()`/`stream()` for incremental tokens,
    `cancel(handle)`, and `drain()`. `run()` is the one-shot path (fill
    the pool with one batch, drain, legacy `ServeProgram`-shaped result).

    The SLO/robustness knobs configure the session's priority admission
    (`shed_watermark`, `aging_rounds`), slot preemption (`preempt`), the
    per-chunk device-wait watchdog (`watchdog_s` -> `SessionWedged`),
    fault recovery (`max_retries`, `retry_backoff_s`), and the NaN
    corruption sentinel (`nan_check`); `open(faults=FaultPlan(...))` arms
    scripted fault injection for chaos runs.

    `paged=True` swaps the per-slot private KV layout for the shared
    paged pool (runtime/kvpool.py): attention K/V lives in one global
    page array, slots hold page tables, refill installs tables instead
    of zeroing cache rows, and shared prompt prefixes are reused
    copy-on-write so repeated preambles skip prefill entirely. Paged
    sessions run with preemption off (slot snapshots do not carry page
    tables) and require an arch with positional attention (windowed /
    recurrent-only archs keep their private layout and reject `paged`).
    """

    slots: int = 4                         # slot-pool size (batch rows)
    max_seq: int = 64
    max_prompt: int = 8                    # per-slot prompt buffer length
    max_new: int = 16                      # one-shot run() / submit default
    seed: int = 0
    eos_id: int | None = None
    chunk: int = 16                        # decode steps per host sync
    max_queue: int | None = None           # bounded-queue backpressure
    admission: str = "fifo"                # or "longest_prefix"
    shed_watermark: int | None = None      # total queue depth that sheds
    #   best-effort work (latency/throughput get QueueFull instead)
    aging_rounds: int = 8                  # anti-starvation: +1 effective
    #   class rank per this many admission rounds waited
    preempt: bool = True                   # latency may checkpoint + evict
    #   a lower-class running slot (bit-identical resume)
    watchdog_s: float | None = None        # per-chunk device-wait bound;
    #   None = wait forever (poll(timeout_s=...) still overrides)
    max_retries: int = 2                   # fault-recovery restarts per
    #   request before it fails with "retries_exhausted"
    retry_backoff_s: float = 0.05          # base of the exponential
    #   re-admission backoff after a fault restart
    nan_check: bool = False                # scan cache rows for NaN every
    #   chunk (auto-on when a FaultPlan scripts corruption)
    paged: bool = False                    # shared paged KV pool with COW
    #   prefix reuse (forces preempt off; see class docstring)
    page_size: int = 16                    # tokens per KV page
    n_pages: int | None = None             # pool size; None -> slots *
    #   pages_per_slot + 1 (trash page), i.e. private-layout capacity
    prefix_cache: bool = True              # publish finished prompts for
    #   COW prefix reuse across requests
    snapshot_every: int | None = None      # chunks between bit-exact
    #   session snapshots (needs open(durable_dir=...)); None = journal-only
    journal_fsync: bool | int = True       # True/False/every-K (see Journal)
    scrub_pages: int = 2                   # stamped pages re-verified per
    #   boundary by the background integrity scrub (paged; 0 disables)


@dataclasses.dataclass(frozen=True)
class ShardedServeSessionProgram(ServeSessionProgram):
    """Cluster-of-clusters serving: `groups` full session cells behind
    one `submit/poll/stream/cancel/drain` surface.

    Mirrors MemPool's tiles -> groups -> cluster hierarchy on the device
    mesh: each serving group owns a complete session cell (slot pool,
    paged KV pool + prefix cache, stall ledger, journal) pinned to its
    own device, and a two-level scheduler places each request in a group
    (locality-aware: warm prefix-cache overlap + load, scored with the
    paper's topology model) before the group's own slot scheduler takes
    over. All `ServeSessionProgram` knobs apply *per group* — e.g.
    `slots=4, groups=2` is 8 slots total, two pools of 4.

    `open()` returns a `runtime.ShardedServeSession`; with `groups=1` it
    is token-for-token identical to `ServeSessionProgram.open()` (same
    cell, same scheduler, a trivial placement layer) and its durable
    directory stays restorable by either program. `run()` (the one-shot
    legacy path) is not defined for sharded sessions.
    """

    groups: int = 2                        # serving groups (session cells)


@dataclasses.dataclass(frozen=True)
class DryRunProgram:
    """Lower + compile one (arch x shape) cell on this cluster's mesh and
    extract memory/cost/collective analysis — no allocation."""

    shape: str = "train_4k"
    fsdp_gather: bool = False
    decode_chunk: int = 1                  # decode shapes: lower the K-step
    #   scan-compiled engine cell instead of the single-step one
    session: bool = False                  # decode shapes: lower the slot-
    #   scheduled session cell (donated pool state) instead
    paged: bool = False                    # session shapes: lower the
    #   shared-paged-KV session cell (page tables in state)
    page_size: int = 16                    # tokens per KV page (paged)


@dataclasses.dataclass(frozen=True)
class BenchProgram:
    """The paper-figure benchmark sweep, run under this cluster's policy."""

    sections: tuple[str, ...] = ()         # () -> every module offered
    smoke: bool = False
    repeat: int = 1


# ----------------------------------------------------------------------------
# Cluster
# ----------------------------------------------------------------------------


class Cluster:
    """The substrate: arch + mesh + addressing + kernel policy + programs.

    `arch` may be an arch name (``"qwen3-14b-smoke"``), an ArchConfig, or
    None for a kernel-only cluster (policy + tunes + bench programs, no
    model). `mesh` defaults to all local devices on a (data, model) mesh.

    `tune_db` is the persistent timed-tune database: a
    `kernels.tunedb.TuneDB`, a path to open one, or None to fall back to
    the ``REPRO_TUNE_DB`` env default (which may itself be unset — no
    persistence). When a DB resolves, the cluster warm-starts
    KERNEL_TUNES from it (so `tuned_call` hits instead of racing) and
    installs it as the active write-through target for new races; the
    warm-start count is kept in ``tune_db_warm`` and surfaced by
    `Program.report()` alongside the policy's tune_hits/misses/races.
    """

    def __init__(self, arch: "str | ArchConfig | None" = None, mesh=None, *,
                 policy: "KernelPolicy | str | None" = None,
                 rules_overrides=None,
                 tune_db: "tunedb.TuneDB | str | None" = None):
        self.arch: ArchConfig | None = (
            get_arch(arch) if isinstance(arch, str) else arch)
        self.mesh = mesh if mesh is not None else compat.make_mesh(
            (jax.device_count(), 1), ("data", "model"))
        if rules_overrides is None:
            rules_overrides = (self.arch.rules_overrides if self.arch
                               else ())
        self.rules = addressing.default_rules(self.mesh,
                                              overrides=rules_overrides)
        self._policy = as_policy(policy)
        self.compile_cache = CompileCache()
        self.tune_db = tunedb.resolve_db(tune_db)
        self.tune_db_warm = 0
        if self.tune_db is not None:
            self.tune_db_warm = self.tune_db.warm_start(
                backend=jax.default_backend(), mode=self._policy.mode)
            tunedb.set_active_db(self.tune_db)

    # -- kernel policy --------------------------------------------------------
    @property
    def kernel_policy(self) -> KernelPolicy:
        return self._policy

    def policy(self, policy: "KernelPolicy | str | None" = None, **kwargs):
        """Scope a kernel policy on this cluster::

            with cluster.policy("fused"):              # a mode string
            with cluster.policy(mode="tuned", overrides={"matmul": "reference"}):

        Inside the block the policy is both the ambient one (kernel dispatch
        reads it) and the cluster default captured by `compile`.
        """
        if policy is None:
            pol = KernelPolicy(**kwargs) if kwargs else self._policy
        else:
            pol = as_policy(policy)
            if kwargs:
                pol = dataclasses.replace(pol, **kwargs)
        return _PolicyScope(self, pol)

    def tunes(self, kernel: str | None = None) -> list:
        """This cluster's view of the autotune records (KERNEL_TUNES)."""
        recs = kernel_tunes()
        if kernel is not None:
            recs = [r for r in recs if r.kernel == kernel]
        return recs

    # -- addressing plan ------------------------------------------------------
    def plan(self) -> dict[str, Any]:
        """The hybrid addressing plan for this cluster's arch on its mesh:
        {tree path: {logical, spec, region, shape}} for every parameter."""
        cfg = self._require_arch("plan")
        p_sds, p_log = steps.abstract_params(cfg)
        out = {}
        for (path, sds), (_, logical) in zip(
                jax.tree_util.tree_flatten_with_path(p_sds)[0],
                jax.tree_util.tree_flatten_with_path(
                    p_log, is_leaf=lambda x: isinstance(x, tuple))[0]):
            key = "/".join(str(getattr(k, "key", k)) for k in path)
            spec = self.rules.spec_for(logical, sds.shape, self.mesh)
            region = ("REPLICATED" if not [s for s in spec if s] else
                      "INTERLEAVED" if any(n in ("embed", "ffn", "heads",
                                                 "kv_heads", "vocab",
                                                 "expert")
                                           for n in logical if n) else
                      "SEQUENTIAL")
            out[key] = {"logical": logical, "spec": spec, "region": region,
                        "shape": sds.shape}
        return out

    def state_shardings(self, tree_sds, tree_logical):
        return cells.shardings_for(tree_sds, tree_logical, self.mesh,
                                   self.rules)

    # -- compilation ----------------------------------------------------------
    def compile(self, spec) -> "Program":
        """Program spec -> compiled Program, memoized in the compile cache
        keyed on (spec, arch, mesh, policy knobs)."""
        builders = {TrainProgram: CompiledTrain, ServeProgram: CompiledServe,
                    ServeSessionProgram: CompiledServeSession,
                    ShardedServeSessionProgram: CompiledShardedServeSession,
                    DryRunProgram: CompiledDryRun, BenchProgram: CompiledBench}
        try:
            builder = builders[type(spec)]
        except KeyError:
            raise TypeError(f"Cluster.compile expects a program spec, got "
                            f"{type(spec).__name__}") from None
        key = (type(spec).__name__, spec,
               self.arch.name if self.arch else None,
               tuple(self.mesh.shape.items())
               if hasattr(self.mesh.shape, "items") else self.mesh.shape,
               self._policy.fingerprint())
        return self.compile_cache.get(key,
                                      lambda: builder(self, spec,
                                                      self._policy))

    def _require_arch(self, what: str) -> ArchConfig:
        if self.arch is None:
            raise ValueError(f"{what} needs an architecture; this is a "
                             f"kernel-only Cluster (arch=None)")
        return self.arch


class _PolicyScope:
    def __init__(self, cluster: Cluster, pol: KernelPolicy):
        self._cluster = cluster
        self._pol = pol
        self._prev: KernelPolicy | None = None
        self._cm = None

    def __enter__(self) -> KernelPolicy:
        self._prev = self._cluster._policy
        self._cluster._policy = self._pol
        self._cm = use_policy(self._pol)
        return self._cm.__enter__()

    def __exit__(self, *exc):
        try:
            return self._cm.__exit__(*exc)
        finally:
            self._cluster._policy = self._prev


# ----------------------------------------------------------------------------
# Compiled programs
# ----------------------------------------------------------------------------


class Program:
    """A compiled program bound to its cluster: `.run()`, `.plan()`,
    `.report()`. Subclasses hold the actual compiled step functions."""

    kind = "program"

    def __init__(self, cluster: Cluster, spec, policy: KernelPolicy):
        self.cluster = cluster
        self.spec = spec
        self.policy = policy
        self._last_run: dict | None = None

    def run(self, **kwargs) -> dict:
        raise NotImplementedError

    def plan(self) -> dict:
        return self.cluster.plan()

    def report(self) -> dict:
        """Program metadata + (when run) a result summary."""
        mesh = self.cluster.mesh
        out = {
            "kind": self.kind,
            "arch": self.cluster.arch.name if self.cluster.arch else None,
            "mesh": dict(mesh.shape.items())
            if hasattr(mesh.shape, "items") else mesh.shape,
            "spec": dataclasses.asdict(self.spec),
            "policy": self.policy.describe(),
            "compile_cache": {"hits": self.cluster.compile_cache.hits,
                              "misses": self.cluster.compile_cache.misses},
        }
        if self.cluster.tune_db is not None:
            out["tunedb"] = dict(self.cluster.tune_db.describe(),
                                 warm_started=self.cluster.tune_db_warm)
        if self._last_run is not None:
            out["result"] = {k: v for k, v in self._last_run.items()
                             if k != "params"}
        return out


class CompiledTrain(Program):
    kind = "train"

    def __init__(self, cluster, spec: TrainProgram, policy):
        super().__init__(cluster, spec, policy)
        cfg = cluster._require_arch("TrainProgram")
        n = spec.num_steps
        warmup = spec.warmup if spec.warmup is not None else max(n // 10, 1)
        raw_step = steps.make_train_step(cfg,
                                         schedule_kwargs={"warmup": warmup,
                                                          "total": n},
                                         policy=policy)
        self.step: Callable = jax.jit(raw_step, donate_argnums=0)
        # scan-of-steps engine program (state donated through the chunk)
        self.chunk: Callable | None = (
            engine.make_train_chunk(raw_step)
            if spec.steps_per_sync > 1 else None)

    def init_state(self, seed: int | None = None):
        cfg = self.cluster.arch
        seed = self.spec.seed if seed is None else seed
        state = steps.init_train_state(cfg, jax.random.PRNGKey(seed),
                                       max_seq=self.spec.seq)
        sh = self._state_shardings(state)
        return jax.tree.map(jax.device_put, state, sh), sh

    def _state_shardings(self, state):
        state_sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        _, state_log = steps.abstract_train_state(self.cluster.arch,
                                                  self.spec.seq)
        return self.cluster.state_shardings(state_sds, state_log)

    def _feed(self, batch_sh):
        from repro.data import (Distributor, DoubleBufferedFeed, Splitter,
                                SyntheticLMStream)
        from repro.data.pipeline import BatchSpec

        cfg, spec = self.cluster.arch, self.spec
        stream = SyntheticLMStream(BatchSpec(spec.batch, spec.seq, cfg.vocab),
                                   seed=spec.seed)
        dist = Distributor(self.cluster.mesh,
                           Splitter(self.cluster.mesh, ("data",)))
        if spec.double_buffer:
            # chunked stepping drains steps_per_sync batches per dispatch;
            # the ring must hold a full chunk or the drain blocks on the
            # producer and un-hides the transfers it exists to hide
            return DoubleBufferedFeed(
                lambda s: dist.materialize(stream, s, batch_sh),
                depth=max(2, spec.steps_per_sync))

        def batches() -> Iterator[dict]:
            step = 0
            while True:
                yield dist.materialize(stream, step, batch_sh)
                step += 1

        return batches()

    def run(self) -> dict:
        spec = self.spec
        mesh, rules = self.cluster.mesh, self.cluster.rules
        n = spec.num_steps
        state, state_sh = self.init_state()
        batch_sh = jax.sharding.NamedSharding(
            mesh, rules.spec_for(("batch", "seq"), (spec.batch, spec.seq),
                                 mesh))
        feed = self._feed(batch_sh)
        loop = TrainLoop(
            TrainLoopConfig(
                total_steps=n,
                checkpoint_every=(spec.checkpoint_every
                                  if spec.checkpoint_every is not None
                                  else max(n // 2, 1)),
                log_every=(spec.log_every if spec.log_every is not None
                           else max(n // 10, 1)),
                checkpoint_dir=spec.checkpoint_dir,
                steps_per_sync=spec.steps_per_sync),
            self.step, state, feed, state_shardings=state_sh,
            train_chunk=self.chunk)
        try:
            with compat.set_mesh(mesh):
                report = loop.run(
                    start_step=None if spec.resume else 0)
        finally:
            if hasattr(feed, "close"):
                feed.close()
        if hasattr(feed, "stall_report"):
            report["feed"] = feed.stall_report()
        report["params"] = loop.state["params"]
        self._last_run = report
        return report


class CompiledServe(Program):
    kind = "serve"

    def __init__(self, cluster, spec: ServeProgram, policy):
        super().__init__(cluster, spec, policy)
        cfg = cluster._require_arch("ServeProgram")
        self.decode: Callable = jax.jit(
            steps.make_decode_step(cfg, max_seq=spec.max_seq, policy=policy))
        # the K-step scan program is built once here so repeated .run()s
        # hit the jit cache instead of re-tracing the whole chunk
        self.engine = (engine.DecodeEngine(self.decode, spec.chunk,
                                           eos_id=spec.eos_id)
                       if spec.chunk > 1 else None)

    def init_params(self, seed: int | None = None):
        cfg = self.cluster.arch
        seed = self.spec.seed if seed is None else seed
        return steps.init_params(cfg, jax.random.PRNGKey(seed),
                                 max_seq=self.spec.max_seq)

    def run(self, params=None, prompt=None) -> dict:
        """Greedy decode `max_new` tokens per slot. `prompt` (B, P) is fed
        token-by-token first (continuous-batching-style ingest); generation
        then continues from the last sampled token."""
        cfg, spec = self.cluster.arch, self.spec
        if params is None:
            params = self.init_params()
        cache = steps.init_cache(cfg, spec.batch,
                                 steps.decode_cache_len(cfg, spec.max_seq))
        start = np.zeros((spec.batch, 1), np.int32)
        pos0 = 0
        if prompt is not None:
            prompt = np.asarray(prompt)
            tok = None
            for t in range(prompt.shape[1]):
                cache, tok = self.decode(
                    params, cache,
                    {"tokens": jnp.asarray(prompt[:, t:t + 1], jnp.int32),
                     "pos": jnp.asarray(t, jnp.int32)})
            start, pos0 = np.asarray(tok), prompt.shape[1]
        loop = ServeLoop(self.decode, params, cache, batch_size=spec.batch,
                         eos_id=spec.eos_id, chunk=spec.chunk,
                         engine=self.engine)
        out = loop.generate(start, max_new=spec.max_new, start_pos=pos0)
        result = {"tokens": out, "stats": loop.stats()}
        self._last_run = {"stats": result["stats"],
                          "tokens_shape": tuple(out.shape)}
        return result


class CompiledServeSession(Program):
    """Request-level serving: slot pool + scheduler + compiled session cell.

    `open()` hands out a live `ServeSession`; `run()` is the one-shot path
    that fills every slot with one batch of requests, drains, and returns
    the legacy `ServeProgram` result shape — bit-identical tokens for
    single-batch submission (api.serve routes through this).
    """

    kind = "serve_session"

    def __init__(self, cluster, spec: ServeSessionProgram, policy):
        super().__init__(cluster, spec, policy)
        cfg = cluster._require_arch("ServeSessionProgram")
        if spec.admission not in ("fifo", "longest_prefix"):
            raise ValueError(f"unknown admission policy {spec.admission!r}")
        # raw (unjitted) per-slot-position decode step; the session chunk
        # jits the whole K-step program around it. Built once here so every
        # session opened on this program shares the compiled cell.
        step = steps.make_decode_step(cfg, max_seq=spec.max_seq,
                                      policy=policy)
        self._chunk_fn = engine.make_session_chunk(step, spec.chunk,
                                                   eos_id=spec.eos_id)
        if spec.paged:
            # shared paged KV pool: refill installs page tables, fault
            # programs route pool leaves by table (steps.py paged ops);
            # snapshot/restore stay None — preemption is off under paged
            pps = -((spec.max_seq + 1) // -spec.page_size)   # ceil
            self._pages_per_slot = pps
            self._n_pages = (spec.n_pages if spec.n_pages is not None
                             else spec.slots * pps + 1)      # +1: trash page
            ops = steps.make_paged_cache_ops(
                cfg, spec.slots, steps.decode_cache_len(cfg, spec.max_seq))
            self._refill_fn = engine.make_paged_session_refill(
                cache_zero=ops["zero_slots"])
            self._snapshot_fn = None
            self._restore_fn = None
            self._nan_scan_fn = engine.make_paged_nan_scan(ops["nan_slots"])
            self._corrupt_fn = engine.make_paged_slot_corrupt(
                ops["corrupt_slots"])
            self._page_copy_fn = engine.make_page_copy(ops["copy_pages"])
            self._page_scrub_fn = engine.make_page_scrub(ops["zero_pages"])
            # integrity programs: page readback feeds publish-time checksum
            # stamps + the background scrub; page flip is the scripted
            # silent-corruption fault (chaos only)
            self._page_read_fn = engine.make_page_read(ops["read_pages"])
            self._page_flip_fn = engine.make_page_flip(ops["flip_pages"])
        else:
            self._refill_fn = engine.make_session_refill(
                cache_zero=steps.zero_cache_slots)
            # checkpoint/restore + fault programs over the model cache
            # layout (stacked layer axes — the steps.py helpers know which
            # axis is batch per leaf; the engine defaults only cover flat
            # caches)
            self._snapshot_fn = engine.make_slot_snapshot(
                cache_take=steps.take_cache_slot)
            self._restore_fn = engine.make_slot_restore(
                cache_put=steps.put_cache_slot)
            self._nan_scan_fn = engine.make_nan_scan(
                cache_nan=steps.nan_cache_slots)
            self._corrupt_fn = engine.make_slot_corrupt(
                cache_fill=steps.fill_cache_slots)
            self._page_copy_fn = None
            self._page_scrub_fn = None
            self._page_read_fn = None
            self._page_flip_fn = None
        self._last_session = None

    def init_params(self, seed: int | None = None):
        cfg = self.cluster.arch
        seed = self.spec.seed if seed is None else seed
        return steps.init_params(cfg, jax.random.PRNGKey(seed),
                                 max_seq=self.spec.max_seq)

    def _make_state(self):
        cfg, spec = self.cluster.arch, self.spec
        clen = steps.decode_cache_len(cfg, spec.max_seq)
        if spec.paged:
            cache = steps.init_paged_cache(cfg, spec.slots, clen,
                                           n_pages=self._n_pages,
                                           page_size=spec.page_size)
            return engine.init_session_state(
                cache, spec.slots, spec.max_prompt,
                pages_per_slot=self._pages_per_slot)
        cache = steps.init_cache(cfg, spec.slots, clen)
        return engine.init_session_state(cache, spec.slots, spec.max_prompt)

    def open(self, params=None, faults=None, durable_dir=None,
             resume: bool = False, crash_hook=None,
             snapshot_every=None, journal_fsync=None,
             device=None, journal_group=None):
        """A fresh `ServeSession` over this compiled cell (own slot pool,
        queue, scheduler, and stall clock). `faults` arms a
        `runtime.FaultPlan` against the session (chaos testing).

        `durable_dir` turns on the durability layer: a crash-consistent
        request journal (fsync'd once per poll) plus, when the spec sets
        ``snapshot_every``, periodic bit-exact session snapshots.
        `resume=True` recovers from an existing `durable_dir` after a
        crash (see `restore()`). `snapshot_every` / `journal_fsync`
        override the spec's values per session — they are host-side
        knobs, so no recompile (`None` keeps the spec's choice).

        `device` pins the session's params and pool state to one device
        (the sharded session places each group on its own mesh slice);
        `journal_group` tags every journal event with the owning group id
        (see `runtime.Journal`). Both default to the single-session
        behaviour: default device, untagged journal."""
        from repro.runtime import ServeSession

        spec = self.spec
        if params is None:
            params = self.init_params()
        make_state = self._make_state
        if device is not None:
            params = jax.device_put(params, device)
            make_state = lambda: jax.device_put(self._make_state(), device)
        kv = None
        if spec.paged:
            from repro.runtime.kvpool import PagedKV
            kv = PagedKV(self._n_pages, spec.page_size, spec.slots,
                         self._pages_per_slot,
                         prefix_cache=spec.prefix_cache)
        sess = ServeSession(self._chunk_fn, self._refill_fn, params,
                            make_state(),
                            n_slots=spec.slots, chunk=spec.chunk,
                            max_prompt=spec.max_prompt, max_seq=spec.max_seq,
                            eos_id=spec.eos_id, max_queue=spec.max_queue,
                            admission=spec.admission,
                            shed_watermark=spec.shed_watermark,
                            aging_rounds=spec.aging_rounds,
                            preempt=spec.preempt and not spec.paged,
                            snapshot_fn=self._snapshot_fn,
                            restore_fn=self._restore_fn,
                            nan_scan_fn=self._nan_scan_fn,
                            corrupt_fn=self._corrupt_fn,
                            state_factory=make_state,
                            watchdog_s=spec.watchdog_s,
                            max_retries=spec.max_retries,
                            retry_backoff_s=spec.retry_backoff_s,
                            nan_check=spec.nan_check,
                            kv=kv,
                            page_copy_fn=self._page_copy_fn,
                            page_scrub_fn=self._page_scrub_fn,
                            faults=faults,
                            durable_dir=durable_dir,
                            snapshot_every=(spec.snapshot_every
                                            if snapshot_every is None
                                            else snapshot_every),
                            journal_fsync=(spec.journal_fsync
                                           if journal_fsync is None
                                           else journal_fsync),
                            page_read_fn=self._page_read_fn,
                            page_flip_fn=self._page_flip_fn,
                            scrub_pages=spec.scrub_pages,
                            crash_hook=crash_hook,
                            resume=resume,
                            journal_group=journal_group)
        self._last_session = sess
        return sess

    def restore(self, durable_dir, params=None, faults=None):
        """Resume a crashed session from its `durable_dir`: load the
        latest snapshot (if any), replay the journal tail, and hand back
        a live session. Requests that finished before the crash surface
        on `sess.recovered`; in-flight requests resume (bit-identically
        from the snapshot, or by re-prefill with the journal-committed
        prefix suppressed) — delivery stays exactly-once."""
        return self.open(params=params, faults=faults,
                         durable_dir=durable_dir, resume=True)

    def run(self, params=None, prompt=None, max_new: int | None = None) -> dict:
        """One-shot: submit one batch (one request per slot), drain, return
        the legacy `{"tokens": (B, 1+max_new), "stats": ...}` shape.

        Without `prompt`, slot i's request is the single start token 0 —
        exactly the `ServeProgram` path, bit for bit (tokens, EOS
        masking/early-stop, and `emitted_per_slot`). With `prompt` (B, P),
        the prompt is prefilled per slot and the first sampled token lands
        in column 0, as `ServeProgram.run(prompt=...)` does.
        """
        spec = self.spec
        max_new = spec.max_new if max_new is None else max_new
        sess = self.open(params=params)
        B = spec.slots
        if prompt is None:
            rows = [np.zeros(1, np.int32)] * B
            per_req = max_new
        else:
            prompt = np.asarray(prompt)
            rows = [prompt[i] for i in range(B)]
            # +1: the last prefill step's output (legacy column 0) counts
            # toward the session budget but not toward legacy emitted
            per_req = max_new + 1
        handles = [sess.submit(r, per_req) for r in rows]
        sess_stats = sess.drain()
        toks = [h.result() for h in handles]
        if prompt is None:
            toks = [np.concatenate([[0], t]).astype(np.int32) for t in toks]
        w = max(t.size for t in toks)
        pad = spec.eos_id if spec.eos_id is not None else 0
        out = np.full((B, w), pad, np.int32)
        for i, t in enumerate(toks):
            out[i, :t.size] = t
        stats = self._legacy_stats(sess, handles,
                                   gen_offset=0 if prompt is None else 1)
        stats["session"] = sess_stats
        result = {"tokens": out, "stats": stats}
        self._last_run = {"stats": {k: v for k, v in stats.items()
                                    if k != "session"},
                          "session": sess_stats,
                          "tokens_shape": tuple(out.shape)}
        return result

    def _legacy_stats(self, sess, handles, gen_offset: int) -> dict:
        """`ServeLoop.stats()`-shaped dict from a drained one-shot session
        (per-token percentiles over post-warmup chunks, stall ledger,
        emitted_per_slot in legacy generation-step counting)."""
        from repro.runtime.serve_loop import chunked_latency_stats

        st = chunked_latency_stats(sess.chunk_latencies)
        st["chunk"] = sess.chunk
        st["stall"] = sess.clock.report()
        st["emitted_per_slot"] = [int(h.tokens.size - gen_offset)
                                  for h in handles]
        if self.spec.eos_id is not None:
            st["finished_slots"] = sum(h.hit_eos for h in handles)
        return st

    def report(self) -> dict:
        out = super().report()
        if self._last_session is not None:
            out["session"] = self._last_session.stats()
        return out


SHARD_MANIFEST = "manifest.json"
SHARD_MANIFEST_KIND = "repro-sharded-serve"


class CompiledShardedServeSession(CompiledServeSession):
    """N serving groups over one compiled session cell.

    The chunk/refill/fault programs are compiled once (inherited from
    `CompiledServeSession`); `open()` instantiates them `spec.groups`
    times — per-group params/state pinned to that group's device from
    `cells.group_devices` — and wires the cells behind a
    `runtime.ShardedServeSession` with a locality-aware `MeshScheduler`.

    Durable layout: the root directory holds a ``manifest.json``
    (`{"kind": "repro-sharded-serve", "version": 1, "groups": G}`) and
    one complete per-session durable dir per group (``group00/`` ...),
    each journal tagged with its group id. With ``groups=1`` the root
    directory *is* the group's durable dir — a plain
    `ServeSessionProgram` restore reads it unchanged, and a sharded
    restore accepts a plain session's manifest-less directory.
    """

    kind = "serve_session_sharded"

    def __init__(self, cluster, spec: ShardedServeSessionProgram, policy):
        if spec.groups < 1:
            raise ValueError(f"groups must be >= 1, got {spec.groups}")
        super().__init__(cluster, spec, policy)

    def _group_dirs(self, durable_dir, resume: bool) -> list:
        """Per-group durable dirs under the root, manifest-checked."""
        import json
        from pathlib import Path

        spec = self.spec
        root = Path(durable_dir)
        root.mkdir(parents=True, exist_ok=True)
        mpath = root / SHARD_MANIFEST
        if resume and mpath.exists():
            m = json.loads(mpath.read_text(encoding="utf-8"))
            if (m.get("kind") != SHARD_MANIFEST_KIND
                    or m.get("groups") != spec.groups):
                raise ValueError(
                    f"durable dir {root} was written by "
                    f"{m.get('kind')!r} with groups={m.get('groups')}; "
                    f"this program has groups={spec.groups}")
        elif resume and spec.groups != 1:
            # manifest-less dir: a plain single session wrote it; only a
            # 1-group sharded session can adopt it
            raise ValueError(
                f"durable dir {root} has no {SHARD_MANIFEST} — it holds a "
                f"single-session journal; restore it with groups=1 (or "
                f"ServeSessionProgram), not groups={spec.groups}")
        else:
            mpath.write_text(json.dumps(
                {"kind": SHARD_MANIFEST_KIND, "version": 1,
                 "groups": spec.groups}) + "\n", encoding="utf-8")
        if spec.groups == 1:
            return [root]
        return [root / f"group{g:02d}" for g in range(spec.groups)]

    def open(self, params=None, faults=None, durable_dir=None,
             resume: bool = False, crash_hook=None,
             snapshot_every=None, journal_fsync=None):
        """A live `runtime.ShardedServeSession`: `spec.groups` session
        cells, each on its own device slice, behind the single-session
        API. `faults` arms group 0 when given one `FaultPlan`, or each
        group when given a sequence (None entries skip a group)."""
        from repro.runtime.groups import (GroupPlan, GroupRuntime,
                                          MeshScheduler,
                                          ShardedServeSession)

        spec = self.spec
        G = spec.groups
        if params is None:
            params = self.init_params()
        devices = cells.group_devices(self.cluster.mesh, G)
        # single distinct device (CPU smoke, groups=1): skip device_put so
        # the cell is bit-identical to the unsharded session's
        distinct = len({id(d) for d in devices}) > 1
        plans = (list(faults) if isinstance(faults, (list, tuple))
                 else [faults] + [None] * (G - 1))
        if len(plans) != G:
            raise ValueError(f"faults: expected {G} plans, got {len(plans)}")
        dirs = (self._group_dirs(durable_dir, resume)
                if durable_dir is not None else [None] * G)
        groups = []
        for g in range(G):
            sess = super().open(
                params=params, faults=plans[g],
                durable_dir=(str(dirs[g]) if dirs[g] is not None else None),
                resume=resume, crash_hook=crash_hook,
                snapshot_every=snapshot_every,
                journal_fsync=journal_fsync,
                device=devices[g] if distinct else None,
                journal_group=g)
            groups.append(GroupRuntime(gid=g, session=sess,
                                       device=devices[g]))
        mesh = MeshScheduler(
            G, page_size=spec.page_size if spec.paged else 16)
        plan = GroupPlan(n_groups=G, devices=devices)
        sharded = ShardedServeSession(groups, mesh=mesh, plan=plan)
        self._last_session = sharded
        return sharded

    def run(self, params=None, prompt=None, max_new=None) -> dict:
        raise NotImplementedError(
            "the one-shot legacy path is not defined for sharded "
            "sessions; use open() + submit/drain")


class CompiledDryRun(Program):
    kind = "dryrun"

    def run(self) -> dict:
        """Lower + compile the cell, extract memory/cost/collective analysis
        (the body of the old launch/dryrun.run_cell)."""
        from repro.core import hlo_cost, locality
        from repro.core import mesh as hw

        cluster, spec = self.cluster, self.spec
        cfg = cluster._require_arch("DryRunProgram")
        shape = SHAPES[spec.shape]
        ok, reason = cell_supported(cfg, shape)
        if not ok:
            record = {"status": "skipped", "reason": reason}
            self._last_run = record
            return record

        mesh, rules = cluster.mesh, cluster.rules
        with use_policy(self.policy):
            fn, args, in_sh, out_sh, donate = cells.build_cell(
                cfg, shape, mesh, rules, fsdp_gather=spec.fsdp_gather,
                policy=self.policy, decode_chunk=spec.decode_chunk,
                session=spec.session, paged=spec.paged,
                page_size=spec.page_size)
            t0 = time.time()
            with compat.set_mesh(mesh):
                lowered = jax.jit(fn, in_shardings=in_sh,
                                  out_shardings=out_sh,
                                  donate_argnums=donate).lower(*args)
                t_lower = time.time() - t0
                t0 = time.time()
                compiled = lowered.compile()
                t_compile = time.time() - t0

        mem = locality.extract_memory(compiled)
        ca = locality.extract_costs(compiled)
        print("memory_analysis:", compiled.memory_analysis())
        print("cost_analysis (built-in, loop-unaware):", ca)

        t0 = time.time()
        hlo_text = compiled.as_text()
        costs = hlo_cost.analyze(hlo_text)
        t_analyze = time.time() - t0

        n_chips = mesh.size
        mf = cells.model_flops(cfg, shape)
        flops_dev = costs["flops"]
        bytes_dev = costs["bytes"]
        coll_dev = costs["collective_operand_bytes"]
        wire_dev = costs["collective_wire_bytes"]
        record = {
            "status": "ok",
            "n_chips": n_chips,
            "seconds": {"lower": t_lower, "compile": t_compile,
                        "analyze": t_analyze},
            "memory_analysis": mem,
            "peak_device_bytes": locality.peak_device_bytes(mem),
            "cost_analysis_builtin": ca,
            "hlo": {
                "flops_per_device": flops_dev,
                "bytes_per_device": bytes_dev,
                "transcendentals_per_device": costs["transcendentals"],
                "collective_operand_bytes_per_device": coll_dev,
                "collective_wire_bytes_per_device": wire_dev,
                "collectives": costs["collectives"],
            },
            "model": mf,
            "roofline": {
                # terms in seconds, per the task's definitions
                "compute_s": flops_dev * n_chips / (
                    n_chips * hw.PEAK_FLOPS_BF16),
                "memory_s": bytes_dev * n_chips / (n_chips * hw.HBM_BW),
                "collective_s": coll_dev * n_chips / (
                    n_chips * hw.ICI_BW_PER_LINK),
                "collective_wire3_s": wire_dev / (3 * hw.ICI_BW_PER_LINK),
                "useful_flops_ratio": mf["model_flops"] / max(
                    flops_dev * n_chips, 1.0),
            },
        }
        dom = max(("compute_s", "memory_s", "collective_s"),
                  key=lambda k: record["roofline"][k])
        record["roofline"]["dominant"] = dom
        self._last_run = record
        return record


class CompiledBench(Program):
    kind = "bench"

    def run(self, modules, echo=print) -> dict:
        """Run the offered bench `modules` ([(name, module)]) under this
        program's policy. Each section's CSV rows are echoed as they land
        and collected (with per-row median over `repeat` runs); the active
        policy — knobs plus tune-hit counters — rides in the result."""
        import sys
        import traceback

        spec = self.spec
        wanted = set(spec.sections) if spec.sections else None
        results: dict = {"smoke": spec.smoke, "sections": {}}
        failed = []
        with use_policy(self.policy) as pol:
            for name, mod in modules:
                if wanted is not None and name not in wanted:
                    continue
                t0 = time.perf_counter()
                try:
                    lines = _median_lines(
                        [_call_main(mod, spec.smoke)
                         for _ in range(spec.repeat)])
                    for line in lines:
                        echo(line)
                    results["sections"][name] = {
                        "status": "ok",
                        "seconds": time.perf_counter() - t0,
                        "rows": [_parse_row(line) for line in lines],
                    }
                except Exception as e:
                    failed.append(name)
                    traceback.print_exc()
                    results["sections"][name] = {
                        "status": "error",
                        "seconds": time.perf_counter() - t0,
                        "error": f"{type(e).__name__}: {e}",
                    }
                print(f"# {name} done in {time.perf_counter() - t0:.1f}s",
                      file=sys.stderr)
        results["policy"] = pol.describe()
        results["failed"] = failed
        self._last_run = {"failed": failed,
                          "sections": sorted(results["sections"])}
        return results


def _call_main(mod, smoke: bool) -> list[str]:
    import inspect
    if "smoke" in inspect.signature(mod.main).parameters:
        return mod.main(smoke=smoke)
    return mod.main()


def _parse_row(line: str) -> dict:
    name, us, derived = line.split(",", 2)
    try:
        us_val = float(us)
    except ValueError:
        us_val = None
    return {"name": name, "us_per_call": us_val, "derived": derived}


def _median_lines(runs: list[list[str]]) -> list[str]:
    """Per-row median us_per_call across repeats (first run's derived)."""
    import statistics
    if len(runs) == 1:
        return runs[0]
    by_name: dict[str, list[float]] = {}
    for run in runs:
        for line in run:
            r = _parse_row(line)
            if r["us_per_call"] is not None:
                by_name.setdefault(r["name"], []).append(r["us_per_call"])
    out = []
    for line in runs[0]:
        r = _parse_row(line)
        if r["us_per_call"] is None or r["name"] not in by_name:
            out.append(line)
            continue
        med = statistics.median(by_name[r["name"]])
        out.append(f"{r['name']},{med:.1f},{r['derived']}")
    return out
