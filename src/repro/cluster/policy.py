"""KernelPolicy — one object steering every kernel-dispatch decision.

MemPool programs one substrate through several runtimes; which kernel body
actually runs (hand-tuned blocking, fused producer-consumer kernel, jnp
reference, interpreter) used to be steered by two side channels: a
fused-route bool threaded through `ArchConfig` into the model files, and a
backend probe buried in `kernels/ops.py`. Both now live here:

  KernelPolicy(mode="tuned" | "fused" | "reference" | "interpret",
               overrides={op_name: mode_or_blocks})

* ``tuned``     — Pallas kernels with autotuned (registry-cached) blockings;
                  autotune-on-miss. The default.
* ``fused``     — same, plus the model stack takes the fused
                  producer-consumer route (kernels/fused.py) wherever a
                  block's norm kind allows it.
* ``reference`` — the pure-jnp oracles from kernels/ref.py.
* ``interpret`` — Pallas bodies forced through the interpreter even on TPU
                  (off-TPU backends always interpret, whatever the mode).

``overrides`` refines single ops: a mode string re-routes that op only
(``{"matmul": "reference"}``), a block dict pins its blocking for
``tuned_call`` (``{"matmul": {"bm": 64, "bn": 64, "bk": 64}}``).

The active policy is an explicitly scoped stack: ``with use_policy(p): ...``
(or ``with cluster.policy(...)``). Dispatch sites read ``current_policy()``
at trace time, so a policy is baked into whatever jit trace it was active
under — exactly like the config bool it replaces, but in one place. With no
scope active, the default policy applies; ``REPRO_INTERPRET=1`` in the
environment turns the default into ``interpret`` mode (the old env path),
and ``REPRO_KERNEL_POLICY`` picks any default mode outright.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Any, Iterator, Mapping

MODES = ("tuned", "fused", "reference", "interpret")


TUNINGS = ("auto", "timed", "modeled", "frozen")


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Kernel-selection policy: a global mode plus per-op overrides.

    ``tuning`` steers how autotune-on-miss picks a blocking: ``"timed"``
    races the top modeled candidates plus the default on device and keeps
    the measured winner (writing it through to the active TuneDB);
    ``"modeled"`` keeps the legacy score-only pick; ``"frozen"`` is the CI
    determinism mode — score-only pick, and the TuneDB is never written.
    ``"auto"`` (the default) defers to ``REPRO_TUNE_MODE`` (itself
    defaulting to ``timed``) — see ``kernels.tunedb.tune_mode``.

    ``stats`` is a mutable per-instance counter dict (ref_calls,
    pallas_calls, tune_hits, tune_misses, tune_races, block_overrides)
    filled in by the dispatch sites — excluded from equality so two
    policies with the same knobs compare equal regardless of traffic.
    """

    mode: str = "tuned"
    overrides: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    tuning: str = "auto"
    stats: dict = dataclasses.field(default_factory=dict, compare=False,
                                    repr=False)

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown policy mode {self.mode!r}; "
                             f"expected one of {MODES}")
        if self.tuning not in TUNINGS:
            raise ValueError(f"unknown tuning {self.tuning!r}; "
                             f"expected one of {TUNINGS}")
        for op, v in self.overrides.items():
            if isinstance(v, str):
                if v not in MODES:
                    raise ValueError(f"override for {op!r}: unknown mode "
                                     f"{v!r}; expected one of {MODES}")
            elif not isinstance(v, Mapping):
                raise TypeError(f"override for {op!r} must be a mode string "
                                f"or a block dict, got {type(v).__name__}")

    # -- per-op resolution ----------------------------------------------------
    def mode_for(self, op: str) -> str:
        """The mode governing `op`: its string override, else the global."""
        o = self.overrides.get(op)
        return o if isinstance(o, str) else self.mode

    def blocks_for(self, op: str) -> dict | None:
        """Pinned blocking for `op` (a dict override), or None to autotune."""
        o = self.overrides.get(op)
        return dict(o) if isinstance(o, Mapping) else None

    def interpret_for(self, op: str) -> bool:
        """Should `op`'s Pallas body run interpreted? Forced by the
        ``interpret`` mode; always true off-TPU (numerics-identical, which is
        what the allclose tests against ref.py verify)."""
        if self.mode_for(op) == "interpret":
            return True
        import jax
        return jax.default_backend() != "tpu"

    @property
    def fused(self) -> bool:
        """Does the model stack take the fused producer-consumer route?"""
        return self.mode == "fused"

    # -- dispatch (the tuned_call body) ---------------------------------------
    def call(self, name: str, *operands, **kwargs):
        """Run kernel `name` under this policy: reference short-circuit,
        pinned blocks, or autotuned (registry-cached, tune-on-miss) blocks.

        This is the single place fused/tuned/reference selection and
        autotune-on-miss live; ``ops.tuned_call`` delegates here.
        """
        from repro.configs import registry
        from repro.kernels import ops, pipeline

        desc = ops.OPS[name]
        if self.mode_for(name) == "reference":
            self.bump("ref_calls")
            return desc.reference(*operands, **kwargs)
        blocks = self.blocks_for(name)
        if blocks is None:
            shapes = desc.shapes(*operands)
            dtype_bytes = operands[desc.streamed_operand].dtype.itemsize
            key = pipeline.shape_key(shapes, dtype_bytes)
            rec = registry.get_kernel_tune(name, key)
            if rec is None:
                # miss -> autotune: under "timed" tuning this compiles and
                # races the top modeled candidates on synthetic operands
                # (the real ones may be tracers) and keeps the measured
                # winner, bumping tune_races and writing the TuneDB
                self.bump("tune_misses")
                tune = pipeline.autotune(
                    name, shapes, dtype_bytes=dtype_bytes,
                    mode=None if self.tuning == "auto" else self.tuning)
                blocks, route = dict(tune.blocks), tune.route
            else:
                self.bump("tune_hits")
                blocks, route = dict(rec.blocks), rec.route
            if route == "unfused" and desc.composition is not None:
                # the race demoted this fusion on these shapes — run the
                # unfused composition of primitive kernels instead (blocks
                # stay recorded in case the composition route is retired)
                self.bump("unfused_routes")
                return desc.composition(*operands, **kwargs)
        else:
            self.bump("block_overrides")
        return desc.wrapper(*operands, **blocks, **kwargs)

    # -- bookkeeping ----------------------------------------------------------
    def bump(self, key: str) -> None:
        self.stats[key] = self.stats.get(key, 0) + 1

    def describe(self) -> dict:
        """JSON-able snapshot: knobs + traffic counters (for bench records,
        program reports, and compile-cache fingerprints)."""
        return {
            "mode": self.mode,
            "overrides": {k: (v if isinstance(v, str) else dict(v))
                          for k, v in sorted(self.overrides.items())},
            "tuning": self.tuning,
            "stats": dict(self.stats),
        }

    def fingerprint(self) -> str:
        """Stable key component (knobs only — stats excluded)."""
        d = self.describe()
        d.pop("stats")
        return repr(sorted((k, repr(v)) for k, v in d.items()))


# ----------------------------------------------------------------------------
# The active-policy stack
# ----------------------------------------------------------------------------

_STACK: list[KernelPolicy] = []


def _env_truthy(name: str) -> bool:
    return os.environ.get(name, "").strip().lower() not in ("", "0", "false")


def default_policy() -> KernelPolicy:
    """The ambient policy when no scope is active. ``REPRO_KERNEL_POLICY``
    selects the mode; ``REPRO_INTERPRET=1`` (the legacy env path) maps to
    ``interpret``."""
    mode = os.environ.get("REPRO_KERNEL_POLICY", "").strip()
    if not mode:
        mode = "interpret" if _env_truthy("REPRO_INTERPRET") else "tuned"
    return KernelPolicy(mode=mode)


def current_policy() -> KernelPolicy:
    return _STACK[-1] if _STACK else default_policy()


def as_policy(p: "KernelPolicy | str | None") -> KernelPolicy:
    """Coerce a policy spec: a KernelPolicy, a bare mode string, or None
    (-> the environment-derived default)."""
    if isinstance(p, KernelPolicy):
        return p
    if p is None:
        return default_policy()
    if isinstance(p, str):
        return KernelPolicy(mode=p)
    raise TypeError(f"cannot make a KernelPolicy from {type(p).__name__}")


@contextlib.contextmanager
def use_policy(p: "KernelPolicy | str | None") -> Iterator[KernelPolicy]:
    """Scope `p` as the active policy (nests; innermost wins)."""
    pol = as_policy(p)
    _STACK.append(pol)
    try:
        yield pol
    finally:
        _STACK.pop()


@contextlib.contextmanager
def scoped(p: "KernelPolicy | str | None") -> Iterator[KernelPolicy]:
    """Like use_policy, but None means *inherit the ambient policy* rather
    than reset to the default — the step-factory helper."""
    if p is None:
        yield current_policy()
    else:
        with use_policy(p) as pol:
            yield pol
