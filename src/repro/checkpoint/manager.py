"""Checkpointing — fault tolerance for long runs.

Design points (scaled for 1000+ nodes, implemented single-host here):
- *async*: snapshot to host memory on the train thread, serialize on a
  background thread; training continues immediately.
- *atomic*: write to step dir + manifest-last rename; a crash mid-write can
  never corrupt the latest checkpoint.
- *logical layout*: leaves are saved by tree path with mesh-independent
  content, so a checkpoint taken on a (16,16) mesh restores onto (2,16,16)
  or a CI-sized mesh (elastic re-sharding happens at device_put on load).
  On a real fleet each host writes only its owned shards; the manifest
  carries the global tree structure either way.
- *auto-resume*: `latest_step()` + `restore()` bring back params/opt/step;
  the data pipeline is stateless-resumable (see data/pipeline.py), so no
  loader state is needed.
"""

from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path

import jax
import ml_dtypes
import numpy as np

_SEP = "/"

# dtypes numpy's npz cannot round-trip natively: stored as unsigned views,
# true dtype recorded in the manifest.
_VIEW_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _encode(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = str(arr.dtype)
    if name in _VIEW_DTYPES:
        return arr.view(_VIEW_DTYPES[name][1]), name
    return arr, name


def _decode(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_DTYPES:
        return arr.view(_VIEW_DTYPES[dtype_name][0])
    return arr


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_key_str(k) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3,
                 async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # ------------------------------------------------------------- save
    def save(self, step: int, state, *, block: bool = False):
        """Snapshot is taken synchronously; serialization is async. A
        failed async write from the *previous* save surfaces here (and on
        `wait()`) — a dropped checkpoint is never silent."""
        self.wait()
        snapshot = _flatten(jax.device_get(state))

        def _write():
            try:
                self._write_step(step, snapshot)
            except Exception as e:   # surfaced on wait() / next save()
                self._error = e

        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def _write_step(self, step: int, snapshot: dict[str, np.ndarray],
                    extra: dict[str, str] | None = None):
        tmp = self.dir / f".tmp-{step}"
        final = self.dir / f"step-{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        encoded = {k: _encode(v) for k, v in snapshot.items()}
        np.savez(tmp / "leaves.npz", **{k: v for k, (v, _) in encoded.items()})
        manifest = {
            "step": step,
            "time": time.time(),
            "leaves": {k: {"shape": list(v.shape), "dtype": dt}
                       for k, (v, dt) in encoded.items()},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
        for name, payload in (extra or {}).items():
            (tmp / name).write_text(payload)    # inside tmp: atomic too
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                       # atomic publish
        (self.dir / "LATEST.tmp").write_text(str(step))
        (self.dir / "LATEST.tmp").rename(self.dir / "LATEST")
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step-{s:09d}", ignore_errors=True)

    def wait(self):
        """Block until the in-flight async write lands. Raises the writer
        thread's exception (once) if the write failed — callers relying on
        `wait()` before a restart must not believe a checkpoint exists
        when it never hit disk."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # --------------------------------------------------- serving session
    def save_session(self, step: int, state, meta: dict):
        """One bit-exact serving-session snapshot: the device state
        pytree plus the host-side scheduler/pool bookkeeping
        (`ServeSession` builds `meta`), as a *single* `.ckpt` file — a
        json manifest line (meta + per-leaf key/dtype/shape, view-dtype
        discipline as in `save`) followed by the raw leaf bytes in
        manifest order. Not npz: the session state is small and the
        write sits on the decode critical path, where `np.savez`'s
        zipfile framing (per-member headers + CRC32) costs ~10x the
        raw-bytes concat. Everything is staged in memory and hits the
        filesystem as one write + one atomic rename (a crash mid-write
        leaves the previous snapshot intact; the journal covers the
        gap)."""
        self.wait()
        encoded = {k: _encode(np.ascontiguousarray(v))
                   for k, v in _flatten(jax.device_get(state)).items()}
        manifest = {"step": step, "meta": meta,
                    "leaves": [{"key": k, "dtype": dt,
                                "view": str(v.dtype),
                                "shape": list(v.shape)}
                               for k, (v, dt) in encoded.items()]}
        blob = b"".join([json.dumps(manifest).encode(), b"\n",
                         *(v.tobytes() for v, _ in encoded.values())])
        tmp = self.dir / f".tmp-session-{step}.ckpt"
        tmp.write_bytes(blob)
        tmp.rename(self.dir / f"session-{step:09d}.ckpt")
        for old in self.session_steps()[: -self.keep]:
            (self.dir / f"session-{old:09d}.ckpt").unlink(missing_ok=True)

    def session_steps(self) -> list[int]:
        return sorted(int(p.stem.split("-")[1])
                      for p in self.dir.glob("session-*.ckpt"))

    def latest_session_step(self) -> int | None:
        steps = self.session_steps()
        return steps[-1] if steps else None

    def restore_session(self, step: int, like) -> tuple[object, dict]:
        """Inverse of `save_session`: (device-state pytree shaped like
        `like`, the session meta dict)."""
        raw = (self.dir / f"session-{step:09d}.ckpt").read_bytes()
        nl = raw.index(b"\n")                   # manifest json has no \n
        manifest = json.loads(raw[:nl])
        leaves, off = {}, nl + 1
        for spec in manifest["leaves"]:
            arr = np.frombuffer(
                raw, dtype=np.dtype(spec["view"]), offset=off,
                count=int(np.prod(spec["shape"], dtype=np.int64)),
            ).reshape(spec["shape"])
            leaves[spec["key"]] = _decode(arr, spec["dtype"])
            off += arr.nbytes
        flat_like, _ = jax.tree_util.tree_flatten_with_path(like)
        out = []
        for path, _leaf in flat_like:
            key = _SEP.join(_key_str(k) for k in path)
            if key not in leaves:
                raise KeyError(f"session snapshot missing leaf {key}")
            out.append(leaves[key])
        state = jax.tree_util.tree_unflatten(jax.tree.structure(like), out)
        return state, manifest["meta"]

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        return sorted(int(p.name.split("-")[1])
                      for p in self.dir.glob("step-*") if p.is_dir())

    def latest_step(self) -> int | None:
        marker = self.dir / "LATEST"
        if marker.exists():
            s = int(marker.read_text())
            if (self.dir / f"step-{s:09d}" / "manifest.json").exists():
                return s
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like, shardings=None):
        """Restore into the structure of `like`; reshard onto `shardings`
        (any mesh — elastic restore) or keep host arrays if None."""
        d = self.dir / f"step-{step:09d}"
        data = np.load(d / "leaves.npz")
        manifest = json.loads((d / "manifest.json").read_text())
        flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        sh_leaves = (jax.tree.leaves(shardings) if shardings is not None
                     else [None] * len(flat_like))
        out = []
        for (path, leaf), sh in zip(flat_like, sh_leaves):
            key = _SEP.join(_key_str(k) for k in path)
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = _decode(data[key], manifest["leaves"][key]["dtype"])
            if sh is not None:
                arr = jax.device_put(arr, sh)
            out.append(arr)
        return jax.tree_util.tree_unflatten(
            jax.tree.structure(like), out)
