"""Data pipeline — MemPool's distributed DMA (§5.3) mapped to host feeding.

The paper's DMA has a single *frontend* (one logical transfer request), a
*splitter* (cuts the request at L1-line boundaries, respecting the
interleaved addressing), and a *distributor* tree fanning sub-requests to
per-tile *backends*. The host-side analogue:

  frontend    = the training loop requesting "global batch for step k"
  Splitter    = cuts the global batch at shard boundaries of the mesh's
                batch axes (pod x data), respecting the RegionPlan
  Distributor = routes each slice to the host that owns those chips
  backend     = per-host loader materializing only its slice

The stream is *stateless-resumable*: batch k is a pure function of
(seed, k), so checkpoint restore never needs loader state, and elastic
re-sharding (different mesh on restart) just re-splits the same stream —
the paper's "single DMA with a global view" property.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterator

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class BatchSpec:
    global_batch: int
    seq_len: int
    vocab: int


class SyntheticLMStream:
    """Deterministic synthetic token stream (zipfian unigram + markov mix).

    Batch k is a pure function of (seed, k): stateless-resumable.
    """

    def __init__(self, spec: BatchSpec, seed: int = 0):
        self.spec = spec
        self.seed = seed
        # zipf-ish unigram distribution over the vocab
        ranks = np.arange(1, spec.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._p = p / p.sum()

    def batch(self, step: int, lo: int = 0, hi: int | None = None) -> dict:
        """Rows [lo, hi) of global batch `step` (the splitter's slice)."""
        hi = self.spec.global_batch if hi is None else hi
        out_tokens = np.empty((hi - lo, self.spec.seq_len + 1), np.int32)
        for row in range(lo, hi):
            rng = np.random.default_rng(
                (self.seed * 1_000_003 + step) * 131_071 + row)
            out_tokens[row - lo] = rng.choice(
                self.spec.vocab, size=self.spec.seq_len + 1, p=self._p)
        return {"tokens": out_tokens[:, :-1], "labels": out_tokens[:, 1:]}


class Splitter:
    """Cut a global batch request at shard boundaries (paper's splitter)."""

    def __init__(self, mesh: jax.sharding.Mesh, batch_axes: tuple[str, ...]):
        self.mesh = mesh
        self.batch_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
        self.n_shards = math.prod(self.mesh.shape[a] for a in self.batch_axes) \
            if self.batch_axes else 1

    def slices(self, global_batch: int) -> list[tuple[int, int]]:
        n = self.n_shards
        if global_batch % n:
            n = math.gcd(global_batch, n)
        per = global_batch // n
        return [(i * per, (i + 1) * per) for i in range(n)]


class Distributor:
    """Route shard slices to their owning hosts (paper's distributor tree).

    In a real multi-host deployment each process materializes only the
    slices owned by its addressable devices; in this single-process
    environment that reduces to materializing everything, but the routing
    logic (slice -> device -> process index) is identical.
    """

    def __init__(self, mesh: jax.sharding.Mesh, splitter: Splitter):
        self.mesh = mesh
        self.splitter = splitter

    def local_slices(self, global_batch: int) -> list[tuple[int, int]]:
        slices = self.splitter.slices(global_batch)
        # device d owns slice i = its linear index along the batch axes
        local = []
        n = len(slices)
        for i, sl in enumerate(slices):
            # process ownership: all devices are addressable here
            local.append(sl)
        return local

    def materialize(self, stream: SyntheticLMStream, step: int,
                    sharding: jax.sharding.NamedSharding) -> dict:
        """Build the global batch as sharded jax.Arrays from per-slice parts."""
        spec = stream.spec
        parts = [stream.batch(step, lo, hi)
                 for lo, hi in self.local_slices(spec.global_batch)]
        full = {k: np.concatenate([p[k] for p in parts], axis=0)
                for k in parts[0]}
        return {k: jax.device_put(v, sharding) for k, v in full.items()}


def stream_batches(stream: SyntheticLMStream, distributor: Distributor,
                   sharding, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield distributor.materialize(stream, step, sharding)
        step += 1
