from .pipeline import Distributor, Splitter, SyntheticLMStream  # noqa: F401
from .prefetch import DoubleBufferedFeed  # noqa: F401
