"""Double-buffered device feed — the paper's Fig. 15 execution scheme.

MemPool's double-buffered kernels overlap the DMA transfer of chunk k+1 with
the compute on chunk k, reaching full utilization in steady-state rounds.
Here: while the device computes step k, a background thread materializes and
device_put()s batch k+1 (JAX transfers are async), so the H2D transfer rides
under the step. The ring-buffer depth is configurable (depth=2 = classic
double buffering).
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterator


class DoubleBufferedFeed:
    def __init__(self, make_batch: Callable[[int], dict], *, depth: int = 2,
                 start_step: int = 0):
        self.make_batch = make_batch
        self.depth = depth
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._timings: list[float] = []
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        step = self._step
        while not self._stop.is_set():
            t0 = time.perf_counter()
            batch = self.make_batch(step)
            self._timings.append(time.perf_counter() - t0)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self) -> tuple[int, dict]:
        return self._q.get()

    @property
    def transfer_seconds(self) -> list[float]:
        return list(self._timings)

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
