"""Double-buffered device feed — the paper's Fig. 15 execution scheme.

MemPool's double-buffered kernels overlap the DMA transfer of chunk k+1 with
the compute on chunk k, reaching full utilization in steady-state rounds.
Here: while the device computes step k, a background thread materializes and
device_put()s batch k+1 (JAX transfers are async), so the H2D transfer rides
under the step. The ring-buffer depth is configurable (depth=2 = classic
double buffering).

Failure mode: an exception in `make_batch` is captured on the producer
thread and re-raised on the consumer side (after any batches queued before
the failure are drained) — a dead producer never leaves the consumer
blocked forever. `close()` is idempotent.

Timings: `transfer_seconds` is producer ("DMA") time per batch;
`consumer_wait_seconds` is how long each `next()` blocked on the queue —
in steady state the transfer hides under compute and the waits collapse to
~0. `stall_report()` folds both into the transfer-vs-compute overlap
ledger (core/overlap.overlap_report).
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterator

_ERR = object()          # producer-failure sentinel (queued after good batches)


class DoubleBufferedFeed:
    def __init__(self, make_batch: Callable[[int], dict], *, depth: int = 2,
                 start_step: int = 0):
        self.make_batch = make_batch
        self.depth = depth
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._timings: list[float] = []
        self._waits: list[float] = []
        self._error: BaseException | None = None
        self._closed = False
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        step = self._step
        while not self._stop.is_set():
            t0 = time.perf_counter()
            try:
                batch = self.make_batch(step)
            except BaseException as e:          # noqa: BLE001 — relayed
                self._error = e
                item: tuple = (_ERR, e)
            else:
                self._timings.append(time.perf_counter() - t0)
                item = (step, batch)
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            if item[0] is _ERR:
                return
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self) -> tuple[int, dict]:
        if self._error is not None and self._q.empty():
            self._raise()                       # sentinel already consumed
        t0 = time.perf_counter()
        item = self._q.get()
        self._waits.append(time.perf_counter() - t0)
        if item[0] is _ERR:
            self._raise()
        return item

    def _raise(self):
        raise RuntimeError(
            "DoubleBufferedFeed producer failed in make_batch"
        ) from self._error

    @property
    def transfer_seconds(self) -> list[float]:
        return list(self._timings)

    @property
    def consumer_wait_seconds(self) -> list[float]:
        return list(self._waits)

    def stall_report(self) -> dict:
        """Transfer-vs-compute overlap: producer busy time vs consumer
        blocked time (see core/overlap.overlap_report). The first wait is
        dropped — it is the pipeline fill, not a steady-state stall."""
        from repro.core.overlap import overlap_report
        return overlap_report(sum(self._timings), sum(self._waits[1:]))

    def close(self):
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        if self._thread.is_alive():
            self._thread.join(timeout=2.0)
