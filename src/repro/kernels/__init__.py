"""Pallas TPU kernels: the paper's Table-1 suite + LM hot-spot kernels.

Each <name>.py holds the pl.pallas_call + BlockSpec implementation;
ops.py the jit'd public wrappers (interpret=True off-TPU); ref.py the
pure-jnp oracles the tests assert against.
"""

from . import ops, ref  # noqa: F401
