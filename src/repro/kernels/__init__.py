"""Pallas TPU kernels: the paper's Table-1 suite + LM hot-spot kernels.

pipeline.py is the shared tile-pipeline layer (TileSpec / KernelPipeline /
autotuner); each <name>.py describes its kernel on that layer and registers
its traffic model + tune space; ops.py holds the jit'd public wrappers
(interpret=True off-TPU) and the tuned dispatch; ref.py the pure-jnp
oracles the tests assert against.
"""

from . import ops, pipeline, ref  # noqa: F401
