"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def matmul(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def axpy(alpha, x, y):
    return (alpha * x.astype(jnp.float32) + y.astype(jnp.float32)).astype(x.dtype)


def dotp(x, y):
    return jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))


def conv2d_3x3(x, w):
    """x: (H, W); w: (3, 3). Zero-padded 'same' convolution (correlation)."""
    xf = x.astype(jnp.float32)
    out = jnp.zeros_like(xf)
    H, W = x.shape
    xp = jnp.pad(xf, 1)
    for dy in range(3):
        for dx in range(3):
            out = out + w[dy, dx].astype(jnp.float32) * \
                jax.lax.dynamic_slice(xp, (dy, dx), (H, W))
    return out.astype(x.dtype)


def dct_matrix(n: int = 8) -> np.ndarray:
    k = np.arange(n)[:, None]
    i = np.arange(n)[None, :]
    c = np.sqrt(2.0 / n) * np.cos((2 * i + 1) * k * np.pi / (2 * n))
    c[0] /= np.sqrt(2.0)
    return c.astype(np.float32)


def dct8x8(blocks):
    """blocks: (N, 8, 8) -> 2-D DCT per block: C X C^T."""
    C = jnp.asarray(dct_matrix(8))
    xf = blocks.astype(jnp.float32)
    return jnp.einsum("ij,njk,lk->nil", C, xf, C).astype(blocks.dtype)


def rmsnorm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) *
            (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def flash_attention(q, k, v, *, causal: bool = True):
    """q,k,v: (B, H, S, hd) (kernel layout; GQA resolved by the wrapper)."""
    b, h, s, hd = q.shape
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * hd ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
