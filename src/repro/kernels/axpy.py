"""axpy — alpha*x + y, the paper's low-intensity BLAS kernel.

Pure streaming: one grid dim over row blocks, VMEM-resident tiles, VPU
elementwise math. Arithmetic intensity 1 MAC / 3 words — the paper uses it
to expose the memory-bound regime (Table 1: 90 OP/cycle vs 336 for conv).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _axpy_kernel(alpha_ref, x_ref, y_ref, o_ref):
    a = alpha_ref[0, 0]
    o_ref[...] = (a * x_ref[...].astype(jnp.float32)
                  + y_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def axpy(alpha, x: jax.Array, y: jax.Array, *, block_rows: int = 512,
         interpret: bool = False) -> jax.Array:
    """x, y: (M, N) with N lane-aligned; alpha scalar."""
    m, n = x.shape
    br = min(block_rows, m)
    assert m % br == 0, (m, br)
    alpha_arr = jnp.asarray(alpha, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _axpy_kernel,
        grid=(m // br,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((br, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(alpha_arr, x, y)
