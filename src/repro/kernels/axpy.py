"""axpy — alpha*x + y, the paper's low-intensity BLAS kernel.

Pure streaming: one grid dim over row blocks, VMEM-resident tiles, VPU
elementwise math. Arithmetic intensity 1 MAC / 3 words — the paper uses it
to expose the memory-bound regime (Table 1: 90 OP/cycle vs 336 for conv).
Built on the shared tile-pipeline layer (pipeline.py); every byte is touched
exactly once, so its p_local is 1.0 and tuning only trades pipeline
overhead against VMEM footprint.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import pipeline as pp


def _axpy_kernel(alpha_ref, x_ref, y_ref, o_ref):
    a = alpha_ref[0, 0]
    o_ref[...] = (a * x_ref[...].astype(jnp.float32)
                  + y_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def build_pipeline(m: int, n: int, dtype, *, block_rows: int | None = None,
                   dtype_bytes: int = 4) -> pp.KernelPipeline:
    br = pp.resolve_block(m, block_rows, default=512)
    return pp.KernelPipeline(
        name="axpy",
        body=_axpy_kernel,
        grid=(pp.GridAxis("rows", m // br, "parallel"),),
        in_tiles=[
            pp.TileSpec((1, 1), lambda i: (0, 0), memory_space="smem"),
            pp.TileSpec((br, n), lambda i: (i, 0)),
            pp.TileSpec((br, n), lambda i: (i, 0)),
        ],
        out_tiles=pp.TileSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), dtype),
        cost=traffic({"m": m, "n": n}, {"block_rows": br}, dtype_bytes),
    )


def axpy(alpha, x: jax.Array, y: jax.Array, *, block_rows: int | None = None,
         interpret: bool = False) -> jax.Array:
    """x, y: (M, N) with N lane-aligned; alpha scalar."""
    m, n = x.shape
    alpha_arr = jnp.asarray(alpha, jnp.float32).reshape(1, 1)
    pipe = build_pipeline(m, n, x.dtype, block_rows=block_rows,
                          dtype_bytes=x.dtype.itemsize)
    return pipe(alpha_arr, x, y, interpret=interpret)


# -- pipeline-layer contract --------------------------------------------------

def traffic(shapes: dict, blocks: dict, dtype_bytes: int = 4) -> pp.Traffic:
    m, n = shapes["m"], shapes["n"]
    br = min(blocks["block_rows"], m)
    moved = 3 * m * n * dtype_bytes              # x + y read, o written, once
    return pp.Traffic(
        flops=2.0 * m * n,
        hbm_bytes=float(moved),
        ideal_bytes=float(moved),
        grid_steps=m // br,
        vmem_bytes=2 * 3 * br * n * dtype_bytes,
    )


def tune_space(shapes: dict):
    for br in pp.block_candidates(shapes["m"], align=8):
        yield {"block_rows": br}


pp.register(pp.KernelDef(
    name="axpy", traffic=traffic, tune_space=tune_space,
    default_blocks=lambda shapes: {"block_rows": pp.snap_block(shapes["m"], 512)}))
