"""Blocked MXU matmul — the paper's `matmul` kernel, TPU-native.

MemPool's matmul gives each core a 4x4 output tile in registers (8 loads per
16 MACs) to maximize compute intensity. The TPU translation: each grid cell
owns a (bm, bn) output tile held in VMEM scratch across the K loop (the
"register tile"), streaming (bm, bk) / (bk, bn) operand tiles from HBM
(the "remote banks") — identical locality story, MXU-aligned block shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(a: jax.Array, b: jax.Array, *, bm: int = 256, bn: int = 256,
           bk: int = 256, interpret: bool = False) -> jax.Array:
    """a: (M, K) @ b: (K, N); M, N, K multiples of the block sizes."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"({m},{n},{k}) not divisible by blocks ({bm},{bn},{bk})"
    n_k = k // bk
    kernel = functools.partial(_matmul_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
