"""Blocked MXU matmul — the paper's `matmul` kernel, TPU-native.

MemPool's matmul gives each core a 4x4 output tile in registers (8 loads per
16 MACs) to maximize compute intensity. The TPU translation on the shared
tile-pipeline layer: each grid cell owns a (bm, bn) output tile held in VMEM
scratch across the K loop (the "register tile"), streaming (bm, bk) /
(bk, bn) operand tiles from HBM (the "remote banks") — identical locality
story, MXU-aligned block shapes. This is the kernel where the autotuner's
locality term matters most: A is re-streamed N/bn times and B M/bm times, so
bigger output tiles raise p_local exactly like MemPool's register blocking.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import pipeline as pp


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def build_pipeline(m: int, n: int, k: int, dtype, *, bm: int | None = None,
                   bn: int | None = None, bk: int | None = None,
                   dtype_bytes: int = 4) -> pp.KernelPipeline:
    bm = pp.resolve_block(m, bm, default=256)
    bn = pp.resolve_block(n, bn, default=256)
    bk = pp.resolve_block(k, bk, default=256)
    n_k = k // bk
    return pp.KernelPipeline(
        name="matmul",
        body=functools.partial(_matmul_kernel, n_k=n_k),
        grid=(pp.GridAxis("m", m // bm, "parallel"),
              pp.GridAxis("n", n // bn, "parallel"),
              pp.GridAxis("k", n_k, "arbitrary")),
        in_tiles=[
            pp.TileSpec((bm, bk), lambda i, j, s: (i, s)),
            pp.TileSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_tiles=pp.TileSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), dtype),
        scratch=[pltpu.VMEM((bm, bn), jnp.float32)],
        cost=traffic({"m": m, "n": n, "k": k},
                     {"bm": bm, "bn": bn, "bk": bk}, dtype_bytes),
    )


def matmul(a: jax.Array, b: jax.Array, *, bm: int | None = None,
           bn: int | None = None, bk: int | None = None,
           interpret: bool = False) -> jax.Array:
    """a: (M, K) @ b: (K, N); M, N, K multiples of the block sizes."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    pipe = build_pipeline(m, n, k, a.dtype, bm=bm, bn=bn, bk=bk,
                          dtype_bytes=a.dtype.itemsize)
    return pipe(a, b, interpret=interpret)


# -- pipeline-layer contract --------------------------------------------------

def traffic(shapes: dict, blocks: dict, dtype_bytes: int = 4) -> pp.Traffic:
    m, n, k = shapes["m"], shapes["n"], shapes["k"]
    bm = min(blocks["bm"], m)
    bn = min(blocks["bn"], n)
    bk = min(blocks["bk"], k)
    # A streamed once per N-block column, B once per M-block row
    streamed = dtype_bytes * (m * k * (n // bn) + k * n * (m // bm) + m * n)
    ideal = dtype_bytes * (m * k + k * n + m * n)
    vmem = (2 * dtype_bytes * (bm * bk + bk * bn)   # double-buffered operands
            + 2 * dtype_bytes * bm * bn             # output tile
            + 4 * bm * bn)                          # f32 accumulator scratch
    return pp.Traffic(
        flops=2.0 * m * n * k,
        hbm_bytes=float(streamed),
        ideal_bytes=float(ideal),
        grid_steps=(m // bm) * (n // bn) * (k // bk),
        vmem_bytes=vmem,
    )


def tune_space(shapes: dict):
    m, n, k = shapes["m"], shapes["n"], shapes["k"]
    for bm in pp.block_candidates(m, align=pp.mxu_align(m), cap=6):
        for bn in pp.block_candidates(n, align=pp.mxu_align(n), cap=6):
            for bk in pp.block_candidates(k, align=pp.mxu_align(k), cap=6):
                yield {"bm": bm, "bn": bn, "bk": bk}


def _defaults(shapes: dict) -> dict:
    return {"bm": pp.snap_block(shapes["m"], 256),
            "bn": pp.snap_block(shapes["n"], 256),
            "bk": pp.snap_block(shapes["k"], 256)}


pp.register(pp.KernelDef(
    name="matmul", traffic=traffic, tune_space=tune_space,
    default_blocks=_defaults))
