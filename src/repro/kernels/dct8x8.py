"""8x8 2-D DCT — the paper's `dct` kernel (JPEG-style block transform).

MemPool cores each own local 8x8 blocks and use the stack for intermediates.
TPU translation: a batch of blocks per grid step, the (8, 8) basis matrix
resident in VMEM, two small matmuls per block batched on the MXU:
Y = C X C^T.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dct_kernel(x_ref, c_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (bn, 8, 8)
    c = c_ref[...].astype(jnp.float32)          # (8, 8)
    t = jax.lax.dot_general(x, c, (((2,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # X C^T
    y = jnp.einsum("ij,njk->nik", c, t)                          # C (X C^T)
    o_ref[...] = y.astype(o_ref.dtype)


def dct8x8(blocks: jax.Array, *, block_n: int = 512,
           interpret: bool = False) -> jax.Array:
    """blocks: (N, 8, 8) -> per-block 2-D DCT."""
    from . import ref
    n = blocks.shape[0]
    bn = min(block_n, n)
    assert n % bn == 0
    c = jnp.asarray(ref.dct_matrix(8))
    return pl.pallas_call(
        _dct_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, 8, 8), lambda i: (i, 0, 0)),
            pl.BlockSpec((8, 8), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 8, 8), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(blocks.shape, blocks.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(blocks, c)
