"""8x8 2-D DCT — the paper's `dct` kernel (JPEG-style block transform).

MemPool cores each own local 8x8 blocks and use the stack for intermediates.
TPU translation on the tile-pipeline layer: a batch of blocks per grid step,
the (8, 8) basis matrix resident in VMEM (constant index_map = never
re-fetched), two small matmuls per block batched on the MXU: Y = C X C^T.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import pipeline as pp


def _dct_kernel(x_ref, c_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (bn, 8, 8)
    c = c_ref[...].astype(jnp.float32)          # (8, 8)
    t = jax.lax.dot_general(x, c, (((2,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # X C^T
    y = jnp.einsum("ij,njk->nik", c, t)                          # C (X C^T)
    o_ref[...] = y.astype(o_ref.dtype)


def build_pipeline(n: int, dtype, *, block_n: int | None = None,
                   dtype_bytes: int = 4) -> pp.KernelPipeline:
    bn = pp.resolve_block(n, block_n, default=512)
    return pp.KernelPipeline(
        name="dct8x8",
        body=_dct_kernel,
        grid=(pp.GridAxis("blocks", n // bn, "parallel"),),
        in_tiles=[
            pp.TileSpec((bn, 8, 8), lambda i: (i, 0, 0)),
            pp.TileSpec((8, 8), lambda i: (0, 0)),
        ],
        out_tiles=pp.TileSpec((bn, 8, 8), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 8, 8), dtype),
        cost=traffic({"n": n}, {"block_n": bn}, dtype_bytes),
    )


def dct8x8(blocks: jax.Array, *, block_n: int | None = None,
           interpret: bool = False) -> jax.Array:
    """blocks: (N, 8, 8) -> per-block 2-D DCT."""
    from . import ref
    n = blocks.shape[0]
    c = jnp.asarray(ref.dct_matrix(8))
    pipe = build_pipeline(n, blocks.dtype, block_n=block_n,
                          dtype_bytes=blocks.dtype.itemsize)
    return pipe(blocks, c, interpret=interpret)


# -- pipeline-layer contract --------------------------------------------------

def traffic(shapes: dict, blocks: dict, dtype_bytes: int = 4) -> pp.Traffic:
    n = shapes["n"]
    bn = min(blocks["block_n"], n)
    moved = 2 * n * 64 * dtype_bytes + 64 * 4
    return pp.Traffic(
        flops=4.0 * n * 8 ** 3,                 # two 8x8x8 matmuls per block
        hbm_bytes=float(moved),
        ideal_bytes=float(moved),
        grid_steps=n // bn,
        vmem_bytes=2 * 2 * bn * 64 * dtype_bytes + 64 * 4,
    )


def tune_space(shapes: dict):
    for bn in pp.block_candidates(shapes["n"], align=8):
        yield {"block_n": bn}


pp.register(pp.KernelDef(
    name="dct8x8", traffic=traffic, tune_space=tune_space,
    default_blocks=lambda shapes: {"block_n": pp.snap_block(shapes["n"], 512)}))
