"""Causal flash attention — the LM hot-spot kernel.

The baseline jnp chunked attention (models/attention.py) crosses HBM ~3x per
score block; this kernel keeps the (bq, bk) block, the online-softmax state
(m, l) and the output accumulator resident in VMEM across the whole kv loop,
so HBM traffic collapses to one read of q/k/v and one write of o — the
"sequential region" of the attention computation in MemPool terms.

Grid: (B, H, nq, nk) with the kv dim "arbitrary" (sequential) so the VMEM
scratch carries across kv steps. GQA is expressed in the k/v index_maps
(h -> h // group), no repeated KV in memory.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, n_k: int, bq: int, bk: int, causal: bool):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                # (bq, hd)
    k = k_ref[0, 0]                                # (bk, hd)
    v = v_ref[0, 0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == n_k - 1)
    def _store():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 512,
                    bk: int = 512, interpret: bool = False):
    """q: (B, H, S, hd); k/v: (B, KV, S, hd) with H % KV == 0."""
    b, h, s, hd = q.shape
    kv = k.shape[1]
    group = h // kv
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0
    n_q, n_k = s // bq, s // bk
    kernel = functools.partial(_fa_kernel, scale=hd ** -0.5, n_k=n_k,
                               bq=bq, bk=bk, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def hbm_traffic_bytes(b, h, kv, s, hd, dtype_bytes: int = 2) -> dict:
    """Structural HBM traffic of this kernel vs the jnp chunked baseline.

    Used by §Perf: the kernel's traffic is q+k+v read once, o written once;
    the baseline crosses HBM ~3x per (bq, bk) score block (write scores,
    read for exp/sum, write p, read for pv) plus q/k/v reads per block pair.
    """
    qkv = (b * h * s * hd + 2 * b * kv * s * hd) * dtype_bytes
    out = b * h * s * hd * dtype_bytes
    kernel = qkv + out
    n_blocks = (s // 512) ** 2
    score_block = b * h * 512 * 512 * 4
    baseline = kernel + 3 * n_blocks * score_block
    return {"kernel_bytes": float(kernel), "baseline_bytes": float(baseline),
            "reduction": baseline / kernel}
