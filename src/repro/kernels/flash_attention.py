"""Causal flash attention — the LM hot-spot kernel.

The baseline jnp chunked attention (models/attention.py) crosses HBM ~3x per
score block; this kernel keeps the (bq, bk) block, the online-softmax state
(m, l) and the output accumulator resident in VMEM across the whole kv loop,
so HBM traffic collapses to one read of q/k/v and one write of o — the
"sequential region" of the attention computation in MemPool terms.

On the tile-pipeline layer: grid (B, H, nq, nk) with the kv axis "arbitrary"
(sequential) so the three VMEM scratch buffers — the register tile — carry
across kv steps. GQA is expressed in the k/v TileSpec index_maps
(h -> h // group), no repeated KV in memory. K/V are re-streamed once per
query block, which is the reuse ratio the autotuner's locality term trades
against the (bq x bk) score tile's VMEM footprint.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import pipeline as pp

NEG = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, n_k: int, bq: int, bk: int, causal: bool):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                # (bq, hd)
    k = k_ref[0, 0]                                # (bk, hd)
    v = v_ref[0, 0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == n_k - 1)
    def _store():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def build_pipeline(b: int, h: int, kv: int, s: int, hd: int, dtype, *,
                   causal: bool = True, bq: int | None = None,
                   bk: int | None = None,
                   dtype_bytes: int = 4) -> pp.KernelPipeline:
    group = h // kv
    bq = pp.resolve_block(s, bq, default=512)
    bk = pp.resolve_block(s, bk, default=512)
    n_q, n_k = s // bq, s // bk
    body = functools.partial(_fa_kernel, scale=hd ** -0.5, n_k=n_k,
                             bq=bq, bk=bk, causal=causal)
    return pp.KernelPipeline(
        name="flash_attention",
        body=body,
        grid=(pp.GridAxis("batch", b, "parallel"),
              pp.GridAxis("heads", h, "parallel"),
              pp.GridAxis("q", n_q, "parallel"),
              pp.GridAxis("kv", n_k, "arbitrary")),
        in_tiles=[
            pp.TileSpec((1, 1, bq, hd),
                        lambda b_, h_, i, j: (b_, h_, i, 0)),
            pp.TileSpec((1, 1, bk, hd),
                        lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
            pp.TileSpec((1, 1, bk, hd),
                        lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
        ],
        out_tiles=pp.TileSpec((1, 1, bq, hd),
                              lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), dtype),
        scratch=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        cost=traffic({"b": b, "h": h, "kv": kv, "s": s, "hd": hd},
                     {"bq": bq, "bk": bk}, dtype_bytes, causal=causal),
    )


def flash_attention(q, k, v, *, causal: bool = True, bq: int | None = None,
                    bk: int | None = None, interpret: bool = False):
    """q: (B, H, S, hd); k/v: (B, KV, S, hd) with H % KV == 0."""
    b, h, s, hd = q.shape
    kv = k.shape[1]
    pipe = build_pipeline(b, h, kv, s, hd, q.dtype, causal=causal,
                          bq=bq, bk=bk, dtype_bytes=q.dtype.itemsize)
    return pipe(q, k, v, interpret=interpret)


# -- pipeline-layer contract --------------------------------------------------

def traffic(shapes: dict, blocks: dict, dtype_bytes: int = 4, *,
            causal: bool = True) -> pp.Traffic:
    b, h, s, hd = shapes["b"], shapes["h"], shapes["s"], shapes["hd"]
    kv = shapes["kv"]
    bq = min(blocks["bq"], s)
    bk = min(blocks["bk"], s)
    n_q = s // bq
    q_bytes = b * h * s * hd * dtype_bytes
    # the pipeline fetches one K and one V block per (head, q-block, kv-block)
    kv_stream = 2 * b * h * n_q * s * hd * dtype_bytes
    kv_ideal = 2 * b * kv * s * hd * dtype_bytes
    out = b * h * s * hd * dtype_bytes
    # causal masking skips ~half the score blocks' useful work
    mac_frac = 0.5 + 0.5 / n_q if causal else 1.0
    flops = 4.0 * b * h * s * s * hd * mac_frac
    vmem = (2 * dtype_bytes * (bq * hd + 2 * bk * hd)    # q + k + v tiles
            + 2 * dtype_bytes * bq * hd                  # out tile
            + 4 * (2 * bq + bq * hd))                    # m, l, acc scratch
    return pp.Traffic(
        flops=flops,
        hbm_bytes=float(q_bytes + kv_stream + out),
        ideal_bytes=float(q_bytes + kv_ideal + out),
        grid_steps=b * h * n_q * (s // bk),
        vmem_bytes=vmem,
        transcendentals=float(b * h * s * (s // bk)),    # exp per row per step
    )


def tune_space(shapes: dict):
    s = shapes["s"]
    for bq in pp.block_candidates(s, align=pp.mxu_align(s), cap=6):
        for bk in pp.block_candidates(s, align=pp.mxu_align(s), cap=6):
            yield {"bq": bq, "bk": bk}


def _defaults(shapes: dict) -> dict:
    return {"bq": pp.snap_block(shapes["s"], 512),
            "bk": pp.snap_block(shapes["s"], 512)}


pp.register(pp.KernelDef(
    name="flash_attention", traffic=traffic, tune_space=tune_space,
    default_blocks=_defaults))


def hbm_traffic_bytes(b, h, kv, s, hd, dtype_bytes: int = 2) -> dict:
    """Structural HBM traffic of this kernel vs the jnp chunked baseline.

    Used by §Perf: the kernel's traffic is q+k+v read once, o written once;
    the baseline crosses HBM ~3x per (bq, bk) score block (write scores,
    read for exp/sum, write p, read for pv) plus q/k/v reads per block pair.
    """
    qkv = (b * h * s * hd + 2 * b * kv * s * hd) * dtype_bytes
    out = b * h * s * hd * dtype_bytes
    kernel = qkv + out
    n_blocks = (s // 512) ** 2
    score_block = b * h * 512 * 512 * 4
    baseline = kernel + 3 * n_blocks * score_block
    return {"kernel_bytes": float(kernel), "baseline_bytes": float(baseline),
            "reduction": baseline / kernel}
