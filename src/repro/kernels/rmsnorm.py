"""Fused RMSNorm — one HBM round-trip instead of three (norm hot path)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * (1.0 + s_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: (M, D); scale: (D,)."""
    import functools
    m, d = x.shape
    br = min(block_rows, m)
    assert m % br == 0
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(m // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, scale)
