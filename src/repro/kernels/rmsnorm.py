"""Fused RMSNorm — one HBM round-trip instead of three (norm hot path).

On the tile-pipeline layer: row blocks stream through VMEM, the scale vector
stays resident (constant index_map), and the mean/rsqrt/scale fusion runs on
the VPU per tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import pipeline as pp


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * (1.0 + s_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


def build_pipeline(m: int, d: int, dtype, *, eps: float = 1e-6,
                   block_rows: int | None = None,
                   dtype_bytes: int = 4) -> pp.KernelPipeline:
    br = pp.resolve_block(m, block_rows, default=256)
    return pp.KernelPipeline(
        name="rmsnorm",
        body=functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(pp.GridAxis("rows", m // br, "parallel"),),
        in_tiles=[
            pp.TileSpec((br, d), lambda i: (i, 0)),
            pp.TileSpec((d,), lambda i: (0,)),
        ],
        out_tiles=pp.TileSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), dtype),
        cost=traffic({"m": m, "d": d}, {"block_rows": br}, dtype_bytes),
    )


def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
            block_rows: int | None = None, interpret: bool = False) -> jax.Array:
    """x: (M, D); scale: (D,)."""
    m, d = x.shape
    pipe = build_pipeline(m, d, x.dtype, eps=eps, block_rows=block_rows,
                          dtype_bytes=x.dtype.itemsize)
    return pipe(x, scale, interpret=interpret)


# -- pipeline-layer contract --------------------------------------------------

def traffic(shapes: dict, blocks: dict, dtype_bytes: int = 4) -> pp.Traffic:
    m, d = shapes["m"], shapes["d"]
    br = min(blocks["block_rows"], m)
    moved = 2 * m * d * dtype_bytes + d * 4
    return pp.Traffic(
        flops=4.0 * m * d,
        hbm_bytes=float(moved),
        ideal_bytes=float(moved),
        grid_steps=m // br,
        vmem_bytes=2 * 2 * br * d * dtype_bytes + d * 4,
        transcendentals=float(m),               # one rsqrt per row
    )


def tune_space(shapes: dict):
    for br in pp.block_candidates(shapes["m"], align=8):
        yield {"block_rows": br}


pp.register(pp.KernelDef(
    name="rmsnorm", traffic=traffic, tune_space=tune_space,
    default_blocks=lambda shapes: {"block_rows": pp.snap_block(shapes["m"], 256)}))
