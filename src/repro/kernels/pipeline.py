"""Unified tile-pipeline layer — one memory hierarchy, many kernels.

MemPool's claim is that a single hierarchical fabric (tile -> group ->
cluster, hybrid local/interleaved addressing, double-buffered DMA) serves
every kernel. This module is that claim as code for the TPU translation:
every Pallas kernel in this repo describes itself as

  * a set of `TileSpec`s — block shapes + index maps, i.e. which slice of
    each operand is resident in VMEM ("the local tile") at each grid step;
  * a tuple of `GridAxis`es — the iteration space with per-dimension
    semantics ("parallel" = independent tiles, "arbitrary" = sequential,
    carrying VMEM scratch across steps — the paper's sequential region);
  * optional VMEM scratch — the "register tile" held across the sequential
    axis (matmul accumulator, flash-attention online-softmax state);

and `KernelPipeline` emits the `pl.pallas_call`. Pallas's grid pipeline
double-buffers every streamed operand block (the DMA of block k+1 rides
under the compute of block k — paper Fig. 15 / TCDM burst streaming), which
is why `vmem_bytes()` charges two slots per streamed tile and why the cost
model overlaps the memory and compute terms with `max()`.

The autotuner (`autotune`) *ranks* block-size candidates by scoring each
against the repo's existing cost models: `launch/roofline.kernel_roofline`
for the compute/memory terms and `core/interconnect.TopologyModel` for the
locality penalty — candidates that re-stream operands (low reuse = low
p_local in MemPool terms) pay the congested-fabric latency blow-up of the
paper's Fig. 5 model. The *pick*, however, is measured, not modeled: the
top-N modeled candidates plus the hand-picked default are compiled and
raced on device (warmup + median-of-repeats wall time — the same timing
loop the benchmark driver uses), and the measured winner is kept. The
score only prunes the search space; it proved unable to discriminate
between valid blockings (every record used to report modeled_speedup=1.00
while several "tuned" picks were measurably slower than the defaults).
Winning records are registered in `configs/registry.KERNEL_TUNES` — and
written through to the active `kernels.tunedb.TuneDB` — so launchers,
benchmarks, and later processes share one measurement.
"""

from __future__ import annotations

import dataclasses
import math
import os
import statistics
import time
from typing import Any, Callable, Iterator, Sequence

import jax
from jax.experimental import pallas as pl

from repro.core import compat
from repro.core import mesh as hw
from repro.core.interconnect import TOP_H, TopologyModel
from repro.launch.roofline import kernel_roofline

# ----------------------------------------------------------------------------
# Tile / grid description
# ----------------------------------------------------------------------------

def _memory_space(name: str):
    from jax.experimental.pallas import tpu as pltpu
    return {"smem": pltpu.SMEM}[name]


@dataclasses.dataclass(frozen=True)
class TileSpec:
    """One operand's residency: the VMEM block and where it comes from.

    `block` is the tile held on-chip per grid step (the paper's per-core
    working set); `index_map` routes grid coordinates to block coordinates —
    including neighbor/halo routing (conv2d) and head-group folding
    (flash-attention GQA), the analogue of the hybrid addressing scheme's
    scrambler. `memory_space="smem"` marks scalar operands.
    """

    block: tuple[int, ...]
    index_map: Callable[..., tuple] | None = None
    memory_space: str | None = None           # None -> pipelined VMEM

    def block_spec(self) -> pl.BlockSpec:
        if self.memory_space is None:
            return pl.BlockSpec(self.block, self.index_map)
        return pl.BlockSpec(self.block, self.index_map,
                            memory_space=_memory_space(self.memory_space))

    def bytes_per_step(self, dtype_bytes: int) -> int:
        return math.prod(self.block) * dtype_bytes


@dataclasses.dataclass(frozen=True)
class GridAxis:
    """One grid dimension with its MemPool-flavoured semantics.

    "parallel"  — tiles are independent (cores race ahead);
    "arbitrary" — sequential on TPU: VMEM scratch carries across steps,
                  the paper's sequential region owned by one tile.
    """

    name: str
    size: int
    semantics: str = "parallel"

    def __post_init__(self):
        assert self.semantics in ("parallel", "arbitrary"), self.semantics
        assert self.size >= 1, (self.name, self.size)


# ----------------------------------------------------------------------------
# Fusion hooks — prologue on streamed input tiles, epilogue before writeback
# ----------------------------------------------------------------------------
#
# MemPool's DMA engine exists so intermediate tiles are *consumed in L1*
# instead of bouncing through higher memory. The TPU translation: a producer
# kernel's body is stitched into the consumer's grid either as a *prologue*
# (applied to a streamed operand tile right after it lands in VMEM — e.g.
# rmsnorm folded onto the matmul A tile) or an *epilogue* (applied to the
# register/output tile right before writeback — e.g. bias + GELU after the
# K loop). Both run on tile *values*; the hook machinery below intercepts
# ref loads/stores so existing kernel bodies compose unchanged.


@dataclasses.dataclass(frozen=True)
class _Hook:
    """One fusion hook bound to its own slice of the extra-tile operands.

    Each fuse() call appends its extra tiles and binds its hooks to exactly
    that range, so stacked fusions never see each other's operands.
    """

    fn: Callable
    extras_range: tuple[int, int]       # half-open range into extra_tiles

    def __call__(self, value, extras: tuple):
        lo, hi = self.extras_range
        return self.fn(value, *extras[lo:hi])


class _PrologueRef:
    """Wraps an input ref; loads run through the hook chain in fuse order."""

    def __init__(self, ref, hooks: Sequence[_Hook], extras: tuple):
        self._ref = ref
        self._hooks = tuple(hooks)
        self._extras = extras

    def __getitem__(self, idx):
        value = self._ref[idx]
        for hook in self._hooks:
            value = hook(value, self._extras)
        return value

    def __getattr__(self, name):
        return getattr(self._ref, name)


class _EpilogueRef:
    """Wraps an output ref; stores run through the hook chain (innermost —
    most recently fused — first).

    Each hook sees the value the body (or the previous hook) produced and
    returns the fused result; the wrapper re-casts at the end so hooks are
    free to compute in f32.
    """

    def __init__(self, ref, hooks: Sequence[_Hook], extras: tuple):
        self._ref = ref
        self._hooks = tuple(hooks)
        self._extras = extras

    def __setitem__(self, idx, value):
        for hook in self._hooks:
            value = hook(value, self._extras)
        self._ref[idx] = value.astype(self._ref.dtype)

    def __getitem__(self, idx):
        return self._ref[idx]

    def __getattr__(self, name):
        return getattr(self._ref, name)


class FusionError(ValueError):
    """Raised when producer/consumer TileSpecs cannot be stitched."""


def check_fusable(producer_tile: TileSpec, consumer_tile: TileSpec,
                  *, full_dims: Sequence[int] = (),
                  dims: Sequence[int] = ()) -> None:
    """Validate that a producer's output tile can feed a consumer's input.

    Same residency (both pipelined VMEM or both SMEM) and identical block
    shape — the producer tile must be *fully consumed* in the step that
    loads it, or the fusion would recompute partial tiles inconsistently.
    `full_dims` lists block axes that must span the whole array dimension
    (given through `dims`), e.g. a row-normalization folded into a matmul
    prologue needs the entire reduction dim resident per tile.
    """
    if producer_tile.memory_space != consumer_tile.memory_space:
        raise FusionError(
            f"residency mismatch: producer {producer_tile.memory_space} vs "
            f"consumer {consumer_tile.memory_space}")
    if tuple(producer_tile.block) != tuple(consumer_tile.block):
        raise FusionError(
            f"tile shape mismatch: producer {producer_tile.block} vs "
            f"consumer {consumer_tile.block}; the producer tile must be "
            f"fully consumed per grid step")
    for axis, dim in zip(full_dims, dims):
        if consumer_tile.block[axis] != dim:
            raise FusionError(
                f"block axis {axis} covers {consumer_tile.block[axis]} of "
                f"{dim}; the fused producer needs the full dimension "
                f"resident per tile")


class KernelPipeline:
    """Builds one `pl.pallas_call` from tiles + grid + register-tile scratch.

    `prologues` maps input-operand index -> hook applied to that operand's
    tile on load; `epilogue` is applied to every output-tile store.
    `extra_tiles` are additional operands (scales, biases, residual tiles)
    consumed only by the hooks; they are appended after `in_tiles` in the
    emitted pallas_call's operand order.
    """

    def __init__(self, name: str, body: Callable, grid: Sequence[GridAxis],
                 in_tiles: Sequence[TileSpec],
                 out_tiles: TileSpec | Sequence[TileSpec],
                 out_shape: Any, scratch: Sequence[Any] = (),
                 cost: "Traffic | None" = None,
                 prologues: dict[int, Callable] | None = None,
                 epilogue: Callable | None = None,
                 extra_tiles: Sequence[TileSpec] = ()):
        self.name = name
        self.body = body
        self.grid = tuple(grid)
        self.in_tiles = tuple(in_tiles)
        self.out_tiles = (tuple(out_tiles) if isinstance(out_tiles, (tuple, list))
                          else (out_tiles,))
        self.multi_out = isinstance(out_tiles, (tuple, list))
        self.out_shape = out_shape
        self.scratch = tuple(scratch)
        self.cost = cost
        self.extra_tiles = tuple(extra_tiles)
        whole = (0, len(self.extra_tiles))
        self._pro_hooks: dict[int, list[_Hook]] = {
            idx: [_Hook(fn, whole)] for idx, fn in (prologues or {}).items()}
        self._epi_hooks: list[_Hook] = \
            [_Hook(epilogue, whole)] if epilogue is not None else []
        for idx in self._pro_hooks:
            if not 0 <= idx < len(self.in_tiles):
                raise FusionError(f"prologue on operand {idx}, but pipeline "
                                  f"has {len(self.in_tiles)} inputs")

    def fuse(self, *, prologues: dict[int, Callable] | None = None,
             epilogue: Callable | None = None,
             extra_tiles: Sequence[TileSpec] = (),
             name: str | None = None,
             cost: "Traffic | None" = None) -> "KernelPipeline":
        """Return a new pipeline with producer/consumer hooks stitched in.

        The new fusion's extra tiles are appended and its hooks are bound
        to exactly that slice, so stacked fusions compose without seeing
        each other's operands. Prologue indices refer to the *core* operand
        order; an existing hook on the same slot composes (new prologue
        runs after the old one; new epilogue before the old one, i.e.
        closest to the register tile first).
        """
        fused = KernelPipeline(
            name=name or self.name, body=self.body, grid=self.grid,
            in_tiles=self.in_tiles, out_tiles=(
                tuple(self.out_tiles) if self.multi_out else self.out_tiles[0]),
            out_shape=self.out_shape, scratch=self.scratch,
            cost=cost if cost is not None else self.cost,
            extra_tiles=(*self.extra_tiles, *extra_tiles))
        fused._pro_hooks = {idx: list(hooks)
                            for idx, hooks in self._pro_hooks.items()}
        fused._epi_hooks = list(self._epi_hooks)
        rng = (len(self.extra_tiles),
               len(self.extra_tiles) + len(extra_tiles))
        for idx, fn in (prologues or {}).items():
            if not 0 <= idx < len(self.in_tiles):
                raise FusionError(f"prologue on operand {idx}, but pipeline "
                                  f"has {len(self.in_tiles)} inputs")
            fused._pro_hooks.setdefault(idx, []).append(_Hook(fn, rng))
        if epilogue is not None:
            fused._epi_hooks.insert(0, _Hook(epilogue, rng))
        return fused

    # -- introspection -------------------------------------------------------
    @property
    def grid_steps(self) -> int:
        return math.prod(a.size for a in self.grid)

    def dimension_semantics(self) -> tuple[str, ...]:
        return tuple(a.semantics for a in self.grid)

    def vmem_bytes(self, dtype_bytes: int = 4) -> int:
        """Double-buffered VMEM footprint: 2 slots per streamed tile (the
        pipeline's in-flight copy of block k+1 next to block k) + scratch.

        Introspection for a *built* pipeline. The autotuner budget-checks the
        per-kernel `traffic()` formulas instead (pure shape math, no pipeline
        construction per candidate); those may under-count resident constant
        tiles deliberately (e.g. conv2d's 3x3 weight is charged once).
        """
        tiles = [t for t in (*self.in_tiles, *self.extra_tiles,
                             *self.out_tiles)
                 if t.memory_space is None]
        streamed = 2 * sum(t.bytes_per_step(dtype_bytes) for t in tiles)
        scratch = 0
        for s in self.scratch:
            shape = getattr(s, "shape", None)
            dt = getattr(s, "dtype", None)
            if shape is not None:
                scratch += math.prod(shape) * (
                    jax.numpy.dtype(dt).itemsize if dt is not None else 4)
        return streamed + scratch

    # -- emission ------------------------------------------------------------
    def _hooked_body(self) -> Callable:
        """Wrap `body` so hook-bearing refs apply prologues/epilogue.

        The emitted kernel receives (core inputs, extra tiles, outputs,
        scratch); the original body still sees only (core inputs, outputs,
        scratch) — fusion operands exist purely for the hooks.
        """
        if not (self._pro_hooks or self._epi_hooks or self.extra_tiles):
            return self.body
        n_in = len(self.in_tiles)
        n_extra = len(self.extra_tiles)
        n_out = len(self.out_tiles)

        def wrapped(*refs):
            core = list(refs[:n_in])
            extras = tuple(refs[n_in:n_in + n_extra])
            outs = list(refs[n_in + n_extra:n_in + n_extra + n_out])
            scratch = refs[n_in + n_extra + n_out:]
            for idx, hooks in self._pro_hooks.items():
                core[idx] = _PrologueRef(core[idx], hooks, extras)
            if self._epi_hooks:
                outs = [_EpilogueRef(o, self._epi_hooks, extras)
                        for o in outs]
            return self.body(*core, *outs, *scratch)

        return wrapped

    def pipeline_stages(self, dtype_bytes: int = 4) -> int | None:
        """CostEstimate-backed multiple-buffering hint for the grid pipeline.

        Compute-bound kernels keep the classic 2 stages (block k+1's DMA
        under block k's compute fully hides the memory term). Memory-bound
        kernels want a deeper in-flight window — the TCDM-burst amortization
        — so they get 3 stages when a third slot set still fits the VMEM
        budget. None when the pipeline carries no cost model.
        """
        if self.cost is None:
            return None
        r = kernel_roofline(self.cost.flops, self.cost.hbm_bytes)
        if r["memory_s"] <= r["compute_s"]:
            return 2
        slot = sum(t.bytes_per_step(dtype_bytes)
                   for t in (*self.in_tiles, *self.extra_tiles,
                             *self.out_tiles)
                   if t.memory_space is None)
        scratch = self.vmem_bytes(dtype_bytes) - 2 * slot
        return 3 if 3 * slot + scratch <= VMEM_BUDGET_BYTES else 2

    def pallas_call(self, *, interpret: bool = False) -> Callable:
        out_specs = tuple(t.block_spec() for t in self.out_tiles)
        call_kw, cp_kw = compat.pallas_hints(
            cost=(dict(flops=int(self.cost.flops),
                       bytes_accessed=int(self.cost.hbm_bytes),
                       transcendentals=int(self.cost.transcendentals))
                  if self.cost is not None else None),
            num_stages=self.pipeline_stages(),
            dimension_semantics=self.dimension_semantics())
        return pl.pallas_call(
            self._hooked_body(),
            grid=tuple(a.size for a in self.grid),
            in_specs=[t.block_spec()
                      for t in (*self.in_tiles, *self.extra_tiles)],
            out_specs=out_specs if self.multi_out else out_specs[0],
            out_shape=self.out_shape,
            scratch_shapes=list(self.scratch),
            compiler_params=compat.pallas_compiler_params(cp_kw),
            interpret=interpret,
            **call_kw)

    def __call__(self, *operands, interpret: bool = False):
        return self.pallas_call(interpret=interpret)(*operands)


# ----------------------------------------------------------------------------
# Traffic / cost model
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Traffic:
    """Structural traffic of one kernel invocation under a given blocking.

    `saved_bytes` is only set on fused kernels: the intermediate's write +
    read that the unfused producer/consumer composition would have streamed
    through HBM and the fusion eliminates. The unfused composition's traffic
    is therefore `hbm_bytes + saved_bytes` (plus the producer's own operand
    reads, which both paths share).
    """

    flops: float
    hbm_bytes: float        # streamed under this blocking (re-fetches counted)
    ideal_bytes: float      # compulsory traffic: every operand/result once
    grid_steps: int
    vmem_bytes: int
    transcendentals: float = 0.0
    saved_bytes: float = 0.0


def fused_traffic(consumer: Traffic, producer: Traffic,
                  intermediate_bytes: float, *,
                  extra_vmem: int = 0, refetch: int = 1) -> Traffic:
    """Traffic of a producer fused into a consumer's grid.

    The producer's compute rides along (re-run `refetch` times when the
    consumer re-streams the fused operand — e.g. a norm prologue recomputes
    once per N-block column); the intermediate's HBM write (producer side)
    and read (consumer side) disappear. `intermediate_bytes` is the size of
    that intermediate counted once.
    """
    saved = 2.0 * intermediate_bytes
    return Traffic(
        flops=consumer.flops + producer.flops * refetch,
        hbm_bytes=consumer.hbm_bytes + producer.hbm_bytes - saved,
        ideal_bytes=consumer.ideal_bytes + producer.ideal_bytes - saved,
        grid_steps=consumer.grid_steps,
        vmem_bytes=consumer.vmem_bytes + extra_vmem,
        transcendentals=(consumer.transcendentals
                         + producer.transcendentals * refetch),
        saved_bytes=saved,
    )


# fixed per-grid-step pipeline bookkeeping (index computation, DMA issue);
# penalizes degenerate tiny tiles the roofline terms alone would not
GRID_STEP_SECONDS = 2e-7
# injected load at which the locality penalty is evaluated (a busy fabric,
# below the Top_H saturation point — paper Fig. 5 operating point)
_INJECTED_LOAD = 0.3


def locality_factor(traffic: Traffic,
                    model: TopologyModel | None = None) -> tuple[float, float]:
    """(latency blow-up >= 1, p_local) for this blocking's reuse behaviour.

    Reuse fraction = compulsory / streamed bytes: every re-streamed byte is
    a "remote" access in MemPool terms, every reused byte a local-tile hit.
    The Top_H congestion model turns that into an average-latency ratio
    versus the perfectly-local schedule.
    """
    model = model or TopologyModel(TOP_H)
    p_local = min(1.0, traffic.ideal_bytes / max(traffic.hbm_bytes, 1.0))
    base = model.avg_latency(_INJECTED_LOAD, p_local=1.0)
    factor = model.avg_latency(_INJECTED_LOAD, p_local=p_local) / base
    return max(factor, 1.0), p_local


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    compute_s: float
    memory_s: float
    overhead_s: float
    locality: float
    p_local: float
    total_s: float


def score(traffic: Traffic, model: TopologyModel | None = None) -> CostBreakdown:
    """Modeled seconds for one invocation: double-buffered overlap of the
    roofline compute/memory terms, memory scaled by the interconnect-model
    locality penalty, plus per-step pipeline overhead."""
    r = kernel_roofline(traffic.flops, traffic.hbm_bytes)
    factor, p_local = locality_factor(traffic, model)
    memory_s = r["memory_s"] * factor
    overhead = traffic.grid_steps * GRID_STEP_SECONDS
    total = max(r["compute_s"], memory_s) + overhead
    return CostBreakdown(compute_s=r["compute_s"], memory_s=memory_s,
                         overhead_s=overhead, locality=factor,
                         p_local=p_local, total_s=total)


# ----------------------------------------------------------------------------
# Kernel registry
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelDef:
    """A kernel's contract with the pipeline layer.

    `traffic(shapes, blocks, dtype_bytes)` and `tune_space(shapes)` are pure
    shape math — the autotuner never runs the kernel.
    """

    name: str
    traffic: Callable[[dict, dict, int], Traffic]
    tune_space: Callable[[dict], Iterator[dict]]
    default_blocks: Callable[[dict], dict]


KERNELS: dict[str, KernelDef] = {}


def register(defn: KernelDef) -> KernelDef:
    KERNELS[defn.name] = defn
    return defn


def shape_key(shapes: dict, dtype_bytes: int = 4) -> str:
    # dtype_bytes is part of the key: blocks tuned under a 2-byte VMEM
    # footprint are not valid for 4-byte operands of the same shape
    return f"b{dtype_bytes}_" + "_".join(
        f"{k}{shapes[k]}" for k in sorted(shapes))


def block_candidates(dim: int, *, align: int = 8, cap: int = 8,
                     max_block: int | None = None) -> list[int]:
    """Divisors of `dim` that are multiples of `align`, geometrically thinned.

    Falls back to [dim] when nothing aligns (tiny dims) so every kernel
    always has at least one valid, divisibility-respecting candidate.
    """
    cands = [d for d in range(align, dim + 1, align) if dim % d == 0]
    if not cands:
        cands = [dim]
    if max_block is not None:
        capped = [c for c in cands if c <= max_block]
        cands = capped or [min(cands)]
    if len(cands) > cap:
        idx = sorted({round(i * (len(cands) - 1) / (cap - 1))
                      for i in range(cap)})
        cands = [cands[i] for i in idx]
    return cands


def snap_block(dim: int, block: int) -> int:
    """Largest divisor of `dim` that is <= `block` (>= 1)."""
    block = max(1, min(block, dim))
    while dim % block:
        block -= 1
    return block


def resolve_block(dim: int, block: int | None, default: int) -> int:
    """Resolve one block size against its dimension.

    `None` (the wrapper default) snaps `default` to the largest divisor, so
    any operand shape works out of the box. An explicit value is capped at
    the dimension itself (a block can't exceed the array; the cap is the
    whole-dim block, exactly divisible) and must then divide — silently
    substituting some *smaller* blocking for one the caller asked for would
    invalidate their benchmark, so non-divisors raise instead.
    """
    if block is None:
        return snap_block(dim, default)
    block = max(1, min(block, dim))
    if dim % block:
        raise ValueError(
            f"block size {block} does not divide dimension {dim}; pass a "
            f"divisor or omit it for the snapped default")
    return block


def mxu_align(dim: int) -> int:
    """MXU-facing dims prefer 128-aligned tiles; fall back for small dims."""
    return hw.MXU_TILE if dim % hw.MXU_TILE == 0 else 8


# ----------------------------------------------------------------------------
# Autotuner
# ----------------------------------------------------------------------------

# leave headroom under the physical VMEM for the compiler's own buffers
VMEM_BUDGET_BYTES = int(hw.VMEM_BYTES * 0.75)


@dataclasses.dataclass(frozen=True)
class TuneResult:
    kernel: str
    shapes: tuple[tuple[str, int], ...]
    blocks: dict[str, int]
    cost: CostBreakdown
    default_blocks: dict[str, int]
    default_cost: CostBreakdown
    # timed-race results; 0.0 / "modeled" when the pick was score-only
    # (frozen mode, no operand factory, or every race lane failed)
    measured_us: float = 0.0
    default_us: float = 0.0
    source: str = "modeled"
    raced: int = 0                  # lanes actually timed (incl. default)
    # "fused": the kernel body won (with `blocks`); "unfused": the op's
    # composition of primitive kernels beat every blocking, and tuned_call
    # dispatches the composition for this (kernel, shapes) cell instead
    route: str = "fused"

    @property
    def timed(self) -> bool:
        return self.measured_us > 0.0

    @property
    def measured_speedup(self) -> float:
        """Raced wall-time speedup over the default blocking; >= 1.0 by
        construction (the default is always a race lane), 1.0 untimed."""
        if not self.timed:
            return 1.0
        return self.default_us / max(self.measured_us, 1e-30)


# -- the timing loop ---------------------------------------------------------
# Shared with the benchmark driver (benchmarks/bench_table1_kernels.timeit
# delegates here): warmup runs absorb compilation, then the median of
# `reps` blocked wall-clock runs. Medians, not means — one GC pause or
# compile-cache refill must not hand the race to the wrong blocking.

def _env_int(name: str, default: int) -> int:
    raw = os.environ.get(name, "").strip()
    return int(raw) if raw else default


def median_time(fn: Callable[[], Any], *, reps: int = 3,
                warmup: int = 1) -> float:
    """Median wall seconds per call of `fn()` after `warmup` discarded runs."""
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn())
    times = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return statistics.median(times)


@dataclasses.dataclass(frozen=True)
class _RaceOutcome:
    blocks: dict[str, int]
    measured_s: float
    default_s: float
    lanes: int
    route: str = "fused"


# sentinel "blocks" dict the composition lane hands the injectable timer —
# tests key on it to force the unfused route to win or lose a race
COMPOSITION_LANE = {"route": "unfused"}


def _race_dtype(dtype_bytes: int):
    return {2: jax.numpy.bfloat16, 8: jax.numpy.float64}.get(
        dtype_bytes, jax.numpy.float32)


def _race(kernel: str, shapes: dict, candidates: Sequence[dict],
          default_blocks: dict, dtype_bytes: int, *,
          timer: Callable[[Callable, dict], float] | None = None,
          reps: int | None = None,
          warmup: int | None = None) -> _RaceOutcome | None:
    """Time each candidate blocking (plus the default) on device and return
    the measured winner; None when racing is impossible (no operand
    factory for this kernel, operand synthesis failed, or every lane
    errored) — the caller falls back to the modeled pick.

    When the descriptor carries an unfused `composition`, it races as one
    extra lane (timed with the `COMPOSITION_LANE` sentinel as its blocks
    dict). If it beats every kernel blocking the outcome's route flips to
    "unfused" — `blocks` still records the best *kernel* blocking so the
    record stays usable if the composition is ever unavailable.

    `timer(fn, blocks) -> seconds` is injectable for deterministic tests;
    the default is `median_time` with REPRO_TUNE_REPS/1-warmup settings.
    Operands are *synthesized* from the shape dict (never taken from the
    calling site — tuned_call may be running under a jit trace where the
    real operands are tracers).
    """
    from repro.kernels import ops
    desc = ops.OPS.get(kernel)
    if desc is None or desc.operands is None:
        return None
    try:
        operands = desc.operands(shapes, _race_dtype(dtype_bytes))
    except Exception:
        return None
    if timer is None:
        reps = _env_int("REPRO_TUNE_REPS", 3) if reps is None else reps
        warmup = 1 if warmup is None else warmup

        def timer(fn, blocks, _r=reps, _w=warmup):
            return median_time(fn, reps=_r, warmup=_w)

    lanes: list[dict] = []
    seen: set = set()
    for b in (*candidates, dict(default_blocks)):
        k = tuple(sorted(b.items()))
        if k not in seen:
            seen.add(k)
            lanes.append(dict(b))
    times: list[float] = []
    for b in lanes:
        try:
            times.append(float(timer(lambda b=b: desc.wrapper(*operands, **b),
                                     b)))
        except Exception:
            times.append(float("inf"))      # a lane that won't run can't win
    best = min(range(len(lanes)), key=times.__getitem__)
    if not math.isfinite(times[best]):
        return None
    default_key = tuple(sorted(default_blocks.items()))
    default_s = next(t for b, t in zip(lanes, times)
                     if tuple(sorted(b.items())) == default_key)
    comp_s, comp_lanes = float("inf"), 0
    if desc.composition is not None:
        comp_lanes = 1
        try:
            comp_s = float(timer(lambda: desc.composition(*operands),
                                 dict(COMPOSITION_LANE)))
        except Exception:
            comp_s = float("inf")
    if comp_s < times[best]:
        return _RaceOutcome(blocks=lanes[best], measured_s=comp_s,
                            default_s=default_s,
                            lanes=len(lanes) + comp_lanes, route="unfused")
    return _RaceOutcome(blocks=lanes[best], measured_s=times[best],
                        default_s=default_s, lanes=len(lanes) + comp_lanes)


def autotune(kernel: str, shapes: dict, *, dtype_bytes: int = 4,
             vmem_budget: int = VMEM_BUDGET_BYTES,
             register_record: bool = True,
             mode: str | None = None,
             timer: Callable[[Callable, dict], float] | None = None,
             top_n: int | None = None,
             reps: int | None = None) -> TuneResult:
    """Pick the measured-fastest valid blocking for `kernel` at `shapes`.

    Every candidate from the kernel's tune space is checked for
    divisibility (the space only emits divisors) and the double-buffered
    VMEM budget, then *ranked* with the modeled `score`. Under the "timed"
    tune mode (the default — see `kernels.tunedb.tune_mode`), the top
    `top_n` (REPRO_TUNE_TOPN, default 3) modeled candidates and the
    hand-picked default are then compiled and raced with warmup +
    median-of-repeats timing, and the measured winner is kept; "modeled"
    keeps the score-only pick (the legacy behaviour), and "frozen" does
    the same while guaranteeing no DB write (CI determinism). The winner
    is recorded in `configs.registry.KERNEL_TUNES` keyed on (kernel,
    shape_key) and — for timed picks — written through to the active
    TuneDB. One race bumps the ambient KernelPolicy's `tune_races`
    counter.
    """
    from repro.kernels import tunedb

    defn = KERNELS[kernel]
    scored: list[tuple[float, dict]] = []
    for blocks in defn.tune_space(shapes):
        t = defn.traffic(shapes, blocks, dtype_bytes)
        if t.vmem_bytes > vmem_budget:
            continue
        scored.append((score(t).total_s, dict(blocks)))
    if not scored:                 # budget excluded everything: take smallest
        blocks = next(iter(defn.tune_space(shapes)))
        scored = [(score(defn.traffic(shapes, blocks, dtype_bytes)).total_s,
                   dict(blocks))]
    scored.sort(key=lambda sc: sc[0])
    best_blocks = dict(scored[0][1])
    default = defn.default_blocks(shapes)
    default_cost = score(defn.traffic(shapes, default, dtype_bytes))

    resolved = tunedb.tune_mode(mode)
    measured_us = default_us = 0.0
    source, raced, route = "modeled", 0, "fused"
    if resolved == "timed":
        top_n = _env_int("REPRO_TUNE_TOPN", 3) if top_n is None else top_n
        outcome = _race(kernel, shapes,
                        [b for _, b in scored[:max(top_n, 1)]], default,
                        dtype_bytes, timer=timer, reps=reps)
        if outcome is not None:
            best_blocks = dict(outcome.blocks)
            measured_us = outcome.measured_s * 1e6
            default_us = outcome.default_s * 1e6
            source, raced, route = "timed", outcome.lanes, outcome.route
            from repro.cluster.policy import current_policy
            current_policy().bump("tune_races")

    best_cost = score(defn.traffic(shapes, best_blocks, dtype_bytes))
    result = TuneResult(kernel=kernel,
                        shapes=tuple(sorted(shapes.items())),
                        blocks=best_blocks, cost=best_cost,
                        default_blocks=dict(default),
                        default_cost=default_cost,
                        measured_us=measured_us, default_us=default_us,
                        source=source, raced=raced, route=route)
    if register_record:
        from repro.configs import registry
        best_traffic = defn.traffic(shapes, best_blocks, dtype_bytes)
        rec = registry.register_kernel_tune(registry.KernelTuneRecord(
            kernel=kernel, shape_key=shape_key(shapes, dtype_bytes),
            blocks=tuple(sorted(best_blocks.items())),
            modeled_seconds=best_cost.total_s,
            default_blocks=tuple(sorted(default.items())),
            default_modeled_seconds=default_cost.total_s,
            saved_bytes=best_traffic.saved_bytes,
            measured_us=measured_us, default_us=default_us, source=source,
            route=route))
        if source == "timed" and resolved != "frozen":
            db = tunedb.active_db()
            if db is not None:
                from repro.cluster.policy import current_policy
                db.record(rec, backend=jax.default_backend(),
                          mode=current_policy().mode)
    return result


def tuned_record(kernel: str, shapes: dict, *, dtype_bytes: int = 4,
                 **autotune_kwargs):
    """Registry-first tune record for (kernel, shapes, dtype).

    A hit — including a TuneDB warm-start — returns without re-racing
    (this is what makes a second benchmark run race-free); a miss runs
    `autotune` (timed under the active mode) and returns the fresh record.
    Either way the ambient KernelPolicy's tune_hits/tune_misses counter
    is bumped, same as the `tuned_call` dispatch path.
    """
    from repro.cluster.policy import current_policy
    from repro.configs import registry
    key = shape_key(shapes, dtype_bytes)
    rec = registry.get_kernel_tune(kernel, key)
    if rec is not None:
        current_policy().bump("tune_hits")
        return rec
    current_policy().bump("tune_misses")
    autotune(kernel, shapes, dtype_bytes=dtype_bytes, **autotune_kwargs)
    return registry.get_kernel_tune(kernel, key)


def tuned_blocks(kernel: str, shapes: dict, *, dtype_bytes: int = 4) -> dict:
    """Registry-cached tuned blocks for (kernel, shapes, dtype); tunes on miss."""
    return dict(tuned_record(kernel, shapes, dtype_bytes=dtype_bytes).blocks)
