"""3x3 2-D convolution — the paper's `2dconv` kernel.

MemPool tiles the image so each core's pixels live in its own tile (local
accesses except at tile edges). TPU translation: the grid walks row-blocks;
halo rows arrive as two extra views of the same input whose index_maps point
at the neighbor blocks (clamped at the image edges), so each VMEM tile has
its "remote" halo delivered by the pipeline rather than re-fetched — the
neighbor-tile access of the paper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _conv_kernel(x_ref, up_ref, dn_ref, w_ref, o_ref, *, n_blocks: int):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)          # (bh, W)
    bh, W = x.shape
    w = w_ref[...].astype(jnp.float32)          # (3, 3) in SMEM-like block

    # rows shifted by -1 (need row above) and +1 (row below), with halo
    # rows taken from the neighbor blocks; zero at the true image edges.
    up_halo = up_ref[...].astype(jnp.float32)[-1:]   # last row of block i-1
    dn_halo = dn_ref[...].astype(jnp.float32)[:1]    # first row of block i+1
    up_halo = jnp.where(i == 0, jnp.zeros_like(up_halo), up_halo)
    dn_halo = jnp.where(i == n_blocks - 1, jnp.zeros_like(dn_halo), dn_halo)
    x_up = jnp.concatenate([up_halo, x[:-1]], axis=0)    # row r-1
    x_dn = jnp.concatenate([x[1:], dn_halo], axis=0)     # row r+1

    def shift_cols(a, dx):
        if dx == 0:
            return a
        pad = jnp.zeros((a.shape[0], abs(dx)), a.dtype)
        if dx > 0:    # neighbor to the left
            return jnp.concatenate([pad, a[:, :-dx]], axis=1)
        return jnp.concatenate([a[:, -dx:], pad], axis=1)

    acc = jnp.zeros_like(x)
    for dy, row in ((0, x_up), (1, x), (2, x_dn)):
        for dx in range(3):
            acc = acc + w[dy, dx] * shift_cols(row, 1 - dx)
    o_ref[...] = acc.astype(o_ref.dtype)


def conv2d_3x3(x: jax.Array, w: jax.Array, *, block_rows: int = 256,
               interpret: bool = False) -> jax.Array:
    """x: (H, W); w: (3, 3); zero-padded same correlation."""
    H, W = x.shape
    bh = min(block_rows, H)
    assert H % bh == 0
    n_blocks = H // bh
    kernel = functools.partial(_conv_kernel, n_blocks=n_blocks)
    clamp = lambda i, lo, hi: jnp.clip(i, lo, hi)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((bh, W), lambda i: (i, 0)),
            pl.BlockSpec((bh, W),
                         lambda i: (clamp(i - 1, 0, n_blocks - 1), 0)),
            pl.BlockSpec((bh, W),
                         lambda i: (clamp(i + 1, 0, n_blocks - 1), 0)),
            pl.BlockSpec((3, 3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bh, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x, x, x, w)
