"""3x3 2-D convolution — the paper's `2dconv` kernel.

MemPool tiles the image so each core's pixels live in its own tile (local
accesses except at tile edges). TPU translation on the tile-pipeline layer:
the grid walks row-blocks; halo rows arrive as two extra TileSpec views of
the same input whose index_maps point at the neighbor blocks (clamped at the
image edges), so each VMEM tile has its "remote" halo delivered by the
pipeline rather than re-fetched — the neighbor-tile access of the paper.
Because the halo arrives as full neighbor-block views, the input is streamed
~3x regardless of block height (p_local is flat at ~0.5 — the fixed price of
this halo scheme); tuning block_rows trades per-step pipeline overhead
against the VMEM footprint only. Fetching halo *rows* instead of blocks
would let taller blocks genuinely shrink the re-streamed share — a future
optimization the traffic model would reward automatically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import pipeline as pp


def _conv_kernel(x_ref, up_ref, dn_ref, w_ref, o_ref, *, n_blocks: int):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)          # (bh, W)
    bh, W = x.shape
    w = w_ref[...].astype(jnp.float32)          # (3, 3) in SMEM-like block

    # rows shifted by -1 (need row above) and +1 (row below), with halo
    # rows taken from the neighbor blocks; zero at the true image edges.
    up_halo = up_ref[...].astype(jnp.float32)[-1:]   # last row of block i-1
    dn_halo = dn_ref[...].astype(jnp.float32)[:1]    # first row of block i+1
    up_halo = jnp.where(i == 0, jnp.zeros_like(up_halo), up_halo)
    dn_halo = jnp.where(i == n_blocks - 1, jnp.zeros_like(dn_halo), dn_halo)
    x_up = jnp.concatenate([up_halo, x[:-1]], axis=0)    # row r-1
    x_dn = jnp.concatenate([x[1:], dn_halo], axis=0)     # row r+1

    def shift_cols(a, dx):
        if dx == 0:
            return a
        pad = jnp.zeros((a.shape[0], abs(dx)), a.dtype)
        if dx > 0:    # neighbor to the left
            return jnp.concatenate([pad, a[:, :-dx]], axis=1)
        return jnp.concatenate([a[:, -dx:], pad], axis=1)

    acc = jnp.zeros_like(x)
    for dy, row in ((0, x_up), (1, x), (2, x_dn)):
        for dx in range(3):
            acc = acc + w[dy, dx] * shift_cols(row, 1 - dx)
    o_ref[...] = acc.astype(o_ref.dtype)


def build_pipeline(H: int, W: int, dtype, *, block_rows: int | None = None,
                   dtype_bytes: int = 4) -> pp.KernelPipeline:
    bh = pp.resolve_block(H, block_rows, default=256)
    n_blocks = H // bh
    clamp = lambda i, lo, hi: jnp.clip(i, lo, hi)
    return pp.KernelPipeline(
        name="conv2d",
        body=functools.partial(_conv_kernel, n_blocks=n_blocks),
        grid=(pp.GridAxis("rows", n_blocks, "arbitrary"),),
        in_tiles=[
            pp.TileSpec((bh, W), lambda i: (i, 0)),
            pp.TileSpec((bh, W),
                        lambda i: (clamp(i - 1, 0, n_blocks - 1), 0)),
            pp.TileSpec((bh, W),
                        lambda i: (clamp(i + 1, 0, n_blocks - 1), 0)),
            pp.TileSpec((3, 3), lambda i: (0, 0)),
        ],
        out_tiles=pp.TileSpec((bh, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), dtype),
        cost=traffic({"h": H, "w": W}, {"block_rows": bh}, dtype_bytes),
    )


def conv2d_3x3(x: jax.Array, w: jax.Array, *, block_rows: int | None = None,
               interpret: bool = False) -> jax.Array:
    """x: (H, W); w: (3, 3); zero-padded same correlation."""
    H, W = x.shape
    pipe = build_pipeline(H, W, x.dtype, block_rows=block_rows,
                          dtype_bytes=x.dtype.itemsize)
    return pipe(x, x, x, w, interpret=interpret)


# -- pipeline-layer contract --------------------------------------------------

def traffic(shapes: dict, blocks: dict, dtype_bytes: int = 4) -> pp.Traffic:
    H, W = shapes["h"], shapes["w"]
    bh = min(blocks["block_rows"], H)
    n_blocks = H // bh
    # the pipeline fetches the center block plus both neighbor views per step
    streamed = dtype_bytes * (3 * H * W + H * W) + 9 * 4 * n_blocks
    ideal = dtype_bytes * 2 * H * W + 9 * 4
    return pp.Traffic(
        flops=2.0 * 9 * H * W,
        hbm_bytes=float(streamed),
        ideal_bytes=float(ideal),
        grid_steps=n_blocks,
        vmem_bytes=2 * 4 * bh * W * dtype_bytes + 9 * 4,
    )


def tune_space(shapes: dict):
    for bh in pp.block_candidates(shapes["h"], align=8):
        yield {"block_rows": bh}


pp.register(pp.KernelDef(
    name="conv2d", traffic=traffic, tune_space=tune_space,
    default_blocks=lambda shapes: {"block_rows": pp.snap_block(shapes["h"], 256)}))
