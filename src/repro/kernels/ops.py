"""Public jit'd wrappers for the kernel suite.

Dispatch: real `pl.pallas_call` lowering on TPU; `interpret=True` (kernel
body executed op-by-op on CPU) everywhere else — numerics identical, which
is what the allclose tests against ref.py verify.

Every kernel registers one `OpDescriptor` in `OPS` — the single table
holding its public wrapper, its runtime-operand -> pipeline-shape-dict
mapping, and which operand's dtype sets the VMEM tile footprint. The
fused kernels (kernels/fused.py) register here too, so `tuned_call`
serves fused and unfused names uniformly.

The fused wrappers carry a `custom_vjp`: the forward runs the fused Pallas
kernel; the backward recomputes through the jnp reference composition
(FlashAttention-style — residuals are the kernel *inputs*, so the fused
intermediate stays out of HBM in the forward pass, which is where the
serve path and the activation-bound training forward spend their traffic).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from . import axpy as _axpy
from . import conv2d as _conv2d
from . import dct8x8 as _dct8x8
from . import dotp as _dotp
from . import flash_attention as _fa
from . import fused as _fused
from . import matmul as _matmul
from . import pipeline as _pipeline
from . import ref as _ref
from . import rmsnorm as _rmsnorm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ----------------------------------------------------------------------------
# Kernel descriptor table — one record per public kernel
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OpDescriptor:
    """A kernel's public contract in one place.

    `shapes(*operands)` maps the wrapper's runtime operands to the
    pipeline-layer shape dict (the autotuner key); `streamed_operand` is the
    index of the main streamed operand — the one whose dtype sets the VMEM
    tile footprint (weights/scales/alpha ride along). `fused` marks kernels
    whose Traffic carries `saved_bytes` (an eliminated intermediate).
    """

    name: str
    wrapper: Callable
    shapes: Callable[..., dict]
    streamed_operand: int = 0
    fused: bool = False


OPS: dict[str, OpDescriptor] = {}


def register_op(desc: OpDescriptor) -> OpDescriptor:
    OPS[desc.name] = desc
    return desc


def wrapper_for(name: str):
    """Public name -> jit'd wrapper dispatch (same table tuned_call uses)."""
    return OPS[name].wrapper


def kernel_shapes(name: str, *operands) -> dict:
    """The pipeline-layer shape dict for a kernel's runtime operands.

    Operand order matches the public wrapper, so `kernel_shapes(name,
    *args)` pairs with `tuned_call(name, *args)`.
    """
    return OPS[name].shapes(*operands)


def tuned_call(name: str, *operands, **kwargs):
    """Run a kernel with autotuned (registry-cached) block sizes."""
    desc = OPS[name]
    shapes = desc.shapes(*operands)
    dtype_bytes = operands[desc.streamed_operand].dtype.itemsize
    blocks = _pipeline.tuned_blocks(name, shapes, dtype_bytes=dtype_bytes)
    return desc.wrapper(*operands, **blocks, **kwargs)


# ----------------------------------------------------------------------------
# The unfused kernel suite
# ----------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a, b, *, bm: int | None = None, bn: int | None = None,
           bk: int | None = None):
    return _matmul.matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_rows",))
def axpy(alpha, x, y, *, block_rows: int | None = None):
    return _axpy.axpy(alpha, x, y, block_rows=block_rows,
                      interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_rows",))
def dotp(x, y, *, block_rows: int | None = None):
    return _dotp.dotp(x, y, block_rows=block_rows, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_rows",))
def conv2d_3x3(x, w, *, block_rows: int | None = None):
    return _conv2d.conv2d_3x3(x, w, block_rows=block_rows,
                              interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_n",))
def dct8x8(blocks, *, block_n: int | None = None):
    return _dct8x8.dct8x8(blocks, block_n=block_n, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_rows",))
def rmsnorm(x, scale, *, block_rows: int | None = None):
    return _rmsnorm.rmsnorm(x, scale, block_rows=block_rows,
                            interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int | None = None,
                    bk: int | None = None):
    return _fa.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                               interpret=_interpret())


# ----------------------------------------------------------------------------
# Fused kernels: Pallas forward, reference-composition backward
# ----------------------------------------------------------------------------


def _ref_rmsnorm_matmul(x, scale, w):
    return jnp.dot(_ref.rmsnorm(x, scale), w,
                   preferred_element_type=jnp.float32).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _rmsnorm_matmul_p(blocks: tuple, x, scale, w):
    return _fused.rmsnorm_matmul(x, scale, w, interpret=_interpret(),
                                 **dict(blocks))


def _rmsnorm_matmul_fwd(blocks, x, scale, w):
    return _rmsnorm_matmul_p(blocks, x, scale, w), (x, scale, w)


def _rmsnorm_matmul_bwd(blocks, res, g):
    _, vjp = jax.vjp(_ref_rmsnorm_matmul, *res)
    return vjp(g)


_rmsnorm_matmul_p.defvjp(_rmsnorm_matmul_fwd, _rmsnorm_matmul_bwd)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def rmsnorm_matmul(x, scale, w, *, bm: int | None = None,
                   bn: int | None = None):
    """matmul(rmsnorm(x, scale), w); the normed x never round-trips HBM."""
    return _rmsnorm_matmul_p((("bm", bm), ("bn", bn)), x, scale, w)


def _ref_matmul_bias_act(act: str, a, b, bias):
    h = jnp.dot(a, b, preferred_element_type=jnp.float32) \
        + bias.astype(jnp.float32)
    return _fused.ACTIVATIONS[act](h).astype(a.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _matmul_bias_act_p(act: str, blocks: tuple, a, b, bias):
    return _fused.matmul_bias_act(a, b, bias, act=act,
                                  interpret=_interpret(), **dict(blocks))


def _matmul_bias_act_fwd(act, blocks, a, b, bias):
    return _matmul_bias_act_p(act, blocks, a, b, bias), (a, b, bias)


def _matmul_bias_act_bwd(act, blocks, res, g):
    _, vjp = jax.vjp(functools.partial(_ref_matmul_bias_act, act), *res)
    return vjp(g)


_matmul_bias_act_p.defvjp(_matmul_bias_act_fwd, _matmul_bias_act_bwd)


@functools.partial(jax.jit, static_argnames=("act", "bm", "bn", "bk"))
def matmul_bias_act(a, b, bias, *, act: str = "gelu", bm: int | None = None,
                    bn: int | None = None, bk: int | None = None):
    """act(a @ b + bias) with the epilogue applied before writeback."""
    return _matmul_bias_act_p(act, (("bm", bm), ("bn", bn), ("bk", bk)),
                              a, b, bias)


def _ref_matmul_residual_add(a, b, res):
    return (jnp.dot(a, b, preferred_element_type=jnp.float32)
            + res.astype(jnp.float32)).astype(a.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _matmul_residual_add_p(blocks: tuple, a, b, res):
    return _fused.matmul_residual_add(a, b, res, interpret=_interpret(),
                                      **dict(blocks))


def _matmul_residual_add_fwd(blocks, a, b, res):
    return _matmul_residual_add_p(blocks, a, b, res), (a, b, res)


def _matmul_residual_add_bwd(blocks, res_, g):
    _, vjp = jax.vjp(_ref_matmul_residual_add, *res_)
    return vjp(g)


_matmul_residual_add_p.defvjp(_matmul_residual_add_fwd,
                              _matmul_residual_add_bwd)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_residual_add(a, b, res, *, bm: int | None = None,
                        bn: int | None = None, bk: int | None = None):
    """a @ b + res; the matmul output never round-trips HBM."""
    return _matmul_residual_add_p((("bm", bm), ("bn", bn), ("bk", bk)),
                                  a, b, res)


def _ref_flash_attention_proj(causal: bool, q, k, v, wo):
    g = q.shape[1] // k.shape[1]
    o = _ref.flash_attention(q, jnp.repeat(k, g, axis=1),
                             jnp.repeat(v, g, axis=1), causal=causal)
    return jnp.einsum("bhsk,hkd->bsd", o, wo).astype(q.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _flash_attention_proj_p(causal: bool, blocks: tuple, q, k, v, wo):
    return _fused.flash_attention_proj(q, k, v, wo, causal=causal,
                                       interpret=_interpret(),
                                       **dict(blocks))


def _flash_attention_proj_fwd(causal, blocks, q, k, v, wo):
    return _flash_attention_proj_p(causal, blocks, q, k, v, wo), (q, k, v, wo)


def _flash_attention_proj_bwd(causal, blocks, res, g):
    _, vjp = jax.vjp(functools.partial(_ref_flash_attention_proj, causal),
                     *res)
    return vjp(g)


_flash_attention_proj_p.defvjp(_flash_attention_proj_fwd,
                               _flash_attention_proj_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention_proj(q, k, v, wo, *, causal: bool = True,
                         bq: int | None = None, bk: int | None = None):
    """Flash attention with the output projection fused across heads."""
    return _flash_attention_proj_p(causal, (("bq", bq), ("bk", bk)),
                                   q, k, v, wo)


# ----------------------------------------------------------------------------
# Descriptor registration
# ----------------------------------------------------------------------------


def _shapes_axpy(alpha, x, y):
    return {"m": x.shape[0], "n": x.shape[1]}


def _shapes_dotp(x, y):
    return {"m": x.shape[0], "n": x.shape[1]}


def _shapes_matmul(a, b):
    return {"m": a.shape[0], "k": a.shape[1], "n": b.shape[1]}


def _shapes_conv2d(x, w):
    return {"h": x.shape[0], "w": x.shape[1]}


def _shapes_dct8x8(blocks):
    return {"n": blocks.shape[0]}


def _shapes_rmsnorm(x, scale):
    return {"m": x.shape[0], "d": x.shape[1]}


def _shapes_flash_attention(q, k, v):
    b, h, s, hd = q.shape
    return {"b": b, "h": h, "kv": k.shape[1], "s": s, "hd": hd}


def _shapes_rmsnorm_matmul(x, scale, w):
    return {"m": x.shape[0], "k": x.shape[1], "n": w.shape[1]}


def _shapes_matmul_epilogue(a, b, extra):
    return {"m": a.shape[0], "k": a.shape[1], "n": b.shape[1]}


def _shapes_flash_attention_proj(q, k, v, wo):
    b, h, s, hd = q.shape
    return {"b": b, "h": h, "kv": k.shape[1], "s": s, "hd": hd,
            "dm": wo.shape[-1]}


for _desc in (
    OpDescriptor("axpy", axpy, _shapes_axpy, streamed_operand=1),
    OpDescriptor("dotp", dotp, _shapes_dotp),
    OpDescriptor("matmul", matmul, _shapes_matmul),
    OpDescriptor("conv2d", conv2d_3x3, _shapes_conv2d),
    OpDescriptor("dct8x8", dct8x8, _shapes_dct8x8),
    OpDescriptor("rmsnorm", rmsnorm, _shapes_rmsnorm),
    OpDescriptor("flash_attention", flash_attention, _shapes_flash_attention),
    OpDescriptor("rmsnorm_matmul", rmsnorm_matmul, _shapes_rmsnorm_matmul,
                 fused=True),
    OpDescriptor("matmul_bias_act", matmul_bias_act, _shapes_matmul_epilogue,
                 fused=True),
    OpDescriptor("matmul_residual_add", matmul_residual_add,
                 _shapes_matmul_epilogue, fused=True),
    OpDescriptor("flash_attention_proj", flash_attention_proj,
                 _shapes_flash_attention_proj, fused=True),
):
    register_op(_desc)
