"""Public wrappers for the kernel suite, dispatched through the KernelPolicy.

Every public wrapper consults the active `repro.cluster.KernelPolicy`
(`current_policy()`) at call/trace time:

  * mode "reference"  -> the pure-jnp oracle (kernels/ref.py composition);
  * mode "interpret"  -> the Pallas body through the interpreter even on
                         TPU (off-TPU backends always interpret — numerics
                         identical, which is what the allclose tests
                         against ref.py verify);
  * otherwise         -> real `pl.pallas_call` lowering on TPU.

Per-op overrides (`KernelPolicy.overrides`) re-route or re-block single
ops; `tuned_call` delegates to `KernelPolicy.call`, where fused/tuned/
reference selection and autotune-on-miss live in one place.

Dispatch happens in Python, outside the inner jitted kernels (the resolved
`interpret` flag is a static jit arg), so *direct* wrapper calls always see
the policy active at that call. Inside a user-jitted function, however, the
policy is read while tracing and baked into the trace — switching the
ambient policy does NOT retrace an already-cached jit. Compiled Cluster
programs pin their policy at compile time (and the compile cache keys on
it), which is the supported way to compare policies on one model.

Every kernel registers one `OpDescriptor` in `OPS` — the single table
holding its public wrapper, its reference composition, its runtime-operand
-> pipeline-shape-dict mapping, and which operand's dtype sets the VMEM
tile footprint. The fused kernels (kernels/fused.py) register here too, so
`tuned_call` serves fused and unfused names uniformly.

The fused wrappers carry a `custom_vjp`: the forward runs the fused Pallas
kernel; the backward recomputes through the jnp reference composition
(FlashAttention-style — residuals are the kernel *inputs*, so the fused
intermediate stays out of HBM in the forward pass, which is where the
serve path and the activation-bound training forward spend their traffic).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.cluster.policy import current_policy

from . import axpy as _axpy
from . import conv2d as _conv2d
from . import dct8x8 as _dct8x8
from . import dotp as _dotp
from . import flash_attention as _fa
from . import fused as _fused
from . import matmul as _matmul
from . import ref as _ref
from . import rmsnorm as _rmsnorm


def _take_reference(name: str) -> bool:
    """Reference-mode short-circuit for `name` under the active policy."""
    pol = current_policy()
    if pol.mode_for(name) == "reference":
        pol.bump("ref_calls")
        return True
    pol.bump("pallas_calls")
    return False


def _interp(name: str) -> bool:
    return current_policy().interpret_for(name)


# ----------------------------------------------------------------------------
# Kernel descriptor table — one record per public kernel
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OpDescriptor:
    """A kernel's public contract in one place.

    `shapes(*operands)` maps the wrapper's runtime operands to the
    pipeline-layer shape dict (the autotuner key); `operands(shapes,
    dtype)` is its inverse — synthetic random operands for a shape dict,
    which is what the autotuner's timed race runs candidates on (the real
    operands at a tuned_call miss may be jit tracers); `reference` is the
    pure-jnp composition the "reference" policy mode routes to (and the
    custom-VJP backward recomputes through, for fused kernels);
    `streamed_operand` is the index of the main streamed operand — the one
    whose dtype sets the VMEM tile footprint (weights/scales/alpha ride
    along). `fused` marks kernels whose Traffic carries `saved_bytes` (an
    eliminated intermediate); `composition` is the *unfused route* for a
    fused kernel — the same math built from the primitive Pallas wrappers
    plus jnp epilogues (NOT the pure-jnp `reference`) — which the timed
    race runs as one extra lane, so a fusion that loses to its own parts
    on real shapes is demoted per (kernel, shape) cell.
    """

    name: str
    wrapper: Callable
    shapes: Callable[..., dict]
    reference: Callable | None = None
    streamed_operand: int = 0
    fused: bool = False
    operands: Callable[[dict, Any], tuple] | None = None
    composition: Callable | None = None


OPS: dict[str, OpDescriptor] = {}


def register_op(desc: OpDescriptor) -> OpDescriptor:
    OPS[desc.name] = desc
    return desc


def wrapper_for(name: str):
    """Public name -> policy-dispatched wrapper (same table tuned_call uses)."""
    return OPS[name].wrapper


def kernel_shapes(name: str, *operands) -> dict:
    """The pipeline-layer shape dict for a kernel's runtime operands.

    Operand order matches the public wrapper, so `kernel_shapes(name,
    *args)` pairs with `tuned_call(name, *args)`.
    """
    return OPS[name].shapes(*operands)


def tuned_call(name: str, *operands, **kwargs):
    """Run a kernel under the active KernelPolicy: reference short-circuit,
    per-op block override, or autotuned (registry-cached) block sizes with
    autotune-on-miss — see `KernelPolicy.call`."""
    return current_policy().call(name, *operands, **kwargs)


# ----------------------------------------------------------------------------
# The unfused kernel suite
# ----------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _matmul_c(a, b, *, bm, bn, bk, interpret):
    return _matmul.matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=interpret)


def matmul(a, b, *, bm: int | None = None, bn: int | None = None,
           bk: int | None = None):
    if _take_reference("matmul"):
        return _ref.matmul(a, b)
    return _matmul_c(a, b, bm=bm, bn=bn, bk=bk, interpret=_interp("matmul"))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _axpy_c(alpha, x, y, *, block_rows, interpret):
    return _axpy.axpy(alpha, x, y, block_rows=block_rows, interpret=interpret)


def axpy(alpha, x, y, *, block_rows: int | None = None):
    if _take_reference("axpy"):
        return _ref.axpy(alpha, x, y)
    return _axpy_c(alpha, x, y, block_rows=block_rows,
                   interpret=_interp("axpy"))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _dotp_c(x, y, *, block_rows, interpret):
    return _dotp.dotp(x, y, block_rows=block_rows, interpret=interpret)


def dotp(x, y, *, block_rows: int | None = None):
    if _take_reference("dotp"):
        return _ref.dotp(x, y)
    return _dotp_c(x, y, block_rows=block_rows, interpret=_interp("dotp"))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _conv2d_c(x, w, *, block_rows, interpret):
    return _conv2d.conv2d_3x3(x, w, block_rows=block_rows,
                              interpret=interpret)


def conv2d_3x3(x, w, *, block_rows: int | None = None):
    if _take_reference("conv2d"):
        return _ref.conv2d_3x3(x, w)
    return _conv2d_c(x, w, block_rows=block_rows, interpret=_interp("conv2d"))


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _dct8x8_c(blocks, *, block_n, interpret):
    return _dct8x8.dct8x8(blocks, block_n=block_n, interpret=interpret)


def dct8x8(blocks, *, block_n: int | None = None):
    if _take_reference("dct8x8"):
        return _ref.dct8x8(blocks)
    return _dct8x8_c(blocks, block_n=block_n, interpret=_interp("dct8x8"))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _rmsnorm_c(x, scale, *, block_rows, interpret):
    return _rmsnorm.rmsnorm(x, scale, block_rows=block_rows,
                            interpret=interpret)


def rmsnorm(x, scale, *, block_rows: int | None = None):
    if _take_reference("rmsnorm"):
        return _ref.rmsnorm(x, scale)
    return _rmsnorm_c(x, scale, block_rows=block_rows,
                      interpret=_interp("rmsnorm"))


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def _flash_attention_c(q, k, v, *, causal, bq, bk, interpret):
    return _fa.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                               interpret=interpret)


def _ref_flash_attention(q, k, v, *, causal: bool = True, **_):
    g = q.shape[1] // k.shape[1]
    return _ref.flash_attention(q, jnp.repeat(k, g, axis=1),
                                jnp.repeat(v, g, axis=1), causal=causal)


def flash_attention(q, k, v, *, causal: bool = True, bq: int | None = None,
                    bk: int | None = None):
    if _take_reference("flash_attention"):
        return _ref_flash_attention(q, k, v, causal=causal)
    return _flash_attention_c(q, k, v, causal=causal, bq=bq, bk=bk,
                              interpret=_interp("flash_attention"))


# ----------------------------------------------------------------------------
# Fused kernels: Pallas forward, reference-composition backward
# ----------------------------------------------------------------------------


def _ref_rmsnorm_matmul(x, scale, w, **_):
    return jnp.dot(_ref.rmsnorm(x, scale), w,
                   preferred_element_type=jnp.float32).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _rmsnorm_matmul_p(blocks: tuple, interpret: bool, x, scale, w):
    return _fused.rmsnorm_matmul(x, scale, w, interpret=interpret,
                                 **dict(blocks))


def _rmsnorm_matmul_fwd(blocks, interpret, x, scale, w):
    return _rmsnorm_matmul_p(blocks, interpret, x, scale, w), (x, scale, w)


def _rmsnorm_matmul_bwd(blocks, interpret, res, g):
    _, vjp = jax.vjp(_ref_rmsnorm_matmul, *res)
    return vjp(g)


_rmsnorm_matmul_p.defvjp(_rmsnorm_matmul_fwd, _rmsnorm_matmul_bwd)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def _rmsnorm_matmul_c(x, scale, w, *, bm, bn, interpret):
    return _rmsnorm_matmul_p((("bm", bm), ("bn", bn)), interpret, x, scale, w)


def rmsnorm_matmul(x, scale, w, *, bm: int | None = None,
                   bn: int | None = None):
    """matmul(rmsnorm(x, scale), w); the normed x never round-trips HBM."""
    if _take_reference("rmsnorm_matmul"):
        return _ref_rmsnorm_matmul(x, scale, w)
    return _rmsnorm_matmul_c(x, scale, w, bm=bm, bn=bn,
                             interpret=_interp("rmsnorm_matmul"))


def _ref_matmul_bias_act(act: str, a, b, bias):
    h = jnp.dot(a, b, preferred_element_type=jnp.float32) \
        + bias.astype(jnp.float32)
    return _fused.ACTIVATIONS[act](h).astype(a.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _matmul_bias_act_p(act: str, blocks: tuple, interpret: bool, a, b, bias):
    return _fused.matmul_bias_act(a, b, bias, act=act, interpret=interpret,
                                  **dict(blocks))


def _matmul_bias_act_fwd(act, blocks, interpret, a, b, bias):
    return _matmul_bias_act_p(act, blocks, interpret, a, b, bias), (a, b, bias)


def _matmul_bias_act_bwd(act, blocks, interpret, res, g):
    _, vjp = jax.vjp(functools.partial(_ref_matmul_bias_act, act), *res)
    return vjp(g)


_matmul_bias_act_p.defvjp(_matmul_bias_act_fwd, _matmul_bias_act_bwd)


@functools.partial(jax.jit,
                   static_argnames=("act", "bm", "bn", "bk", "interpret"))
def _matmul_bias_act_c(a, b, bias, *, act, bm, bn, bk, interpret):
    return _matmul_bias_act_p(act, (("bm", bm), ("bn", bn), ("bk", bk)),
                              interpret, a, b, bias)


def matmul_bias_act(a, b, bias, *, act: str = "gelu", bm: int | None = None,
                    bn: int | None = None, bk: int | None = None):
    """act(a @ b + bias) with the epilogue applied before writeback."""
    if _take_reference("matmul_bias_act"):
        return _ref_matmul_bias_act(act, a, b, bias)
    return _matmul_bias_act_c(a, b, bias, act=act, bm=bm, bn=bn, bk=bk,
                              interpret=_interp("matmul_bias_act"))


def _ref_matmul_residual_add(a, b, res, **_):
    return (jnp.dot(a, b, preferred_element_type=jnp.float32)
            + res.astype(jnp.float32)).astype(a.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _matmul_residual_add_p(blocks: tuple, interpret: bool, a, b, res):
    return _fused.matmul_residual_add(a, b, res, interpret=interpret,
                                      **dict(blocks))


def _matmul_residual_add_fwd(blocks, interpret, a, b, res):
    return _matmul_residual_add_p(blocks, interpret, a, b, res), (a, b, res)


def _matmul_residual_add_bwd(blocks, interpret, res_, g):
    _, vjp = jax.vjp(_ref_matmul_residual_add, *res_)
    return vjp(g)


_matmul_residual_add_p.defvjp(_matmul_residual_add_fwd,
                              _matmul_residual_add_bwd)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _matmul_residual_add_c(a, b, res, *, bm, bn, bk, interpret):
    return _matmul_residual_add_p((("bm", bm), ("bn", bn), ("bk", bk)),
                                  interpret, a, b, res)


def matmul_residual_add(a, b, res, *, bm: int | None = None,
                        bn: int | None = None, bk: int | None = None):
    """a @ b + res; the matmul output never round-trips HBM."""
    if _take_reference("matmul_residual_add"):
        return _ref_matmul_residual_add(a, b, res)
    return _matmul_residual_add_c(a, b, res, bm=bm, bn=bn, bk=bk,
                                  interpret=_interp("matmul_residual_add"))


def _ref_flash_attention_proj(causal: bool, q, k, v, wo):
    g = q.shape[1] // k.shape[1]
    o = _ref.flash_attention(q, jnp.repeat(k, g, axis=1),
                             jnp.repeat(v, g, axis=1), causal=causal)
    return jnp.einsum("bhsk,hkd->bsd", o, wo).astype(q.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _flash_attention_proj_p(causal: bool, blocks: tuple, interpret: bool,
                            q, k, v, wo):
    return _fused.flash_attention_proj(q, k, v, wo, causal=causal,
                                       interpret=interpret, **dict(blocks))


def _flash_attention_proj_fwd(causal, blocks, interpret, q, k, v, wo):
    return (_flash_attention_proj_p(causal, blocks, interpret, q, k, v, wo),
            (q, k, v, wo))


def _flash_attention_proj_bwd(causal, blocks, interpret, res, g):
    _, vjp = jax.vjp(functools.partial(_ref_flash_attention_proj, causal),
                     *res)
    return vjp(g)


_flash_attention_proj_p.defvjp(_flash_attention_proj_fwd,
                               _flash_attention_proj_bwd)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def _flash_attention_proj_c(q, k, v, wo, *, causal, bq, bk, interpret):
    return _flash_attention_proj_p(causal, (("bq", bq), ("bk", bk)),
                                   interpret, q, k, v, wo)


def flash_attention_proj(q, k, v, wo, *, causal: bool = True,
                         bq: int | None = None, bk: int | None = None):
    """Flash attention with the output projection fused across heads."""
    if _take_reference("flash_attention_proj"):
        return _ref_flash_attention_proj(causal, q, k, v, wo)
    return _flash_attention_proj_c(q, k, v, wo, causal=causal, bq=bq, bk=bk,
                                   interpret=_interp("flash_attention_proj"))


# ----------------------------------------------------------------------------
# Descriptor registration
# ----------------------------------------------------------------------------


def _shapes_axpy(alpha, x, y):
    return {"m": x.shape[0], "n": x.shape[1]}


def _shapes_dotp(x, y):
    return {"m": x.shape[0], "n": x.shape[1]}


def _shapes_matmul(a, b):
    return {"m": a.shape[0], "k": a.shape[1], "n": b.shape[1]}


def _shapes_conv2d(x, w):
    return {"h": x.shape[0], "w": x.shape[1]}


def _shapes_dct8x8(blocks):
    return {"n": blocks.shape[0]}


def _shapes_rmsnorm(x, scale):
    return {"m": x.shape[0], "d": x.shape[1]}


def _shapes_flash_attention(q, k, v):
    b, h, s, hd = q.shape
    return {"b": b, "h": h, "kv": k.shape[1], "s": s, "hd": hd}


def _shapes_rmsnorm_matmul(x, scale, w):
    return {"m": x.shape[0], "k": x.shape[1], "n": w.shape[1]}


def _shapes_matmul_epilogue(a, b, extra):
    return {"m": a.shape[0], "k": a.shape[1], "n": b.shape[1]}


def _shapes_flash_attention_proj(q, k, v, wo):
    b, h, s, hd = q.shape
    return {"b": b, "h": h, "kv": k.shape[1], "s": s, "hd": hd,
            "dm": wo.shape[-1]}


# -- operand factories (the race's synthetic inputs, one per kernel) ---------


def _rand(seed: int, shape: tuple, dtype):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) \
        .astype(dtype)


def _mk_axpy(s, dt):
    return (2.0, _rand(0, (s["m"], s["n"]), dt), _rand(1, (s["m"], s["n"]), dt))


def _mk_dotp(s, dt):
    return (_rand(2, (s["m"], s["n"]), dt), _rand(3, (s["m"], s["n"]), dt))


def _mk_matmul(s, dt):
    return (_rand(4, (s["m"], s["k"]), dt), _rand(5, (s["k"], s["n"]), dt))


def _mk_conv2d(s, dt):
    return (_rand(6, (s["h"], s["w"]), dt), _rand(7, (3, 3), dt))


def _mk_dct8x8(s, dt):
    return (_rand(8, (s["n"], 8, 8), dt),)


def _mk_rmsnorm(s, dt):
    return (_rand(9, (s["m"], s["d"]), dt),
            _rand(10, (s["d"],), dt) * jnp.asarray(0.1, dt))


def _mk_flash_attention(s, dt):
    b, h, kv, sq, hd = (s[k] for k in ("b", "h", "kv", "s", "hd"))
    return (_rand(11, (b, h, sq, hd), dt), _rand(12, (b, kv, sq, hd), dt),
            _rand(13, (b, kv, sq, hd), dt))


def _mk_rmsnorm_matmul(s, dt):
    return (_rand(14, (s["m"], s["k"]), dt),
            _rand(15, (s["k"],), dt) * jnp.asarray(0.1, dt),
            _rand(16, (s["k"], s["n"]), dt))


def _mk_matmul_bias_act(s, dt):
    return (_rand(17, (s["m"], s["k"]), dt), _rand(18, (s["k"], s["n"]), dt),
            _rand(19, (s["n"],), dt))


def _mk_matmul_residual_add(s, dt):
    return (_rand(20, (s["m"], s["k"]), dt), _rand(21, (s["k"], s["n"]), dt),
            _rand(22, (s["m"], s["n"]), dt))


def _mk_flash_attention_proj(s, dt):
    b, h, kv, sq, hd, dm = (s[k] for k in ("b", "h", "kv", "s", "hd", "dm"))
    return (_rand(23, (b, h, sq, hd), dt), _rand(24, (b, kv, sq, hd), dt),
            _rand(25, (b, kv, sq, hd), dt),
            _rand(26, (h, hd, dm), dt) * jnp.asarray(0.1, dt))


def _ref_axpy(alpha, x, y, **_):
    return _ref.axpy(alpha, x, y)


def _ref_dotp(x, y, **_):
    return _ref.dotp(x, y)


def _ref_matmul(a, b, **_):
    return _ref.matmul(a, b)


def _ref_conv2d(x, w, **_):
    return _ref.conv2d_3x3(x, w)


def _ref_dct8x8(blocks, **_):
    return _ref.dct8x8(blocks)


def _ref_rmsnorm(x, scale, **_):
    return _ref.rmsnorm(x, scale)


def _ref_matmul_bias_act_op(a, b, bias, *, act: str = "gelu", **_):
    return _ref_matmul_bias_act(act, a, b, bias)


def _ref_flash_attention_proj_op(q, k, v, wo, *, causal: bool = True, **_):
    return _ref_flash_attention_proj(causal, q, k, v, wo)


# -- unfused compositions (the fused kernels' race opponents) ----------------
# Same math as the fused kernel but built from the primitive Pallas
# wrappers with jnp epilogues — i.e. what a caller would write without the
# fusion. Block kwargs are swallowed (`**_`): each primitive tunes itself
# through its own registry cell when called via the policy-dispatched
# wrappers, so the composition lane carries no blocking of its own.


def _comp_rmsnorm_matmul(x, scale, w, **_):
    return matmul(rmsnorm(x, scale), w)


def _comp_matmul_bias_act(a, b, bias, *, act: str = "gelu", **_):
    h = matmul(a, b).astype(jnp.float32) + bias.astype(jnp.float32)
    return _fused.ACTIVATIONS[act](h).astype(a.dtype)


def _comp_matmul_residual_add(a, b, res, **_):
    return (matmul(a, b).astype(jnp.float32)
            + res.astype(jnp.float32)).astype(a.dtype)


def _comp_flash_attention_proj(q, k, v, wo, *, causal: bool = True, **_):
    o = flash_attention(q, k, v, causal=causal)
    return jnp.einsum("bhsk,hkd->bsd", o, wo).astype(q.dtype)


for _desc in (
    OpDescriptor("axpy", axpy, _shapes_axpy, _ref_axpy, streamed_operand=1,
                 operands=_mk_axpy),
    OpDescriptor("dotp", dotp, _shapes_dotp, _ref_dotp, operands=_mk_dotp),
    OpDescriptor("matmul", matmul, _shapes_matmul, _ref_matmul,
                 operands=_mk_matmul),
    OpDescriptor("conv2d", conv2d_3x3, _shapes_conv2d, _ref_conv2d,
                 operands=_mk_conv2d),
    OpDescriptor("dct8x8", dct8x8, _shapes_dct8x8, _ref_dct8x8,
                 operands=_mk_dct8x8),
    OpDescriptor("rmsnorm", rmsnorm, _shapes_rmsnorm, _ref_rmsnorm,
                 operands=_mk_rmsnorm),
    OpDescriptor("flash_attention", flash_attention, _shapes_flash_attention,
                 _ref_flash_attention, operands=_mk_flash_attention),
    OpDescriptor("rmsnorm_matmul", rmsnorm_matmul, _shapes_rmsnorm_matmul,
                 _ref_rmsnorm_matmul, fused=True,
                 operands=_mk_rmsnorm_matmul,
                 composition=_comp_rmsnorm_matmul),
    OpDescriptor("matmul_bias_act", matmul_bias_act, _shapes_matmul_epilogue,
                 _ref_matmul_bias_act_op, fused=True,
                 operands=_mk_matmul_bias_act,
                 composition=_comp_matmul_bias_act),
    OpDescriptor("matmul_residual_add", matmul_residual_add,
                 _shapes_matmul_epilogue, _ref_matmul_residual_add,
                 fused=True, operands=_mk_matmul_residual_add,
                 composition=_comp_matmul_residual_add),
    OpDescriptor("flash_attention_proj", flash_attention_proj,
                 _shapes_flash_attention_proj, _ref_flash_attention_proj_op,
                 fused=True, operands=_mk_flash_attention_proj,
                 composition=_comp_flash_attention_proj),
):
    register_op(_desc)
