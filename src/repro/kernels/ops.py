"""Public jit'd wrappers for the kernel suite.

Dispatch: real `pl.pallas_call` lowering on TPU; `interpret=True` (kernel
body executed op-by-op on CPU) everywhere else — numerics identical, which
is what the allclose tests against ref.py verify.
"""

from __future__ import annotations

import functools

import jax

from . import axpy as _axpy
from . import conv2d as _conv2d
from . import dct8x8 as _dct8x8
from . import dotp as _dotp
from . import flash_attention as _fa
from . import matmul as _matmul
from . import rmsnorm as _rmsnorm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a, b, *, bm: int = 256, bn: int = 256, bk: int = 256):
    return _matmul.matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=_interpret())


@jax.jit
def axpy(alpha, x, y):
    return _axpy.axpy(alpha, x, y, interpret=_interpret())


@jax.jit
def dotp(x, y):
    return _dotp.dotp(x, y, interpret=_interpret())


@jax.jit
def conv2d_3x3(x, w):
    return _conv2d.conv2d_3x3(x, w, interpret=_interpret())


@jax.jit
def dct8x8(blocks):
    return _dct8x8.dct8x8(blocks, interpret=_interpret())


@jax.jit
def rmsnorm(x, scale):
    return _rmsnorm.rmsnorm(x, scale, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 512,
                    bk: int = 512):
    return _fa.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                               interpret=_interpret())
