"""Public jit'd wrappers for the kernel suite.

Dispatch: real `pl.pallas_call` lowering on TPU; `interpret=True` (kernel
body executed op-by-op on CPU) everywhere else — numerics identical, which
is what the allclose tests against ref.py verify.

Every wrapper takes its block sizes as static kwargs (defaults match the
kernel modules); `tuned_call` routes through the pipeline-layer autotuner
(kernels/pipeline.py) + the configs registry, so callers get the
model-scored blocking for their exact shapes with one call.
"""

from __future__ import annotations

import functools

import jax

from . import axpy as _axpy
from . import conv2d as _conv2d
from . import dct8x8 as _dct8x8
from . import dotp as _dotp
from . import flash_attention as _fa
from . import matmul as _matmul
from . import pipeline as _pipeline
from . import rmsnorm as _rmsnorm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a, b, *, bm: int | None = None, bn: int | None = None,
           bk: int | None = None):
    return _matmul.matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_rows",))
def axpy(alpha, x, y, *, block_rows: int | None = None):
    return _axpy.axpy(alpha, x, y, block_rows=block_rows,
                      interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_rows",))
def dotp(x, y, *, block_rows: int | None = None):
    return _dotp.dotp(x, y, block_rows=block_rows, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_rows",))
def conv2d_3x3(x, w, *, block_rows: int | None = None):
    return _conv2d.conv2d_3x3(x, w, block_rows=block_rows,
                              interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_n",))
def dct8x8(blocks, *, block_n: int | None = None):
    return _dct8x8.dct8x8(blocks, block_n=block_n, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_rows",))
def rmsnorm(x, scale, *, block_rows: int | None = None):
    return _rmsnorm.rmsnorm(x, scale, block_rows=block_rows,
                            interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int | None = None,
                    bk: int | None = None):
    return _fa.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                               interpret=_interpret())


# ----------------------------------------------------------------------------
# Tuned dispatch
# ----------------------------------------------------------------------------

_WRAPPERS = {
    "axpy": axpy, "dotp": dotp, "matmul": matmul, "conv2d": conv2d_3x3,
    "dct8x8": dct8x8, "rmsnorm": rmsnorm, "flash_attention": flash_attention,
}


def wrapper_for(name: str):
    """Public name -> jit'd wrapper dispatch (same registry tuned_call uses)."""
    return _WRAPPERS[name]


def kernel_shapes(name: str, *operands) -> dict:
    """The pipeline-layer shape dict for a kernel's runtime operands.

    Operand order matches the public wrapper (alpha/weight operands
    included), so `kernel_shapes(name, *args)` pairs with
    `tuned_call(name, *args)`.
    """
    if name == "axpy":
        _, x, _ = operands
        return {"m": x.shape[0], "n": x.shape[1]}
    if name == "dotp":
        x, _ = operands
        return {"m": x.shape[0], "n": x.shape[1]}
    if name == "matmul":
        a, b = operands
        return {"m": a.shape[0], "k": a.shape[1], "n": b.shape[1]}
    if name == "conv2d":
        x, _ = operands
        return {"h": x.shape[0], "w": x.shape[1]}
    if name == "dct8x8":
        (blocks,) = operands
        return {"n": blocks.shape[0]}
    if name == "rmsnorm":
        x, _ = operands
        return {"m": x.shape[0], "d": x.shape[1]}
    if name == "flash_attention":
        q, k, _ = operands
        b, h, s, hd = q.shape
        return {"b": b, "h": h, "kv": k.shape[1], "s": s, "hd": hd}
    raise KeyError(name)


# index of the main *streamed* operand per kernel — the one whose dtype
# sets the VMEM tile footprint (weights/scales/alpha ride along)
_STREAMED_OPERAND = {
    "axpy": 1, "dotp": 0, "matmul": 0, "conv2d": 0, "dct8x8": 0,
    "rmsnorm": 0, "flash_attention": 0,
}


def tuned_call(name: str, *operands, **kwargs):
    """Run a kernel with autotuned (registry-cached) block sizes."""
    shapes = kernel_shapes(name, *operands)
    dtype_bytes = operands[_STREAMED_OPERAND[name]].dtype.itemsize
    blocks = _pipeline.tuned_blocks(name, shapes, dtype_bytes=dtype_bytes)
    return _WRAPPERS[name](*operands, **blocks, **kwargs)
