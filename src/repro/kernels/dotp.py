"""dotp — vector dot product with cross-grid accumulation.

The paper's second memory-bound kernel. The reduction accumulates into a
(1, 1) output block revisited by every grid step ("arbitrary" semantics =
sequential on TPU), mirroring MemPool's per-core partial sums + final
reduction tree. Expressed on the shared tile-pipeline layer: the revisited
output block is the register tile, carried across the sequential axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import pipeline as pp


def _dotp_kernel(x_ref, y_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(x_ref[...].astype(jnp.float32)
                          * y_ref[...].astype(jnp.float32))[None, None]


def build_pipeline(m: int, n: int, *, block_rows: int | None = None,
                   dtype_bytes: int = 4) -> pp.KernelPipeline:
    br = pp.resolve_block(m, block_rows, default=512)
    return pp.KernelPipeline(
        name="dotp",
        body=_dotp_kernel,
        grid=(pp.GridAxis("rows", m // br, "arbitrary"),),
        in_tiles=[
            pp.TileSpec((br, n), lambda i: (i, 0)),
            pp.TileSpec((br, n), lambda i: (i, 0)),
        ],
        out_tiles=pp.TileSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        cost=traffic({"m": m, "n": n}, {"block_rows": br}, dtype_bytes),
    )


def dotp(x: jax.Array, y: jax.Array, *, block_rows: int | None = None,
         interpret: bool = False) -> jax.Array:
    """x, y: (M, N); returns scalar f32 sum(x*y)."""
    m, n = x.shape
    pipe = build_pipeline(m, n, block_rows=block_rows,
                          dtype_bytes=x.dtype.itemsize)
    return pipe(x, y, interpret=interpret)[0, 0]


# -- pipeline-layer contract --------------------------------------------------

def traffic(shapes: dict, blocks: dict, dtype_bytes: int = 4) -> pp.Traffic:
    m, n = shapes["m"], shapes["n"]
    br = min(blocks["block_rows"], m)
    moved = 2 * m * n * dtype_bytes + 4
    return pp.Traffic(
        flops=2.0 * m * n,
        hbm_bytes=float(moved),
        ideal_bytes=float(moved),
        grid_steps=m // br,
        vmem_bytes=2 * 2 * br * n * dtype_bytes,
    )


def tune_space(shapes: dict):
    for br in pp.block_candidates(shapes["m"], align=8):
        yield {"block_rows": br}


pp.register(pp.KernelDef(
    name="dotp", traffic=traffic, tune_space=tune_space,
    default_blocks=lambda shapes: {"block_rows": pp.snap_block(shapes["m"], 512)}))
