"""dotp — vector dot product with cross-grid accumulation.

The paper's second memory-bound kernel. The reduction accumulates into a
(1, 1) output block revisited by every grid step ("arbitrary" semantics =
sequential on TPU), mirroring MemPool's per-core partial sums + final
reduction tree.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dotp_kernel(x_ref, y_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(x_ref[...].astype(jnp.float32)
                          * y_ref[...].astype(jnp.float32))[None, None]


def dotp(x: jax.Array, y: jax.Array, *, block_rows: int = 512,
         interpret: bool = False) -> jax.Array:
    """x, y: (M, N); returns scalar f32 sum(x*y)."""
    m, n = x.shape
    br = min(block_rows, m)
    assert m % br == 0
    out = pl.pallas_call(
        _dotp_kernel,
        grid=(m // br,),
        in_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((br, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x, y)
    return out[0, 0]
