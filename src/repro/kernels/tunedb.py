"""TuneDB — a persistent, shareable database of timed kernel tunes.

MemPool's efficiency story only holds because its kernel/interconnect
mappings are *measured* per workload, not modeled; the Flavors follow-up
makes the same point for functional-unit configs. The in-memory analogue
here is `configs.registry.KERNEL_TUNES` — this module gives those records
a disk life so the measurement is paid once per (backend, kernel, shape,
dtype, policy-mode) key and every later process (a second benchmark run,
a CI job restored from `actions/cache`) warm-starts instead of re-racing.

File format (schema-versioned JSON; anything unreadable, corrupt, or from
another schema version is *ignored* — the caller falls back to cold
autotune, never crashes):

    {"version": 1,
     "records": [{"backend": "cpu", "mode": "tuned", "kernel": "matmul",
                  "shape_key": "b4_k512_m512_n512",
                  "blocks": [["bk", 128], ["bm", 128], ["bn", 128]],
                  "default_blocks": [...], "modeled_seconds": ...,
                  "default_modeled_seconds": ..., "saved_bytes": 0.0,
                  "measured_us": 241.7, "default_us": 363.2,
                  "source": "timed"}, ...]}

Environment knobs (all optional):

  REPRO_TUNE_DB      path of the default active DB; unset -> no disk
                     persistence (tests stay hermetic by default)
  REPRO_TUNE_MODE    "timed" (default: race top-N candidates on device),
                     "modeled" (legacy score-only pick), or
                     "frozen" (CI determinism: never race, never write —
                     misses take the modeled pick)

`Cluster` owns a TuneDB handle (constructed from `tune_db=` or the env),
warm-starts KERNEL_TUNES from it on construction, and installs it as the
active DB so `pipeline.autotune` writes new races through.
"""

from __future__ import annotations

import contextlib
import json
import os
import tempfile
from pathlib import Path
from typing import Iterator

from repro.configs import registry

SCHEMA_VERSION = 1

TUNE_MODES = ("timed", "modeled", "frozen")

_DB_ENV = "REPRO_TUNE_DB"
_MODE_ENV = "REPRO_TUNE_MODE"


def tune_mode(override: str | None = None) -> str:
    """Resolve the active tuning mode: explicit override > the active
    KernelPolicy's `tuning` field > REPRO_TUNE_MODE > "timed"."""
    if override is not None:
        if override not in TUNE_MODES:
            raise ValueError(f"unknown tune mode {override!r}; "
                             f"expected one of {TUNE_MODES}")
        return override
    from repro.cluster.policy import current_policy
    pol_tuning = getattr(current_policy(), "tuning", "auto")
    if pol_tuning and pol_tuning != "auto":
        return pol_tuning
    mode = os.environ.get(_MODE_ENV, "").strip() or "timed"
    if mode not in TUNE_MODES:
        raise ValueError(f"{_MODE_ENV}={mode!r}: expected one of "
                         f"{TUNE_MODES}")
    return mode


def _record_to_json(rec: registry.KernelTuneRecord, backend: str,
                    mode: str) -> dict:
    return {
        "backend": backend,
        "mode": mode,
        "kernel": rec.kernel,
        "shape_key": rec.shape_key,
        "blocks": [list(kv) for kv in rec.blocks],
        "modeled_seconds": rec.modeled_seconds,
        "default_blocks": [list(kv) for kv in rec.default_blocks],
        "default_modeled_seconds": rec.default_modeled_seconds,
        "saved_bytes": rec.saved_bytes,
        "measured_us": rec.measured_us,
        "default_us": rec.default_us,
        "source": rec.source,
        "route": rec.route,
    }


def _record_from_json(d: dict) -> registry.KernelTuneRecord:
    return registry.KernelTuneRecord(
        kernel=d["kernel"],
        shape_key=d["shape_key"],
        blocks=tuple((str(k), int(v)) for k, v in d["blocks"]),
        modeled_seconds=float(d["modeled_seconds"]),
        default_blocks=tuple((str(k), int(v))
                             for k, v in d.get("default_blocks", ())),
        default_modeled_seconds=float(d.get("default_modeled_seconds", 0.0)),
        saved_bytes=float(d.get("saved_bytes", 0.0)),
        measured_us=float(d.get("measured_us", 0.0)),
        default_us=float(d.get("default_us", 0.0)),
        source=str(d.get("source", "modeled")),
        route=str(d.get("route", "fused")),
    )


class TuneDB:
    """JSON disk cache of timed tune records, keyed by
    (backend, mode, kernel, shape_key) — shape_key already carries dtype.

    `frozen=True` makes the DB read-only: `record()` and `save()` are
    no-ops (counted in `write_skips`), which is the CI-determinism mode.
    A missing, corrupt, or stale-schema file loads as empty (counted in
    `load_errors`) so callers always fall back to cold autotune.
    """

    def __init__(self, path: str | os.PathLike, *, frozen: bool = False):
        self.path = Path(path)
        self.frozen = frozen
        # key -> raw json record dict (kept verbatim so unknown backends'
        # records survive a load/save round-trip untouched)
        self._records: dict[tuple[str, str, str, str], dict] = {}
        self.loads = 0          # records loaded from disk
        self.stores = 0         # records written through
        self.write_skips = 0    # frozen writes refused
        self.load_errors = 0    # corrupt/stale files ignored
        self._load()

    @staticmethod
    def _key(d: dict) -> tuple[str, str, str, str]:
        return (d["backend"], d["mode"], d["kernel"], d["shape_key"])

    def _load(self) -> None:
        if not self.path.exists():
            return
        try:
            raw = json.loads(self.path.read_text())
            if raw.get("version") != SCHEMA_VERSION:
                raise ValueError(f"schema version {raw.get('version')!r}")
            for d in raw["records"]:
                _record_from_json(d)               # validates the shape
                self._records[self._key(d)] = d
            self.loads = len(self._records)
        except Exception:
            # corrupt / stale / truncated DB: start cold, never crash
            self._records = {}
            self.loads = 0
            self.load_errors += 1

    # -- queries --------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._records)

    def get(self, backend: str, mode: str, kernel: str,
            shape_key: str) -> registry.KernelTuneRecord | None:
        d = self._records.get((backend, mode, kernel, shape_key))
        return _record_from_json(d) if d is not None else None

    def records(self, backend: str | None = None,
                mode: str | None = None) -> Iterator[registry.KernelTuneRecord]:
        for (b, m, _, _), d in sorted(self._records.items()):
            if backend is not None and b != backend:
                continue
            if mode is not None and m != mode:
                continue
            yield _record_from_json(d)

    # -- mutation -------------------------------------------------------------
    def record(self, rec: registry.KernelTuneRecord, *, backend: str,
               mode: str, save: bool = True) -> None:
        """Store one tune record and (unless frozen) write the file."""
        if self.frozen:
            self.write_skips += 1
            return
        d = _record_to_json(rec, backend, mode)
        self._records[self._key(d)] = d
        self.stores += 1
        if save:
            self.save()

    def save(self) -> None:
        """Atomic write (tmp + rename) so a killed process never leaves a
        truncated DB for the next run to trip over."""
        if self.frozen:
            self.write_skips += 1
            return
        payload = {"version": SCHEMA_VERSION,
                   "records": [self._records[k]
                               for k in sorted(self._records)]}
        self.path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.path.parent,
                                   prefix=self.path.name + ".")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1)
            os.replace(tmp, self.path)
        except BaseException:
            with contextlib.suppress(OSError):
                os.unlink(tmp)
            raise

    # -- warm-start -----------------------------------------------------------
    def warm_start(self, *, backend: str, mode: str) -> int:
        """Register every matching record into KERNEL_TUNES (source "db")
        so later `tuned_call`s hit instead of racing. Returns the count.

        In-memory records win: a record already in KERNEL_TUNES for the
        same (kernel, shape_key) — e.g. a fresher race from this process —
        is not overwritten by the disk copy.
        """
        n = 0
        for rec in self.records(backend=backend, mode=mode):
            if registry.get_kernel_tune(rec.kernel, rec.shape_key) is None:
                registry.register_kernel_tune(
                    rec if rec.source == "db" else
                    _dataclass_replace(rec, source="db"))
                n += 1
        return n

    def describe(self) -> dict:
        """JSON-able snapshot for Program.report() / bench records."""
        return {"path": str(self.path), "frozen": self.frozen,
                "entries": len(self._records), "loads": self.loads,
                "stores": self.stores, "write_skips": self.write_skips,
                "load_errors": self.load_errors}


def _dataclass_replace(rec, **kw):
    import dataclasses
    return dataclasses.replace(rec, **kw)


# ----------------------------------------------------------------------------
# The active DB (what pipeline.autotune writes through)
# ----------------------------------------------------------------------------

_UNSET = object()
_ACTIVE: "TuneDB | None | object" = _UNSET


def _env_db() -> TuneDB | None:
    path = os.environ.get(_DB_ENV, "").strip()
    if not path:
        return None
    return TuneDB(path, frozen=tune_mode() == "frozen")


def active_db() -> TuneDB | None:
    """The DB autotune write-through targets: the one installed with
    `set_active_db` (usually by Cluster), else the REPRO_TUNE_DB env one,
    else None (no persistence)."""
    global _ACTIVE
    if _ACTIVE is _UNSET:
        _ACTIVE = _env_db()
    return _ACTIVE  # type: ignore[return-value]


def set_active_db(db: TuneDB | None) -> None:
    global _ACTIVE
    _ACTIVE = db


def reset_active_db() -> None:
    """Forget the cached active DB; next `active_db()` re-reads the env."""
    global _ACTIVE
    _ACTIVE = _UNSET


@contextlib.contextmanager
def use_db(db: TuneDB | None) -> Iterator[TuneDB | None]:
    """Scope `db` as the active write-through target (tests)."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = db
    try:
        yield db
    finally:
        _ACTIVE = prev


def resolve_db(spec: "TuneDB | str | os.PathLike | None",
               *, frozen: bool | None = None) -> TuneDB | None:
    """Coerce a Cluster's `tune_db=` argument: a TuneDB passes through, a
    path opens one, None falls back to the env default (which may be
    None too). `frozen` overrides the opened DB's mode."""
    if spec is None:
        db = active_db()
    elif isinstance(spec, TuneDB):
        db = spec
    else:
        db = TuneDB(spec, frozen=tune_mode() == "frozen")
    if db is not None and frozen is not None:
        db.frozen = frozen
    return db
