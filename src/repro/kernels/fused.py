"""Fused producer–consumer kernels for the transformer hot loop.

Every kernel here is a producer stitched into a consumer's grid through the
tile-pipeline fusion hooks (kernels/pipeline.py): the producer's output tile
never exists in HBM — it is computed in VMEM in the same grid step that
consumes it, exactly the MemPool story of intermediate tiles living in
shared L1 until the whole cluster is done with them.

  rmsnorm_matmul       norm folded into the matmul A-tile *prologue*
                       (requires the full reduction dim resident per tile —
                       checked via check_fusable, the "producer tile fully
                       consumed per step" condition)
  matmul_bias_act      bias + GELU/SiLU applied in the output *epilogue*
                       after the K loop, before writeback
  matmul_residual_add  residual tile streamed in and added in the epilogue
  flash_attention_proj flash attention with the output projection fused:
                       per-head outputs are projected and accumulated across
                       heads in a VMEM register tile; the (B, H, S, hd)
                       attention output never touches HBM

Each registers a `KernelDef` so the autotuner scores fused candidates
directly; their `Traffic.saved_bytes` records the intermediate write+read
the fusion eliminated (the term the fused roofline drops).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import flash_attention as _fa
from . import matmul as _mm
from . import pipeline as pp
from . import rmsnorm as _rn

F32 = jnp.float32

ACTIVATIONS = {
    "none": lambda x: x,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


# ----------------------------------------------------------------------------
# rmsnorm_matmul — norm in the A-tile prologue
# ----------------------------------------------------------------------------

def _norm_tile(a, scale, eps: float):
    """Row-normalize one (bm, k) tile; valid only when k is the full row."""
    af = a.astype(F32)
    var = jnp.mean(af * af, axis=-1, keepdims=True)
    out = af * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(F32))
    return out.astype(a.dtype)


def build_rmsnorm_matmul(m: int, n: int, k: int, dtype, *, eps: float = 1e-6,
                         bm: int | None = None, bn: int | None = None,
                         dtype_bytes: int = 4) -> pp.KernelPipeline:
    bm = pp.resolve_block(m, bm, default=256)
    bn = pp.resolve_block(n, bn, default=256)
    # bk = k: the prologue normalizes whole rows, so the A tile must hold
    # the full reduction dim. check_fusable enforces it against the real
    # producer/consumer TileSpecs rather than trusting this constructor.
    consumer = _mm.build_pipeline(m, n, k, dtype, bm=bm, bn=bn, bk=k,
                                  dtype_bytes=dtype_bytes)
    producer = _rn.build_pipeline(m, k, dtype, eps=eps, block_rows=bm,
                                  dtype_bytes=dtype_bytes)
    pp.check_fusable(producer.out_tiles[0], consumer.in_tiles[0],
                     full_dims=(1,), dims=(k,))
    return consumer.fuse(
        name="rmsnorm_matmul",
        prologues={0: lambda a, s_ref: _norm_tile(a, s_ref[...], eps)},
        extra_tiles=[pp.TileSpec((k,), lambda i, j, s: (0,))],
        cost=traffic_rmsnorm_matmul({"m": m, "n": n, "k": k},
                                    {"bm": bm, "bn": bn}, dtype_bytes),
    )


def rmsnorm_matmul(x: jax.Array, scale: jax.Array, w: jax.Array, *,
                   eps: float = 1e-6, bm: int | None = None,
                   bn: int | None = None, interpret: bool = False) -> jax.Array:
    """matmul(rmsnorm(x, scale), w) in one HBM pass over x.

    x: (M, K); scale: (K,); w: (K, N). The normalized x never exists in HBM.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert scale.shape == (k,), scale.shape
    pipe = build_rmsnorm_matmul(m, n, k, x.dtype, eps=eps, bm=bm, bn=bn,
                                dtype_bytes=x.dtype.itemsize)
    return pipe(x, w, scale, interpret=interpret)


def traffic_rmsnorm_matmul(shapes: dict, blocks: dict,
                           dtype_bytes: int = 4) -> pp.Traffic:
    m, n, k = shapes["m"], shapes["n"], shapes["k"]
    bm = min(blocks["bm"], m)
    bn = min(blocks["bn"], n)
    consumer = _mm.traffic(shapes, {"bm": bm, "bn": bn, "bk": k}, dtype_bytes)
    producer = _rn.traffic({"m": m, "d": k}, {"block_rows": bm}, dtype_bytes)
    return pp.fused_traffic(consumer, producer,
                            intermediate_bytes=float(m * k * dtype_bytes),
                            extra_vmem=2 * k * dtype_bytes,
                            refetch=n // bn)


def _tune_rmsnorm_matmul(shapes: dict):
    m, n = shapes["m"], shapes["n"]
    for bm in pp.block_candidates(m, align=pp.mxu_align(m), cap=6):
        for bn in pp.block_candidates(n, align=pp.mxu_align(n), cap=6):
            yield {"bm": bm, "bn": bn}


pp.register(pp.KernelDef(
    name="rmsnorm_matmul", traffic=traffic_rmsnorm_matmul,
    tune_space=_tune_rmsnorm_matmul,
    default_blocks=lambda s: {"bm": pp.snap_block(s["m"], 256),
                              "bn": pp.snap_block(s["n"], 256)}))


# ----------------------------------------------------------------------------
# matmul_bias_act — bias + activation in the output epilogue
# ----------------------------------------------------------------------------

def build_matmul_bias_act(m: int, n: int, k: int, dtype, *, act: str = "gelu",
                          bm: int | None = None, bn: int | None = None,
                          bk: int | None = None,
                          dtype_bytes: int = 4) -> pp.KernelPipeline:
    act_fn = ACTIVATIONS[act]
    consumer = _mm.build_pipeline(m, n, k, dtype, bm=bm, bn=bn, bk=bk,
                                  dtype_bytes=dtype_bytes)
    bn_r = consumer.out_tiles[0].block[1]
    return consumer.fuse(
        name="matmul_bias_act",
        epilogue=lambda o, b_ref: act_fn(o.astype(F32)
                                         + b_ref[...].astype(F32)),
        extra_tiles=[pp.TileSpec((bn_r,), lambda i, j, s: (j,))],
        cost=traffic_matmul_bias_act(
            {"m": m, "n": n, "k": k},
            {"bm": consumer.out_tiles[0].block[0], "bn": bn_r,
             "bk": consumer.in_tiles[0].block[1]},
            dtype_bytes, act=act),
    )


def matmul_bias_act(a: jax.Array, b: jax.Array, bias: jax.Array, *,
                    act: str = "gelu", bm: int | None = None,
                    bn: int | None = None, bk: int | None = None,
                    interpret: bool = False) -> jax.Array:
    """act(a @ b + bias) without the pre-activation round-trip.

    a: (M, K); b: (K, N); bias: (N,); act in {"none", "gelu", "silu"}.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and bias.shape == (n,), (a.shape, b.shape, bias.shape)
    pipe = build_matmul_bias_act(m, n, k, a.dtype, act=act, bm=bm, bn=bn,
                                 bk=bk, dtype_bytes=a.dtype.itemsize)
    return pipe(a, b, bias, interpret=interpret)


def traffic_matmul_bias_act(shapes: dict, blocks: dict, dtype_bytes: int = 4,
                            *, act: str = "gelu") -> pp.Traffic:
    m, n = shapes["m"], shapes["n"]
    consumer = _mm.traffic(shapes, blocks, dtype_bytes)
    producer = pp.Traffic(
        flops=2.0 * m * n,                       # bias add + activation
        hbm_bytes=float((2 * m * n + n) * dtype_bytes),
        ideal_bytes=float((2 * m * n + n) * dtype_bytes),
        grid_steps=1, vmem_bytes=0,
        transcendentals=float(m * n) if act != "none" else 0.0)
    bn = min(blocks["bn"], n)
    return pp.fused_traffic(consumer, producer,
                            intermediate_bytes=float(m * n * dtype_bytes),
                            extra_vmem=2 * bn * dtype_bytes)


pp.register(pp.KernelDef(
    name="matmul_bias_act", traffic=traffic_matmul_bias_act,
    tune_space=_mm.tune_space,
    default_blocks=lambda s: {"bm": pp.snap_block(s["m"], 256),
                              "bn": pp.snap_block(s["n"], 256),
                              "bk": pp.snap_block(s["k"], 256)}))


# ----------------------------------------------------------------------------
# matmul_residual_add — residual tile streamed into the epilogue
# ----------------------------------------------------------------------------

def build_matmul_residual_add(m: int, n: int, k: int, dtype, *,
                              bm: int | None = None, bn: int | None = None,
                              bk: int | None = None,
                              dtype_bytes: int = 4) -> pp.KernelPipeline:
    consumer = _mm.build_pipeline(m, n, k, dtype, bm=bm, bn=bn, bk=bk,
                                  dtype_bytes=dtype_bytes)
    bm_r, bn_r = consumer.out_tiles[0].block
    # the residual tile must match the output tile exactly — same check the
    # prologue fusions make, from the consumer side
    pp.check_fusable(pp.TileSpec((bm_r, bn_r), lambda i, j, s: (i, j)),
                     consumer.out_tiles[0])
    return consumer.fuse(
        name="matmul_residual_add",
        epilogue=lambda o, r_ref: o.astype(F32) + r_ref[...].astype(F32),
        extra_tiles=[pp.TileSpec((bm_r, bn_r), lambda i, j, s: (i, j))],
        cost=traffic_matmul_residual_add(
            {"m": m, "n": n, "k": k},
            {"bm": bm_r, "bn": bn_r, "bk": consumer.in_tiles[0].block[1]},
            dtype_bytes),
    )


def matmul_residual_add(a: jax.Array, b: jax.Array, res: jax.Array, *,
                        bm: int | None = None, bn: int | None = None,
                        bk: int | None = None,
                        interpret: bool = False) -> jax.Array:
    """a @ b + res without the matmul output round-trip. res: (M, N)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and res.shape == (m, n), (a.shape, b.shape, res.shape)
    pipe = build_matmul_residual_add(m, n, k, a.dtype, bm=bm, bn=bn, bk=bk,
                                     dtype_bytes=a.dtype.itemsize)
    return pipe(a, b, res, interpret=interpret)


def traffic_matmul_residual_add(shapes: dict, blocks: dict,
                                dtype_bytes: int = 4) -> pp.Traffic:
    m, n = shapes["m"], shapes["n"]
    consumer = _mm.traffic(shapes, blocks, dtype_bytes)
    producer = pp.Traffic(
        flops=float(m * n),
        hbm_bytes=float(3 * m * n * dtype_bytes),   # read o + res, write out
        ideal_bytes=float(3 * m * n * dtype_bytes),
        grid_steps=1, vmem_bytes=0)
    bm = min(blocks["bm"], m)
    bn = min(blocks["bn"], n)
    return pp.fused_traffic(consumer, producer,
                            intermediate_bytes=float(m * n * dtype_bytes),
                            extra_vmem=2 * bm * bn * dtype_bytes)


pp.register(pp.KernelDef(
    name="matmul_residual_add", traffic=traffic_matmul_residual_add,
    tune_space=_mm.tune_space,
    default_blocks=lambda s: {"bm": pp.snap_block(s["m"], 256),
                              "bn": pp.snap_block(s["n"], 256),
                              "bk": pp.snap_block(s["k"], 256)}))


# ----------------------------------------------------------------------------
# flash_attention_proj — output projection fused across heads
# ----------------------------------------------------------------------------
#
# The head axis moves *inside* the q-block axis and becomes sequential, so
# a (bq, d_model) projection accumulator in VMEM scratch can sum per-head
# contributions o_h @ Wo[h] across the whole head loop; only the final
# (B, S, d_model) projection result is written to HBM. This is the epilogue
# idea applied where the "epilogue" is itself a reduction over a grid axis.

def _fa_proj_kernel(q_ref, k_ref, v_ref, wo_ref, o_ref,
                    m_ref, l_ref, acc_ref, pacc_ref, *,
                    scale: float, n_k: int, n_h: int, bq: int, bk: int,
                    causal: bool):
    i = pl.program_id(1)
    h = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(jnp.logical_and(h == 0, j == 0))
    def _init_proj():
        pacc_ref[...] = jnp.zeros_like(pacc_ref)

    @pl.when(j == 0)
    def _init_head():
        m_ref[...] = jnp.full_like(m_ref, _fa.NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                # (bq, hd)
    k = k_ref[0, 0]                                # (bk, hd)
    v = v_ref[0, 0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32) * scale
    if causal:
        qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, _fa.NEG)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=F32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == n_k - 1)
    def _project_head():
        o_head = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)   # (bq, hd) f32
        pacc_ref[...] += jax.lax.dot_general(
            o_head.astype(wo_ref.dtype), wo_ref[0],
            (((1,), (0,)), ((), ())), preferred_element_type=F32)

    @pl.when(jnp.logical_and(h == n_h - 1, j == n_k - 1))
    def _store():
        o_ref[0] = pacc_ref[...].astype(o_ref.dtype)


def build_flash_attention_proj(b: int, h: int, kv: int, s: int, hd: int,
                               dm: int, dtype, *, causal: bool = True,
                               bq: int | None = None, bk: int | None = None,
                               dtype_bytes: int = 4) -> pp.KernelPipeline:
    group = h // kv
    bq = pp.resolve_block(s, bq, default=512)
    bk = pp.resolve_block(s, bk, default=512)
    n_q, n_k = s // bq, s // bk
    body = functools.partial(_fa_proj_kernel, scale=hd ** -0.5, n_k=n_k,
                             n_h=h, bq=bq, bk=bk, causal=causal)
    return pp.KernelPipeline(
        name="flash_attention_proj",
        body=body,
        # heads sequential *inside* each q block so the projection
        # accumulator (the fused epilogue's register tile) carries across it
        grid=(pp.GridAxis("batch", b, "parallel"),
              pp.GridAxis("q", n_q, "parallel"),
              pp.GridAxis("heads", h, "arbitrary"),
              pp.GridAxis("kv", n_k, "arbitrary")),
        in_tiles=[
            pp.TileSpec((1, 1, bq, hd),
                        lambda b_, i, h_, j: (b_, h_, i, 0)),
            pp.TileSpec((1, 1, bk, hd),
                        lambda b_, i, h_, j: (b_, h_ // group, j, 0)),
            pp.TileSpec((1, 1, bk, hd),
                        lambda b_, i, h_, j: (b_, h_ // group, j, 0)),
            pp.TileSpec((1, hd, dm), lambda b_, i, h_, j: (h_, 0, 0)),
        ],
        out_tiles=pp.TileSpec((1, bq, dm), lambda b_, i, h_, j: (b_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, dm), dtype),
        scratch=[
            pltpu.VMEM((bq, 1), F32),
            pltpu.VMEM((bq, 1), F32),
            pltpu.VMEM((bq, hd), F32),
            pltpu.VMEM((bq, dm), F32),             # projection accumulator
        ],
        cost=traffic_flash_attention_proj(
            {"b": b, "h": h, "kv": kv, "s": s, "hd": hd, "dm": dm},
            {"bq": bq, "bk": bk}, dtype_bytes, causal=causal),
    )


def flash_attention_proj(q, k, v, wo, *, causal: bool = True,
                         bq: int | None = None, bk: int | None = None,
                         interpret: bool = False):
    """einsum("bhsk,hkd->bsd", attention(q, k, v), wo) in one kernel.

    q: (B, H, S, hd); k/v: (B, KV, S, hd); wo: (H, hd, d_model). The
    (B, H, S, hd) attention output never exists in HBM.
    """
    b, h, s, hd = q.shape
    kv = k.shape[1]
    dm = wo.shape[-1]
    assert wo.shape == (h, hd, dm), wo.shape
    pipe = build_flash_attention_proj(b, h, kv, s, hd, dm, q.dtype,
                                      causal=causal, bq=bq, bk=bk,
                                      dtype_bytes=q.dtype.itemsize)
    return pipe(q, k, v, wo, interpret=interpret)


def traffic_flash_attention_proj(shapes: dict, blocks: dict,
                                 dtype_bytes: int = 4, *,
                                 causal: bool = True) -> pp.Traffic:
    b, h, s, hd = shapes["b"], shapes["h"], shapes["s"], shapes["hd"]
    dm = shapes["dm"]
    base = _fa.traffic(shapes, blocks, dtype_bytes, causal=causal)
    bq = min(blocks["bq"], s)
    bk = min(blocks["bk"], s)
    n_q = s // bq
    o_bytes = b * h * s * hd * dtype_bytes       # the eliminated intermediate
    wo_stream = b * n_q * h * hd * dm * dtype_bytes
    out = b * s * dm * dtype_bytes
    wo_ideal = h * hd * dm * dtype_bytes
    extra_vmem = (2 * hd * dm * dtype_bytes      # wo tile, double-buffered
                  + 4 * bq * dm                  # f32 projection accumulator
                  + 2 * bq * dm * dtype_bytes    # (bq, dm) out replaces o tile
                  - 2 * bq * hd * dtype_bytes)
    return pp.Traffic(
        flops=base.flops + 2.0 * b * s * h * hd * dm,
        hbm_bytes=base.hbm_bytes - o_bytes + wo_stream + out,
        ideal_bytes=base.ideal_bytes - o_bytes + wo_ideal + out,
        grid_steps=base.grid_steps,
        vmem_bytes=base.vmem_bytes + extra_vmem,
        transcendentals=base.transcendentals,
        saved_bytes=2.0 * o_bytes,
    )


def _tune_fa_proj(shapes: dict):
    s = shapes["s"]
    for bq in pp.block_candidates(s, align=pp.mxu_align(s), cap=6):
        for bk in pp.block_candidates(s, align=pp.mxu_align(s), cap=6):
            yield {"bq": bq, "bk": bk}


pp.register(pp.KernelDef(
    name="flash_attention_proj", traffic=traffic_flash_attention_proj,
    tune_space=_tune_fa_proj,
    default_blocks=lambda s: {"bq": pp.snap_block(s["s"], 512),
                              "bk": pp.snap_block(s["s"], 512)}))


# ----------------------------------------------------------------------------
# Fused-vs-unfused traffic accounting (the benchmark / acceptance model)
# ----------------------------------------------------------------------------

def fused_vs_unfused(name: str, shapes: dict, blocks: dict | None = None,
                     dtype_bytes: int = 4) -> dict:
    """Modeled HBM bytes of one fused kernel vs its unfused composition."""
    defn = pp.KERNELS[name]
    blocks = blocks or defn.default_blocks(shapes)
    t = defn.traffic(shapes, blocks, dtype_bytes)
    unfused = t.hbm_bytes + t.saved_bytes
    return {"fused_bytes": t.hbm_bytes, "unfused_bytes": unfused,
            "saved_bytes": t.saved_bytes,
            "reduction": unfused / max(t.hbm_bytes, 1.0)}


def transformer_block_traffic(b: int, s: int, d: int, h: int, kv: int,
                              hd: int, d_ff: int, *, dtype_bytes: int = 2,
                              attn_chunk: int = 512) -> dict:
    """Modeled HBM bytes of one transformer block, fused vs unfused.

    Unfused = today's model path composed of isolated ops: rmsnorm kernel
    round-trips the normed activations, each matmul round-trips its output,
    and attention is the chunked jnp baseline that crosses HBM ~3x per
    score block (the flash_attention.hbm_traffic_bytes baseline model).
    Fused = rmsnorm_matmul for qkv/gate/up, flash_attention_proj for
    attention + output projection, matmul_residual_add for the down
    projection; remaining elementwise traffic identical on both sides.
    """
    m = b * s
    db = dtype_bytes
    qkv_cols = (h + 2 * kv) * hd

    def mm_bytes(mm_m, mm_k, mm_n):
        # compulsory matmul traffic (blocking-independent terms only, so the
        # comparison isolates what fusion changes)
        return (mm_m * mm_k + mm_k * mm_n + mm_m * mm_n) * db

    # --- unfused composition -------------------------------------------------
    attn = _fa.hbm_traffic_bytes(b, h, kv, s, hd, db)
    unfused = {
        "norm_attn": 2 * m * d * db + d * db,
        "qkv": mm_bytes(m, d, qkv_cols) + 2 * m * d * db,  # normed x read 3x
        "attention": attn["baseline_bytes"],
        "out_proj": mm_bytes(m, h * hd, d),
        "residual_attn": 3 * m * d * db,
        "norm_ffn": 2 * m * d * db + d * db,
        "gate_up": mm_bytes(m, d, d_ff) * 2 + m * d * db,  # normed x read 2x
        "act_mult": 3 * m * d_ff * db,
        "down": mm_bytes(m, d_ff, d),
        "residual_ffn": 3 * m * d * db,
    }

    # --- fused path ----------------------------------------------------------
    fa_shapes = {"b": b, "h": h, "kv": kv, "s": s, "hd": hd, "dm": d}
    fa_blocks = {"bq": pp.snap_block(s, attn_chunk),
                 "bk": pp.snap_block(s, attn_chunk)}
    fused = {
        # norm recomputed in the prologue per consumer; x read per consumer
        "qkv": mm_bytes(m, d, qkv_cols) + 2 * m * d * db + 3 * d * db,
        "attention_proj": traffic_flash_attention_proj(
            fa_shapes, fa_blocks, db).ideal_bytes,
        "residual_attn": 3 * m * d * db,
        "gate_up": mm_bytes(m, d, d_ff) * 2 + m * d * db + 2 * d * db,
        "act_mult": 3 * m * d_ff * db,
        "down_residual": mm_bytes(m, d_ff, d) + m * d * db,
    }
    u_total = float(sum(unfused.values()))
    f_total = float(sum(fused.values()))
    return {"unfused": unfused, "fused": fused,
            "unfused_bytes": u_total, "fused_bytes": f_total,
            "reduction": u_total / max(f_total, 1.0)}
