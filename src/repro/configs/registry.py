"""Architecture configs — the ten assigned architectures + the paper's own.

Each config is exact per the assignment table; `smoke()` returns a reduced
same-family variant for CPU tests. `input_specs()` returns ShapeDtypeStruct
stand-ins for every model input of a given workload shape (the multi-pod
dry-run lowers against these; no allocation happens).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | encdec | ssm | hybrid | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    norm: str = "rms"            # rms | layer
    ffn_kind: str = "swiglu"
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- attention window (SWA / local attention) ---
    window: int | None = None
    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 0             # frontend stub: precomputed frame embeddings
    # --- vlm ---
    cross_every: int = 0         # a cross-attn layer every N layers
    n_img_tokens: int = 0
    # --- hybrid/ssm block pattern, cycled over layers ---
    pattern: tuple[str, ...] = ("attn",)
    # --- recurrent dims ---
    lru_width: int = 0
    conv_width: int = 4
    # --- numerics / memory policy ---
    param_dtype: str = "bfloat16"
    moment_dtype: str = "float32"
    remat: str = "nothing"  # save layer inputs only: O(S^2) score blocks
    # must never be checkpointed (checkpoint_dots would hold them to bwd)
    attn_chunk: int = 1024
    attn_schedule: str = "auto"   # auto | masked | folded | banded
    grad_accum: int = 1           # microbatch steps per train step
    sub_quadratic: bool = False   # can run long_500k
    # per-arch sharding-rule overrides applied on top of the hybrid
    # addressing defaults (tuple of (logical_axis, mesh_axes|None) pairs)
    rules_overrides: tuple = ()
    # MoE dispatch locality (False = global/baseline, True = GShard groups;
    # see models/blocks.moe_apply and EXPERIMENTS.md §Perf H2/H3)
    moe_local_dispatch: bool = False
    # NOTE: the fused producer–consumer kernel route (kernels/fused.py) is
    # no longer a config bool — it is steered by repro.cluster.KernelPolicy
    # (mode="fused"), scoped via `with cluster.policy(...)` or pinned with
    # the step factories' `policy=` argument.

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def n_params(self) -> int:
        """Total parameter count (for MODEL_FLOPS = 6 N D)."""
        from repro.models import steps
        specs = steps.param_specs(self)
        leaves = jax.tree.leaves(
            specs, is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "logical"))
        total = 0
        for s in leaves:
            n = 1
            for d in s.shape:
                n *= d
            total += n
        return total

    def n_active_params(self) -> int:
        """Active parameters per token (MoE discounts inactive experts)."""
        total = self.n_params()
        if self.n_experts and self.top_k:
            from repro.models import steps
            specs = steps.param_specs(self)
            expert = 0
            for s in jax.tree.leaves(
                    specs["blocks"],
                    is_leaf=lambda x: hasattr(x, "logical")):
                if "expert" in (s.logical or ()):
                    n = 1
                    for d in s.shape:
                        n *= d
                    expert += n
            total = total - expert + expert * self.top_k // self.n_experts
        return total


# ----------------------------------------------------------------------------
# Workload shapes (assignment)
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ----------------------------------------------------------------------------
# The ten assigned architectures (exact per assignment table)
# ----------------------------------------------------------------------------

ARCHS: dict[str, ArchConfig] = {}


def _reg(cfg: ArchConfig) -> ArchConfig:
    ARCHS[cfg.name] = cfg
    return cfg


QWEN15_32B = _reg(ArchConfig(
    name="qwen1.5-32b", family="dense", n_layers=64, d_model=5120, n_heads=40,
    n_kv_heads=40, d_ff=27392, vocab=152064, qkv_bias=True, rope_theta=1e6,
    grad_accum=8))

YI_34B = _reg(ArchConfig(
    name="yi-34b", family="dense", n_layers=60, d_model=7168, n_heads=56,
    n_kv_heads=8, d_ff=20480, vocab=64000, rope_theta=5e6, grad_accum=8))

DEEPSEEK_67B = _reg(ArchConfig(
    name="deepseek-67b", family="dense", n_layers=95, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=22016, vocab=102400, rope_theta=1e4, grad_accum=16))

QWEN3_14B = _reg(ArchConfig(
    name="qwen3-14b", family="dense", n_layers=40, d_model=5120, n_heads=40,
    n_kv_heads=8, d_ff=17408, vocab=151936, qk_norm=True, rope_theta=1e6,
    grad_accum=4))

GROK_1 = _reg(ArchConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144, n_heads=48,
    n_kv_heads=8, d_ff=32768, vocab=131072, n_experts=8, top_k=2,
    pattern=("attn_moe",), moment_dtype="bfloat16", grad_accum=16,
    remat="nothing"))

MIXTRAL_8X7B = _reg(ArchConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=8, d_ff=14336, vocab=32000, n_experts=8, top_k=2,
    window=4096, pattern=("attn_moe",), rope_theta=1e6, grad_accum=4,
    sub_quadratic=True))

WHISPER_SMALL = _reg(ArchConfig(
    name="whisper-small", family="encdec", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab=51865, norm="layer",
    ffn_kind="gelu", n_enc_layers=12, enc_seq=1500, pattern=("attn_cross",)))

XLSTM_125M = _reg(ArchConfig(
    name="xlstm-125m", family="ssm", n_layers=12, d_model=768, n_heads=4,
    n_kv_heads=4, d_ff=0, vocab=50304, head_dim=192,
    pattern=("mlstm", "mlstm", "mlstm", "slstm"), sub_quadratic=True,
    # 125M model: TP over the recurrent width would insert per-timestep
    # collectives inside the sLSTM scan; run DP/FSDP-only (the MemPool
    # "keep private data in the local tile" choice for a tiny model).
    rules_overrides=(("ffn", None), ("heads", None), ("kv_heads", None))))

RECURRENTGEMMA_9B = _reg(ArchConfig(
    name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
    n_heads=16, n_kv_heads=1, d_ff=12288, vocab=256000, head_dim=256,
    ffn_kind="geglu", window=2048, lru_width=4096,
    pattern=("rglru", "rglru", "local_attn"), sub_quadratic=True,
    grad_accum=4))

LLAMA32_VISION_90B = _reg(ArchConfig(
    name="llama-3.2-vision-90b", family="vlm", n_layers=100, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=28672, vocab=128256, rope_theta=5e5,
    cross_every=5, n_img_tokens=1601, pattern=("attn",), grad_accum=16))


# the paper's own evaluation target: a 256-PE kernel cluster; used by the
# Table-1 benchmarks rather than the LM pipeline.
MEMPOOL_PAPER = dict(
    name="mempool-256", n_cores=256, l1_kib=1024, banks=1024,
    kernels=("matmul", "conv2d", "dct8x8", "axpy", "dotp"))


# ----------------------------------------------------------------------------
# Kernel tune records (written by kernels/pipeline.autotune)
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelTuneRecord:
    """One autotuned blocking for a (kernel, shape) cell.

    `blocks` / `default_blocks` are sorted (name, value) tuples so records
    stay hashable; `modeled_seconds` are the pipeline cost-model scores the
    autotuner *ranked* candidates with (roofline terms x interconnect
    locality penalty). The winner itself is picked by on-device timing:
    `measured_us` / `default_us` are the raced wall times (median of
    repeats) of the winning blocking and the hand-picked default, and
    `measured_speedup` is their ratio — the only speedup this record
    claims. `source` says how the record was produced: "timed" (raced),
    "modeled" (score-only fallback — frozen mode or no operand factory),
    or "db" (warm-started from a TuneDB written by an earlier timed run).
    """

    kernel: str
    shape_key: str
    blocks: tuple[tuple[str, int], ...]
    modeled_seconds: float
    default_blocks: tuple[tuple[str, int], ...] = ()
    default_modeled_seconds: float = 0.0
    # fused kernels only: the intermediate write+read the fusion removed
    # from HBM under the winning blocking (0.0 for unfused kernels)
    saved_bytes: float = 0.0
    # timed-race results (0.0 when source == "modeled": never raced)
    measured_us: float = 0.0
    default_us: float = 0.0
    source: str = "modeled"
    # which implementation won the race: "fused" = the Pallas kernel body
    # itself (with `blocks`), "unfused" = the op's unfused composition of
    # primitive kernels beat every blocking — tuned_call dispatches the
    # composition for this (kernel, shape) cell
    route: str = "fused"

    @property
    def timed(self) -> bool:
        return self.measured_us > 0.0

    @property
    def measured_speedup(self) -> float:
        """Real raced speedup of the tuned blocking over the default.

        >= 1.0 by construction for timed records (the default is always in
        the race, so the winner is never measurably slower); 1.0 for
        modeled-only records, which claim nothing.
        """
        if not self.timed:
            return 1.0
        return self.default_us / max(self.measured_us, 1e-30)


KERNEL_TUNES: dict[tuple[str, str], KernelTuneRecord] = {}


def register_kernel_tune(rec: KernelTuneRecord) -> KernelTuneRecord:
    KERNEL_TUNES[(rec.kernel, rec.shape_key)] = rec
    return rec


def get_kernel_tune(kernel: str, shape_key: str) -> KernelTuneRecord | None:
    return KERNEL_TUNES.get((kernel, shape_key))


def kernel_tunes() -> list[KernelTuneRecord]:
    return [KERNEL_TUNES[k] for k in sorted(KERNEL_TUNES)]


# ----------------------------------------------------------------------------
# Reduced same-family smoke variants
# ----------------------------------------------------------------------------

def smoke(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config: few layers, narrow, tiny vocab."""
    period = len(cfg.pattern)
    n_layers = max(2 * period, 2)
    if cfg.cross_every:
        n_layers = 2 * cfg.cross_every          # keep one cross layer in scan
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        enc_seq=16 if cfg.enc_seq else 0,
        n_img_tokens=8 if cfg.n_img_tokens else 0,
        window=min(cfg.window, 16) if cfg.window else None,
        lru_width=64 if cfg.lru_width else 0,
        attn_chunk=8,
        grad_accum=1,
        moment_dtype="float32",
    )


def get(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return smoke(ARCHS[name.removesuffix("-smoke")])
    return ARCHS[name]


# ----------------------------------------------------------------------------
# input_specs: abstract inputs per (arch x shape), no allocation
# ----------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, Any]:
    """ShapeDtypeStructs for every model input of this workload."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
        if cfg.family == "encdec":
            batch["enc_embeds"] = sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            batch["img_embeds"] = sds((B, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": sds((B, S), i32)}
        if cfg.family == "encdec":
            batch["enc_embeds"] = sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            batch["img_embeds"] = sds((B, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
        return batch
    if shape.kind == "decode":
        batch = {"tokens": sds((B, 1), i32), "pos": sds((), i32)}
        if cfg.family == "encdec":
            batch["enc_embeds"] = sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            batch["img_embeds"] = sds((B, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
        return batch
    raise ValueError(shape.kind)


def cell_supported(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Is this (arch x shape) cell runnable? (per assignment skip rules)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full quadratic attention at 524288 tokens; skipped per "
                       "assignment (noted in DESIGN.md §5)")
    return True, ""
