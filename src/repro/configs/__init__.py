from .registry import (ARCHS, SHAPES, ArchConfig, ShapeConfig,  # noqa: F401
                       cell_supported, get, input_specs, smoke)
