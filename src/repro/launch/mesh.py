"""Production meshes.

Single pod  : (16, 16) over ("data", "model")  — 256 chips, the MemPool
              cluster analogue (256 PEs; `data` plays the tile-group rows,
              `model` the columns of the 2-D ICI torus).
Multi-pod   : (2, 16, 16) over ("pod", "data", "model") — 512 chips across
              two pods connected by DCN.

`make_production_mesh` is a function (not a module constant) so importing
this module never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax import
(see dryrun.py) and only then builds the mesh.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Scaled-down mesh for CI: 8 devices, same axis structure."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
