import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks device
# count on first init). REPRO_DRYRUN_DEVICES overrides for scaled-down CI
# runs (still before the jax import below).
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, extract memory/cost/collective analysis, write one JSON per cell.

A thin wrapper over the Cluster façade: each cell builds a
`repro.cluster.Cluster` on the production mesh and compiles a
`DryRunProgram` on it (the lower/compile/analyze body lives there); this
module keeps the CLI, the variant table, and the JSON envelope.

This is the proof that the distribution config is coherent: a sharding
mismatch, OOM-at-compile, or unsupported collective fails the cell. The
roofline tables in EXPERIMENTS.md are generated from these JSONs by
launch/roofline.py.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both     # spawn one proc per cell
"""

import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

from repro.cluster import Cluster, DryRunProgram
from repro.cluster.cells import (batch_logical, build_cell,  # noqa: F401
                                 layer_gather_specs, model_flops,
                                 shardings_for)
from repro.configs import SHAPES, ARCHS, get
from repro.launch.mesh import make_production_mesh

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


# §Perf hillclimb variants: config deltas applied on top of the baseline.
# "_fsdp_gather" is a build-level switch (forces per-layer weight AG over
# the data axis inside the scan) rather than a config field.
VARIANTS: dict[str, dict] = {
    "folded": {"attn_schedule": "folded"},          # exact-causal schedule
    "localmoe": {"moe_local_dispatch": True},       # shard-local dispatch
    "tponly": {"rules_overrides": (("embed", None),)},  # no FSDP: p_local max
    "localmoe_tponly": {"moe_local_dispatch": True,
                        "rules_overrides": (("embed", None),)},
    "chunk512": {"attn_chunk": 512},
    "chunk2048": {"attn_chunk": 2048},
    "accum4": {"grad_accum": 4},
    "folded_chunk2048": {"attn_schedule": "folded", "attn_chunk": 2048},
    "fsdpgather": {"_fsdp_gather": True},
    "fsdpgather_localmoe": {"_fsdp_gather": True, "moe_local_dispatch": True},
    "fsdpgather_folded": {"_fsdp_gather": True, "attn_schedule": "folded"},
    "cap1": {"capacity_factor": 1.0},
    "cap1_localmoe": {"capacity_factor": 1.0, "moe_local_dispatch": True},
}


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: Path = RESULTS, variant: str | None = None) -> dict:
    import dataclasses
    cfg = get(arch)
    fsdp_gather = False
    if variant:
        deltas = dict(VARIANTS[variant])
        fsdp_gather = deltas.pop("_fsdp_gather", False)
        if deltas:
            cfg = dataclasses.replace(cfg, **deltas)
    multi = mesh_kind == "multi"
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "variant": variant, "timestamp": time.time()}

    mesh = make_production_mesh(multi_pod=multi)
    cluster = Cluster(cfg, mesh)
    program = cluster.compile(DryRunProgram(shape=shape_name,
                                            fsdp_gather=fsdp_gather))
    record |= program.run()
    _write(record, out_dir)
    return record


def _write(record: dict, out_dir: Path):
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{record['arch']}__{record['shape']}__{record['mesh']}"
    if record.get("variant"):
        name += f"__{record['variant']}"
    (out_dir / (name + ".json")).write_text(
        json.dumps(record, indent=2, default=float))
    print(f"[dryrun] wrote {name}.json: status={record.get('status')}")


def run_all(mesh_kinds: list[str], timeout: int = 3600,
            jobs: int = 1, only_missing: bool = False):
    cells = [(a, s, m) for a in ARCHS for s in SHAPES for m in mesh_kinds]
    if only_missing:
        cells = [c for c in cells
                 if not (RESULTS / f"{c[0]}__{c[1]}__{c[2]}.json").exists()]
    print(f"[dryrun] {len(cells)} cells to run")
    procs: list[tuple] = []
    results = []

    def drain(block_all=False):
        while procs and (block_all or len(procs) >= jobs):
            p, cell, t0 = procs.pop(0)
            try:
                rc = p.wait(timeout=timeout)
            except subprocess.TimeoutExpired:
                p.kill()
                rc = -9
            results.append((cell, rc, time.time() - t0))
            print(f"[dryrun] {cell} rc={rc} ({time.time()-t0:.0f}s)")

    for cell in cells:
        drain()
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", cell[0],
               "--shape", cell[1], "--mesh", cell[2]]
        procs.append((subprocess.Popen(cmd), cell, time.time()))
    drain(block_all=True)
    failed = [c for c, rc, _ in results if rc != 0]
    print(f"[dryrun] done; {len(failed)} failed: {failed}")
    return failed


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=1)
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--only-missing", action="store_true")
    ap.add_argument("--variant", default=None, choices=sorted(VARIANTS))
    args = ap.parse_args()
    kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        failed = run_all(kinds, timeout=args.timeout, jobs=args.jobs,
                         only_missing=args.only_missing)
        sys.exit(1 if failed else 0)
    assert args.arch and args.shape, "--arch/--shape required without --all"
    try:
        for kind in kinds:
            run_cell(args.arch, args.shape, kind, variant=args.variant)
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
