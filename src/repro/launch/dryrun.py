import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks device
# count on first init). REPRO_DRYRUN_DEVICES overrides for scaled-down CI
# runs (still before the jax import below).
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, extract memory/cost/collective analysis, write one JSON per cell.

This is the proof that the distribution config is coherent: a sharding
mismatch, OOM-at-compile, or unsupported collective fails the cell. The
roofline tables in EXPERIMENTS.md are generated from these JSONs by
launch/roofline.py.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both     # spawn one proc per cell
"""

import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, ARCHS, cell_supported, get, input_specs
from repro.core import addressing, compat, hlo_cost, locality
from repro.core import mesh as hw
from repro.launch.mesh import make_production_mesh
from repro.models import steps

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def batch_logical(cfg, shape) -> dict:
    log = {"tokens": ("batch", "seq")}
    if shape.kind == "train":
        log["labels"] = ("batch", "seq")
    if shape.kind == "decode":
        log["tokens"] = ("batch", None)
        log["pos"] = ()
    if cfg.family == "encdec":
        log["enc_embeds"] = ("batch", None, None)
    if cfg.family == "vlm":
        log["img_embeds"] = ("batch", None, None)
    return log


def shardings_for(tree_sds, tree_logical, mesh, rules):
    def one(sds, logical):
        spec = rules.spec_for(logical, sds.shape, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree.map(
        one, tree_sds, tree_logical,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def layer_gather_specs(cfg, mesh, rules):
    """PartitionSpecs for ONE super-block's weights with the `data` axis
    removed — forcing FSDP all-gathers inside the scan (variant fsdpgather)."""
    gather_rules = addressing.default_rules(mesh, fsdp=False,
                                            overrides=cfg.rules_overrides)
    p_sds, p_log = steps.abstract_params(cfg)

    def one(sds, logical):
        # strip the leading stacked "layers" dim
        return gather_rules.spec_for(logical[1:], sds.shape[1:], mesh)

    return jax.tree.map(
        one, p_sds["blocks"], p_log["blocks"],
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def build_cell(cfg, shape, mesh, rules, fsdp_gather: bool = False):
    """Returns (fn, args_sds, in_shardings, out_shardings, donate)."""
    batch_sds = input_specs(cfg, shape)
    batch_log = batch_logical(cfg, shape)
    batch_sh = shardings_for(batch_sds, batch_log, mesh, rules)

    if shape.kind == "train":
        wsc = layer_gather_specs(cfg, mesh, rules) if fsdp_gather else None
        fn = steps.make_train_step(cfg, layer_wsc=wsc)
        state_sds, state_log = steps.abstract_train_state(cfg, shape.seq_len)
        state_sh = shardings_for(state_sds, state_log, mesh, rules)
        scalar = NamedSharding(mesh, P())
        out_sh = (state_sh, None)
        return fn, (state_sds, batch_sds), (state_sh, batch_sh), out_sh, (0,)

    params_sds, params_log = steps.abstract_params(cfg, shape.seq_len)
    params_sh = shardings_for(params_sds, params_log, mesh, rules)

    if shape.kind == "prefill":
        fn = steps.make_prefill_step(cfg)
        tok_sh = NamedSharding(
            mesh, rules.spec_for(("batch",), (shape.global_batch,), mesh))
        return (fn, (params_sds, batch_sds), (params_sh, batch_sh),
                tok_sh, ())

    # decode
    cache_len = steps.decode_cache_len(cfg, shape.seq_len)
    fn = steps.make_decode_step(cfg, max_seq=shape.seq_len)
    cache_sds, cache_log = steps.abstract_cache(cfg, shape.global_batch,
                                                cache_len)
    cache_sh = shardings_for(cache_sds, cache_log, mesh, rules)
    tok_sh = NamedSharding(
        mesh, rules.spec_for(("batch", None), (shape.global_batch, 1), mesh))
    return (fn, (params_sds, cache_sds, batch_sds),
            (params_sh, cache_sh, batch_sh), (cache_sh, tok_sh), (1,))


def model_flops(cfg, shape) -> dict:
    n = cfg.n_params()
    n_act = cfg.n_active_params()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        mf = 6.0 * n_act * d
    elif shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        mf = 2.0 * n_act * d
    else:
        d = shape.global_batch
        mf = 2.0 * n_act * d
    return {"n_params": n, "n_active_params": n_act, "tokens": d,
            "model_flops": mf}


# §Perf hillclimb variants: config deltas applied on top of the baseline.
# "_fsdp_gather" is a build-level switch (forces per-layer weight AG over
# the data axis inside the scan) rather than a config field.
VARIANTS: dict[str, dict] = {
    "folded": {"attn_schedule": "folded"},          # exact-causal schedule
    "localmoe": {"moe_local_dispatch": True},       # shard-local dispatch
    "tponly": {"rules_overrides": (("embed", None),)},  # no FSDP: p_local max
    "localmoe_tponly": {"moe_local_dispatch": True,
                        "rules_overrides": (("embed", None),)},
    "chunk512": {"attn_chunk": 512},
    "chunk2048": {"attn_chunk": 2048},
    "accum4": {"grad_accum": 4},
    "folded_chunk2048": {"attn_schedule": "folded", "attn_chunk": 2048},
    "fsdpgather": {"_fsdp_gather": True},
    "fsdpgather_localmoe": {"_fsdp_gather": True, "moe_local_dispatch": True},
    "fsdpgather_folded": {"_fsdp_gather": True, "attn_schedule": "folded"},
    "cap1": {"capacity_factor": 1.0},
    "cap1_localmoe": {"capacity_factor": 1.0, "moe_local_dispatch": True},
}


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: Path = RESULTS, variant: str | None = None) -> dict:
    import dataclasses
    cfg = get(arch)
    fsdp_gather = False
    if variant:
        deltas = dict(VARIANTS[variant])
        fsdp_gather = deltas.pop("_fsdp_gather", False)
        if deltas:
            cfg = dataclasses.replace(cfg, **deltas)
    shape = SHAPES[shape_name]
    multi = mesh_kind == "multi"
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "variant": variant, "timestamp": time.time()}
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        record |= {"status": "skipped", "reason": reason}
        _write(record, out_dir)
        return record

    mesh = make_production_mesh(multi_pod=multi)
    n_chips = mesh.size
    rules = addressing.default_rules(mesh, overrides=cfg.rules_overrides)
    fn, args, in_sh, out_sh, donate = build_cell(cfg, shape, mesh, rules,
                                                 fsdp_gather=fsdp_gather)

    t0 = time.time()
    with compat.set_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = locality.extract_memory(compiled)
    ca = locality.extract_costs(compiled)
    print("memory_analysis:", compiled.memory_analysis())
    print("cost_analysis (built-in, loop-unaware):", ca)

    t0 = time.time()
    hlo_text = compiled.as_text()
    costs = hlo_cost.analyze(hlo_text)
    t_analyze = time.time() - t0

    mf = model_flops(cfg, shape)
    flops_dev = costs["flops"]
    bytes_dev = costs["bytes"]
    coll_dev = costs["collective_operand_bytes"]
    wire_dev = costs["collective_wire_bytes"]
    record |= {
        "status": "ok",
        "n_chips": n_chips,
        "seconds": {"lower": t_lower, "compile": t_compile,
                    "analyze": t_analyze},
        "memory_analysis": mem,
        "peak_device_bytes": locality.peak_device_bytes(mem),
        "cost_analysis_builtin": ca,
        "hlo": {
            "flops_per_device": flops_dev,
            "bytes_per_device": bytes_dev,
            "transcendentals_per_device": costs["transcendentals"],
            "collective_operand_bytes_per_device": coll_dev,
            "collective_wire_bytes_per_device": wire_dev,
            "collectives": costs["collectives"],
        },
        "model": mf,
        "roofline": {
            # terms in seconds, per the task's definitions
            "compute_s": flops_dev * n_chips / (n_chips * hw.PEAK_FLOPS_BF16),
            "memory_s": bytes_dev * n_chips / (n_chips * hw.HBM_BW),
            "collective_s": coll_dev * n_chips / (n_chips * hw.ICI_BW_PER_LINK),
            "collective_wire3_s": wire_dev / (3 * hw.ICI_BW_PER_LINK),
            "useful_flops_ratio": mf["model_flops"] / max(
                flops_dev * n_chips, 1.0),
        },
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: record["roofline"][k])
    record["roofline"]["dominant"] = dom
    _write(record, out_dir)
    return record


def _write(record: dict, out_dir: Path):
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{record['arch']}__{record['shape']}__{record['mesh']}"
    if record.get("variant"):
        name += f"__{record['variant']}"
    (out_dir / (name + ".json")).write_text(
        json.dumps(record, indent=2, default=float))
    print(f"[dryrun] wrote {name}.json: status={record.get('status')}")


def run_all(mesh_kinds: list[str], timeout: int = 3600,
            jobs: int = 1, only_missing: bool = False):
    cells = [(a, s, m) for a in ARCHS for s in SHAPES for m in mesh_kinds]
    if only_missing:
        cells = [c for c in cells
                 if not (RESULTS / f"{c[0]}__{c[1]}__{c[2]}.json").exists()]
    print(f"[dryrun] {len(cells)} cells to run")
    procs: list[tuple] = []
    results = []

    def drain(block_all=False):
        while procs and (block_all or len(procs) >= jobs):
            p, cell, t0 = procs.pop(0)
            try:
                rc = p.wait(timeout=timeout)
            except subprocess.TimeoutExpired:
                p.kill()
                rc = -9
            results.append((cell, rc, time.time() - t0))
            print(f"[dryrun] {cell} rc={rc} ({time.time()-t0:.0f}s)")

    for cell in cells:
        drain()
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", cell[0],
               "--shape", cell[1], "--mesh", cell[2]]
        procs.append((subprocess.Popen(cmd), cell, time.time()))
    drain(block_all=True)
    failed = [c for c, rc, _ in results if rc != 0]
    print(f"[dryrun] done; {len(failed)} failed: {failed}")
    return failed


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=1)
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--only-missing", action="store_true")
    ap.add_argument("--variant", default=None, choices=sorted(VARIANTS))
    args = ap.parse_args()
    kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        failed = run_all(kinds, timeout=args.timeout, jobs=args.jobs,
                         only_missing=args.only_missing)
        sys.exit(1 if failed else 0)
    assert args.arch and args.shape, "--arch/--shape required without --all"
    try:
        for kind in kinds:
            run_cell(args.arch, args.shape, kind, variant=args.variant)
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
