"""Roofline model + tables from the dry-run JSONs -> markdown for EXPERIMENTS.md.

Two roles:
  * `kernel_roofline` — the per-kernel compute/memory roofline terms on the
    v5e constants; the scoring primitive for the kernel autotuner
    (kernels/pipeline.py) and the Table-1 benchmark.
  * the table generators below, which render the dry-run JSONs.

Usage: PYTHONPATH=src python -m repro.launch.roofline [--update-experiments]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def kernel_roofline(flops: float, hbm_bytes: float) -> dict:
    """Roofline terms (seconds) of one kernel invocation on a single chip.

    Same term definitions as the dry-run records' `roofline` block, applied
    to a kernel's own flop/traffic counts instead of a whole train step.
    """
    from repro.core import mesh as hw
    compute_s = flops / hw.PEAK_FLOPS_BF16
    memory_s = hbm_bytes / hw.HBM_BW
    intensity = flops / max(hbm_bytes, 1.0)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "dominant": "compute_s" if compute_s >= memory_s else "memory_s",
        "intensity": intensity,
        "roof_flops": min(hw.PEAK_FLOPS_BF16, intensity * hw.HBM_BW),
    }


def fused_roofline(flops: float, hbm_bytes: float,
                   saved_bytes: float) -> dict:
    """Roofline of a fused kernel, with the dropped intermediate made
    explicit: the unfused composition would stream `hbm_bytes + saved_bytes`
    (the intermediate's write + read), so the fused memory term drops by
    `saved_s` and the traffic_reduction factor is what the fusion bought.
    The autotuner's fused candidates are scored on exactly this reduced
    `hbm_bytes`, so saved traffic is what ranks them above the composition.
    """
    from repro.core import mesh as hw
    r = kernel_roofline(flops, hbm_bytes)
    unfused = kernel_roofline(flops, hbm_bytes + saved_bytes)
    r.update({
        "saved_bytes": saved_bytes,
        "saved_s": saved_bytes / hw.HBM_BW,
        "unfused_memory_s": unfused["memory_s"],
        "traffic_reduction": (hbm_bytes + saved_bytes) / max(hbm_bytes, 1.0),
    })
    return r


def load(mesh: str = "single", variants: bool = False) -> list[dict]:
    rows = []
    for p in sorted(RESULTS.glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("mesh") != mesh:
            continue
        if bool(d.get("variant")) != variants:
            continue
        rows.append(d)
    return rows


def _fmt(x: float, digits: int = 2) -> str:
    if x == 0:
        return "0"
    if x >= 100:
        return f"{x:.0f}"
    return f"{x:.{digits}f}"


def baseline_table(mesh: str = "single") -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| HLO GFLOPs/chip | GB/chip traffic | peak GB/chip | "
           "MODEL/HLO flops | note |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for d in load(mesh):
        if d["status"] == "skipped":
            lines.append(f"| {d['arch']} | {d['shape']} | — | — | — | — | — "
                         f"| — | — | — | skipped: {d['reason'][:60]} |")
            continue
        r = d["roofline"]
        h = d["hlo"]
        lines.append(
            f"| {d['arch']} | {d['shape']} | {_fmt(r['compute_s'])} "
            f"| {_fmt(r['memory_s'])} | {_fmt(r['collective_s'])} "
            f"| **{r['dominant'].replace('_s', '')}** "
            f"| {h['flops_per_device'] / 1e9:.0f} "
            f"| {h['bytes_per_device'] / 1e9:.0f} "
            f"| {d['peak_device_bytes'] / 2**30:.1f} "
            f"| {r['useful_flops_ratio']:.3f} | |")
    return "\n".join(lines)


def variant_table() -> str:
    hdr = ("| arch | shape | variant | compute s | memory s | collective s "
           "| MODEL/HLO flops | peak GB |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for d in load("single", variants=True):
        if d["status"] != "ok":
            continue
        r = d["roofline"]
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['variant']} "
            f"| {_fmt(r['compute_s'])} | {_fmt(r['memory_s'])} "
            f"| {_fmt(r['collective_s'])} | {r['useful_flops_ratio']:.3f} "
            f"| {d['peak_device_bytes'] / 2**30:.1f} |")
    return "\n".join(lines)


def multi_pod_table() -> str:
    single = {(d["arch"], d["shape"]): d for d in load("single")}
    hdr = ("| arch | shape | 256-chip dominant s | 512-chip dominant s "
           "| scaling | collectives 512 (GB/chip) |")
    sep = "|" + "---|" * 6
    lines = [hdr, sep]
    for d in load("multi"):
        if d["status"] != "ok":
            continue
        s = single.get((d["arch"], d["shape"]))
        if not s or s["status"] != "ok":
            continue
        rm, rs = d["roofline"], s["roofline"]
        dm = max(rm["compute_s"], rm["memory_s"], rm["collective_s"])
        ds = max(rs["compute_s"], rs["memory_s"], rs["collective_s"])
        lines.append(
            f"| {d['arch']} | {d['shape']} | {_fmt(ds)} | {_fmt(dm)} "
            f"| {ds / max(dm, 1e-12):.2f}x "
            f"| {d['hlo']['collective_operand_bytes_per_device'] / 1e9:.1f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variants", action="store_true")
    args = ap.parse_args()
    if args.variants:
        print(variant_table())
    else:
        print(baseline_table(args.mesh))
        print()
        print(multi_pod_table())


if __name__ == "__main__":
    main()
