"""Training launcher — the declarative ("Halide-layer") entry point.

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen3-14b --smoke --steps 50 --batch 4 --seq 128

A thin wrapper over the Cluster façade: the CLI builds one
`repro.cluster.Cluster` (mesh + addressing + kernel policy) and compiles a
`TrainProgram` on it. On CPU this runs reduced configs end-to-end (data
pipeline -> region-planned shardings -> compiled train step ->
checkpointing); on a TPU fleet the same invocation with the production
mesh shape trains the full config.
"""

from __future__ import annotations

import argparse

import jax

from repro.cluster import Cluster, TrainProgram
from repro.cluster.policy import MODES
from repro.configs import get
from repro.core import compat


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro-train")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--data-axis", type=int, default=0,
                    help="data axis size (0 = all devices)")
    ap.add_argument("--policy", default=None, choices=MODES,
                    help="kernel policy mode (default: env-derived)")
    ap.add_argument("--no-resume", action="store_true",
                    help="ignore checkpoints in --checkpoint-dir")
    args = ap.parse_args()

    cfg = get(args.arch + ("-smoke" if args.smoke else ""))
    n_dev = jax.device_count()
    data = args.data_axis or n_dev
    mesh = compat.make_mesh((data, n_dev // data), ("data", "model"))

    cluster = Cluster(cfg, mesh, policy=args.policy)
    program = cluster.compile(TrainProgram(
        num_steps=args.steps, batch=args.batch, seq=args.seq,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        resume=not args.no_resume))
    report = program.run()

    print(f"\nfinal step {report['final_step']} "
          f"in {report['wall_seconds']:.1f}s; "
          f"stragglers={len(report['straggler_events'])}")
    for m in report["metrics"][-5:]:
        print(f"  step {m['step']:>5d} loss={m['loss']:.4f} "
              f"{m['seconds'] * 1e3:.0f}ms")


if __name__ == "__main__":
    main()
