"""Training launcher — the declarative ("Halide-layer") entry point.

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen3-14b --smoke --steps 50 --batch 4 --seq 128

On CPU this runs reduced configs end-to-end (data pipeline -> region-planned
shardings -> compiled train step -> checkpointing); on a TPU fleet the same
invocation with the production mesh shape trains the full config.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get
from repro.core import addressing, compat
from repro.data import Distributor, Splitter, SyntheticLMStream
from repro.data.pipeline import BatchSpec
from repro.models import steps
from repro.runtime import TrainLoop, TrainLoopConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro-train")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--data-axis", type=int, default=0,
                    help="data axis size (0 = all devices)")
    args = ap.parse_args()

    cfg = get(args.arch + ("-smoke" if args.smoke else ""))
    n_dev = jax.device_count()
    data = args.data_axis or n_dev
    mesh = compat.make_mesh((data, n_dev // data), ("data", "model"))
    rules = addressing.default_rules(mesh, overrides=cfg.rules_overrides)

    state = steps.init_train_state(cfg, jax.random.PRNGKey(0),
                                   max_seq=args.seq)
    state_sds = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    _, state_log = steps.abstract_train_state(cfg, args.seq)
    from repro.launch.dryrun import shardings_for
    state_sh = shardings_for(state_sds, state_log, mesh, rules)
    state = jax.tree.map(jax.device_put, state, state_sh)

    spec = BatchSpec(global_batch=args.batch, seq_len=args.seq,
                     vocab=cfg.vocab)
    stream = SyntheticLMStream(spec, seed=0)
    dist = Distributor(mesh, Splitter(mesh, ("data",)))
    batch_sh = jax.sharding.NamedSharding(
        mesh, rules.spec_for(("batch", "seq"), (args.batch, args.seq), mesh))

    def batches():
        step = 0
        while True:
            yield dist.materialize(stream, step, batch_sh)
            step += 1

    with compat.set_mesh(mesh):
        train_step = jax.jit(steps.make_train_step(cfg), donate_argnums=0)
        loop = TrainLoop(
            TrainLoopConfig(total_steps=args.steps,
                            checkpoint_every=args.checkpoint_every,
                            checkpoint_dir=args.checkpoint_dir,
                            log_every=max(args.steps // 10, 1)),
            train_step, state, batches(), state_shardings=state_sh)
        report = loop.run()

    print(f"\nfinal step {report['final_step']} "
          f"in {report['wall_seconds']:.1f}s; "
          f"stragglers={len(report['straggler_events'])}")
    for m in report["metrics"][-5:]:
        print(f"  step {m['step']:>5d} loss={m['loss']:.4f} "
              f"{m['seconds'] * 1e3:.0f}ms")


if __name__ == "__main__":
    main()
