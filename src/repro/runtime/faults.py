"""Fault injection + wedge detection for the serving session.

MemPool's robustness claim is architectural: PEs execute independently,
so one stalled core never wedges the cluster and a dead core only costs
its own lanes. Nothing in a software system earns that property without
being exercised — this module is the harness that exercises it. A
`FaultPlan` scripts failures against a `ServeSession` at exact chunk
indices, so chaos runs are reproducible and CI can assert the recovery
contract: every surviving request's tokens are bit-identical to a
fault-free run.

Fault kinds (all fire exactly once, at their scripted chunk):

* ``kill_slot``  — the slot's device row is declared dead at harvest of
  chunk N. Recovery: quarantine the slot (the pool degrades, never
  crashes), discard the request's partial tokens, requeue it with
  bounded retries + exponential backoff.
* ``corrupt_nan`` — the slot's float cache rows are overwritten with NaN
  before chunk N dispatches. Detection is the session's NaN sentinel
  scan on harvest; recovery requeues the request and recycles (zeroes)
  the slot — transient corruption does not cost pool capacity.
* ``wedge``      — chunk N's device wait never completes (the injected
  wait blocks forever). Detection is the session watchdog
  (``watchdog_s`` / ``poll(timeout_s=...)``), which raises
  `SessionWedged` with the StallClock ledger attached; recovery is
  `session.recover_wedged()` — rebuild the pool, requeue everything
  that was running.
* ``refill_error`` — the refill program raises at chunk boundary N. The
  session un-admits the round and retries at the next boundary.
* ``page_alloc_fail`` — every paged-KV page allocation at chunk boundary
  N reports `PoolExhausted` (runtime/kvpool.py). Recovery is the typed
  shed/requeue path: the affected admissions are un-admitted and requeued
  at the front of their class — no crash, no token loss — and the
  session's `stats()["kv"]["pool_exhausted"]` counter records the event.
* ``bit_flip``   — a published KV page's device content is silently
  perturbed (finite values, not NaN) before chunk N dispatches. The NaN
  sentinel scan cannot see it by design; detection is the per-page
  content checksum (stamped at `PagedKV.publish`), verified before the
  page is shared via the PrefixCache and by the background scrub.
  Recovery quarantines the page, drops the poisoned prefix chain, and
  repairs by recompute (the next requester re-prefills).
* ``crash``      — the process dies at the END of chunk N's poll, after
  the journal commit (`crash_hook`; the default raises `SessionCrashed`,
  the chaos harness SIGKILLs itself for a true ``kill -9``). Recovery is
  out-of-process: restart + `restore()` replays the journal/snapshot.

The plan is injected per-session (``program.open(faults=plan)`` or the
``faults=`` constructor argument) and threaded through the driver as
query hooks — the session stays fault-free code when no plan is attached.

Thread safety: the serve loop and the watchdog thread both consult the
plan (e.g. `pending_wedge` mid-wait while `poll` consumes faults), so
all mutation of `_consumed`/`fired` happens under one internal lock.
"""

from __future__ import annotations

import dataclasses
import threading

KINDS = ("kill_slot", "corrupt_nan", "wedge", "refill_error",
         "page_alloc_fail", "bit_flip", "crash")


class InjectedFault(RuntimeError):
    """An error raised by the fault harness itself (e.g. refill_error)."""


class SessionWedged(RuntimeError):
    """The device never completed a chunk within the watchdog timeout.

    Carries the session's StallClock ledger at the moment of detection
    (`stall`) and the wedged chunk index (`chunk`), so the operator sees
    how long the device sat silent and where. Raised by
    `ServeSession.poll/stream/drain` when `timeout_s` (or the session's
    `watchdog_s`) elapses; `session.recover_wedged()` rebuilds the pool.
    """

    def __init__(self, chunk: int, timeout_s: float, stall: dict):
        super().__init__(
            f"device did not complete chunk {chunk} within {timeout_s:.3f}s "
            f"(host_syncs={stall.get('host_syncs')}, "
            f"device_wait_s={stall.get('device_wait_s', 0.0):.3f})")
        self.chunk = chunk
        self.timeout_s = timeout_s
        self.stall = stall


class SessionCrashed(RuntimeError):
    """The scripted ``crash`` fault fired: the process is declared dead
    at the end of this chunk's poll (after the journal commit). In-
    process harnesses catch this and re-open the session with
    ``resume=True``; the chaos subprocess harness SIGKILLs itself
    instead so the restart is a true ``kill -9`` recovery."""

    def __init__(self, chunk: int):
        super().__init__(f"injected process crash at end of chunk {chunk}")
        self.chunk = chunk


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scripted failure: `kind` at chunk `at_chunk` (slot-targeted
    kinds carry `slot`; ``bit_flip`` may carry a target `page`)."""

    kind: str
    at_chunk: int
    slot: int | None = None
    page: int | None = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.at_chunk < 0:
            raise ValueError(f"at_chunk must be >= 0, got {self.at_chunk}")
        needs_slot = self.kind in ("kill_slot", "corrupt_nan")
        if needs_slot and self.slot is None:
            raise ValueError(f"{self.kind} needs a target slot")
        if not needs_slot and self.slot is not None:
            raise ValueError(f"{self.kind} does not take a slot")
        if self.page is not None and self.kind != "bit_flip":
            raise ValueError(f"{self.kind} does not take a page")


class FaultPlan:
    """A reproducible script of failures, queried by the session driver.

    Build fluently::

        plan = (FaultPlan()
                .kill_slot(at_chunk=2, slot=0)
                .corrupt_nan(at_chunk=4, slot=1)
                .wedge(at_chunk=6)
                .refill_error(at_chunk=3))

    Each fault fires exactly once; `fired` records what actually fired
    (kind, chunk, slot) in firing order, and `summary()` aggregates it
    for the `# chaos:` report line.
    """

    def __init__(self, faults: "list[Fault] | None" = None):
        self.faults: list[Fault] = list(faults or [])
        self.fired: list[tuple[str, int, int | None]] = []
        self._consumed: set[int] = set()
        # the serve loop and the watchdog thread both consume/inspect
        # the plan concurrently
        self._lock = threading.Lock()

    # -- builders --------------------------------------------------------
    def add(self, kind: str, at_chunk: int, slot: int | None = None,
            page: int | None = None):
        self.faults.append(Fault(kind, at_chunk, slot, page))
        return self

    def kill_slot(self, at_chunk: int, slot: int) -> "FaultPlan":
        return self.add("kill_slot", at_chunk, slot)

    def corrupt_nan(self, at_chunk: int, slot: int) -> "FaultPlan":
        return self.add("corrupt_nan", at_chunk, slot)

    def wedge(self, at_chunk: int) -> "FaultPlan":
        return self.add("wedge", at_chunk)

    def refill_error(self, at_chunk: int) -> "FaultPlan":
        return self.add("refill_error", at_chunk)

    def page_alloc_fail(self, at_chunk: int) -> "FaultPlan":
        return self.add("page_alloc_fail", at_chunk)

    def bit_flip(self, at_chunk: int, page: int | None = None) -> "FaultPlan":
        """Silently perturb a published KV page's content before this
        chunk (page=None targets the first stamped page at fire time)."""
        return self.add("bit_flip", at_chunk, page=page)

    def crash(self, at_chunk: int) -> "FaultPlan":
        """Kill the process at the end of this chunk's poll, after the
        journal commit."""
        return self.add("crash", at_chunk)

    # -- driver queries (each consumes the fault it matches) -------------
    def _take(self, kind: str, chunk: int) -> list[Fault]:
        out = []
        with self._lock:
            for i, f in enumerate(self.faults):
                if (i in self._consumed or f.kind != kind
                        or f.at_chunk != chunk):
                    continue
                self._consumed.add(i)
                self.fired.append((f.kind, chunk, f.slot))
                out.append(f)
        return out

    def kills(self, chunk: int) -> list[int]:
        """Slots declared dead at harvest of this chunk."""
        return [f.slot for f in self._take("kill_slot", chunk)]

    def corrupts(self, chunk: int) -> list[int]:
        """Slots whose cache rows go NaN before this chunk dispatches."""
        return [f.slot for f in self._take("corrupt_nan", chunk)]

    def wedged(self, chunk: int) -> bool:
        """True when this chunk's device wait must never complete."""
        return bool(self._take("wedge", chunk))

    def page_alloc_failed(self, boundary: int) -> bool:
        """True when page allocation at this chunk boundary is scripted
        to report `PoolExhausted` (paged-KV sessions only)."""
        return bool(self._take("page_alloc_fail", boundary))

    def check_refill(self, boundary: int) -> None:
        """Raises `InjectedFault` when the refill at this chunk boundary
        is scripted to fail."""
        if self._take("refill_error", boundary):
            raise InjectedFault(f"injected refill failure at chunk "
                                f"boundary {boundary}")

    def bit_flips(self, chunk: int) -> "list[int | None]":
        """Target pages to silently corrupt before this chunk dispatches
        (None = let the session pick the first stamped page)."""
        return [f.page for f in self._take("bit_flip", chunk)]

    def crashed(self, chunk: int) -> bool:
        """True when the process is scripted to die at the end of this
        chunk's poll."""
        return bool(self._take("crash", chunk))

    # -- introspection ---------------------------------------------------
    @property
    def has_wedge(self) -> bool:
        return any(f.kind == "wedge" for f in self.faults)

    @property
    def pending_wedge(self) -> bool:
        """A wedge is scripted and has not fired yet (the session checks
        this before dispatching: a wedge with no watchdog would block the
        driver forever, which is a harness misconfiguration)."""
        with self._lock:
            return any(f.kind == "wedge" and i not in self._consumed
                       for i, f in enumerate(self.faults))

    @property
    def has_corruption(self) -> bool:
        return any(f.kind == "corrupt_nan" for f in self.faults)

    @property
    def exhausted(self) -> bool:
        with self._lock:
            return len(self._consumed) == len(self.faults)

    def summary(self) -> dict:
        """{kind: fired count} plus planned totals, for the chaos line."""
        fired: dict[str, int] = {k: 0 for k in KINDS}
        with self._lock:
            n_fired = len(self.fired)
            for kind, _, _ in self.fired:
                fired[kind] += 1
        return {"planned": len(self.faults), "fired": n_fired,
                "by_kind": fired}

    def __repr__(self) -> str:
        return (f"FaultPlan({len(self.faults)} faults, "
                f"{len(self.fired)} fired)")
