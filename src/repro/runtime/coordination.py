"""Host-side coordination for elastic scale and fault events.

The TPU analogue of MemPool's wake-up triggers and control registers: a tiny
event bus the launcher uses to re-plan the mesh when membership changes.
On a real fleet this fronts the cluster coordinator (GKE/Borg signals); here
it is an in-process implementation with identical semantics so the elastic
logic is testable.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable


@dataclasses.dataclass
class MemberEvent:
    kind: str          # "join" | "leave" | "preempt-notice"
    host: str
    time: float


class Coordinator:
    def __init__(self, n_hosts: int):
        self.n_hosts = n_hosts
        self.events: list[MemberEvent] = []
        self.listeners: list[Callable[[MemberEvent], None]] = []

    def emit(self, kind: str, host: str):
        ev = MemberEvent(kind, host, time.time())
        self.events.append(ev)
        if kind == "leave":
            self.n_hosts -= 1
        elif kind == "join":
            self.n_hosts += 1
        for fn in self.listeners:
            fn(ev)

    def subscribe(self, fn: Callable[[MemberEvent], None]):
        self.listeners.append(fn)


def replan_mesh_shape(n_chips: int, *, model_parallel: int = 16,
                      pods: int = 1) -> tuple[int, ...]:
    """Choose a mesh shape for the surviving chip count (elastic restart).

    Keeps the model axis fixed (weight layout unchanged -> cheapest restore)
    and shrinks the data axis; drops to the largest power-of-two data degree
    that divides the survivors. Mirrors MemPool's fixed tile structure with
    a variable number of active groups.
    """
    per_pod = n_chips // pods
    data = per_pod // model_parallel
    if data < 1:
        raise ValueError(f"{n_chips} chips cannot host model={model_parallel}")
    data = 2 ** int(math.log2(data))
    if pods > 1:
        return (pods, data, model_parallel)
    return (data, model_parallel)
