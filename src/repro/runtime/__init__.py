from .engine import (DecodeEngine, StallClock, init_session_state,  # noqa: F401
                     make_decode_chunk, make_nan_scan, make_session_chunk,
                     make_session_refill, make_slot_corrupt,
                     make_slot_restore, make_slot_snapshot, make_train_chunk)
from .faults import (Fault, FaultPlan, InjectedFault,  # noqa: F401
                     SessionCrashed, SessionWedged)
from .groups import (GroupPlan, GroupRuntime, GroupView,  # noqa: F401
                     MeshScheduler, ShardedServeSession)
from .journal import (Journal, ReplayedRequest, ReplaySummary,  # noqa: F401
                      read_events, replay)
from .kvpool import PagedKV, PagePool, PrefixCache, page_digests  # noqa: F401
from .scheduler import (QueueFull, Request, RequestFailed,  # noqa: F401
                        RequestHandle, SlotScheduler, deserialize_request,
                        serialize_request)
from .train_loop import TrainLoop, TrainLoopConfig  # noqa: F401
from .serve_loop import ServeLoop, ServeSession  # noqa: F401
from .compile_cache import CompileCache  # noqa: F401
