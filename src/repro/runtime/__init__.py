from .engine import (DecodeEngine, StallClock, init_session_state,  # noqa: F401
                     make_decode_chunk, make_session_chunk,
                     make_session_refill, make_train_chunk)
from .scheduler import (QueueFull, Request, RequestHandle,  # noqa: F401
                        SlotScheduler)
from .train_loop import TrainLoop, TrainLoopConfig  # noqa: F401
from .serve_loop import ServeLoop, ServeSession  # noqa: F401
from .compile_cache import CompileCache  # noqa: F401
