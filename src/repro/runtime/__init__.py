from .engine import (DecodeEngine, StallClock, make_decode_chunk,  # noqa: F401
                     make_train_chunk)
from .train_loop import TrainLoop, TrainLoopConfig  # noqa: F401
from .serve_loop import ServeLoop  # noqa: F401
from .compile_cache import CompileCache  # noqa: F401
