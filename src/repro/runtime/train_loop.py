"""Training driver — fault-tolerant, straggler-aware, elastic-restartable.

The loop composes the substrate: sharded data feed (data/), double-buffered
prefetch, compiled train step (models/steps.py under the RegionPlan),
async checkpointing (checkpoint/), and the health monitors a 1000-node run
needs: per-step wall-time straggler detection, preemption-triggered final
checkpoint, and auto-resume.

With `steps_per_sync > 1` (and a `train_chunk` built by
`runtime/engine.make_train_chunk`), the loop dispatches a scan of K steps
per host round-trip: the straggler detector and logger sample at chunk
granularity, the host syncs O(total/K) times, and the train state is
donated through the chunk so steady-state training re-uses its buffers.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.runtime.engine import StallClock, stack_batches


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    checkpoint_dir: str = "/tmp/repro-ckpt"
    keep_checkpoints: int = 3
    # straggler detection: flag steps slower than mean + z * std
    straggler_z: float = 3.0
    straggler_warmup: int = 10
    # device-resident chunking: steps rolled into one scan per host sync
    # (needs a train_chunk callable; 1 = the classic per-step loop)
    steps_per_sync: int = 1


class StragglerDetector:
    """Per-step wall-time EMA + z-score detector (paper §8: synchronization
    is the dominant loss at scale — a straggling host shows up as a slow
    collective; on a fleet this event feeds the coordinator)."""

    def __init__(self, z: float = 3.0, warmup: int = 10):
        self.z = z
        self.warmup = warmup
        self.times: list[float] = []
        self.events: list[dict] = []

    def observe(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) <= self.warmup:
            return False
        hist = np.asarray(self.times[-100:-1])
        mu, sd = hist.mean(), hist.std() + 1e-9
        if dt > mu + self.z * sd:
            self.events.append({"step": step, "seconds": dt, "mean": mu,
                                "sigma": sd})
            return True
        return False


def _crossed(prev: int, step: int, every: int) -> bool:
    """Did [prev, step] cross a multiple of `every`? (chunk-safe cadence)"""
    return step // max(every, 1) > prev // max(every, 1)


class TrainLoop:
    def __init__(self, cfg: TrainLoopConfig, train_step: Callable,
                 state, batch_iter, *, state_shardings=None,
                 train_chunk: Callable | None = None):
        self.cfg = cfg
        self.train_step = train_step
        self.train_chunk = train_chunk
        self.state = state
        self.batch_iter = batch_iter
        self.state_shardings = state_shardings
        self.ckpt = CheckpointManager(cfg.checkpoint_dir,
                                      keep=cfg.keep_checkpoints)
        self.straggler = StragglerDetector(cfg.straggler_z,
                                           cfg.straggler_warmup)
        self.metrics_log: list[dict] = []
        self.clock = StallClock()
        self._preempted = False

    # -- fault handling -----------------------------------------------------
    def _install_preemption_handler(self):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not on main thread (tests)

    def maybe_resume(self) -> int:
        step = self.ckpt.latest_step()
        if step is None:
            return 0
        self.state = self.ckpt.restore(step, self.state,
                                       self.state_shardings)
        return step

    # -- main loop ------------------------------------------------------------
    def _next_batch(self):
        batch = next(self.batch_iter)
        if isinstance(batch, tuple):           # (step_idx, batch) feeds
            batch = batch[1]
        return batch

    def run(self, start_step: int | None = None) -> dict:
        self._install_preemption_handler()
        step = self.maybe_resume() if start_step is None else start_step
        k_cfg = max(self.cfg.steps_per_sync, 1)
        chunked = k_cfg > 1 and self.train_chunk is not None
        self.clock = StallClock()
        t_loop = time.perf_counter()
        while step < self.cfg.total_steps and not self._preempted:
            k = min(k_cfg, self.cfg.total_steps - step) if chunked else 1
            if chunked and k > 1:
                batches = [self._next_batch() for _ in range(k)]
                t0 = self.clock.dispatch()
                self.state, metrics = self.train_chunk(
                    self.state, stack_batches(batches))
                self.clock.sync(metrics["loss"])
                loss = float(np.asarray(metrics["loss"])[-1])
            else:
                batch = self._next_batch()
                t0 = self.clock.dispatch()
                self.state, metrics = self.train_step(self.state, batch)
                self.clock.sync(metrics["loss"])
                loss = float(np.asarray(metrics["loss"]).reshape(-1)[-1])
            dt = time.perf_counter() - t0
            prev, step = step, step + k
            slow = self.straggler.observe(step, dt)
            if _crossed(prev, step, self.cfg.log_every) or slow:
                row = {"step": step, "seconds": dt, "loss": loss,
                       "straggler": bool(slow)}
                if k > 1:
                    row["steps_in_chunk"] = k
                self.metrics_log.append(row)
            if _crossed(prev, step, self.cfg.checkpoint_every):
                self.ckpt.save(step, self.state)
        # final checkpoint on natural end or preemption
        self.ckpt.save(step, self.state, block=True)
        self.ckpt.wait()
        return {"final_step": step,
                "preempted": self._preempted,
                "wall_seconds": time.perf_counter() - t_loop,
                "straggler_events": self.straggler.events,
                "stall": self.clock.report(),
                "steps_per_sync": k_cfg if chunked else 1,
                "metrics": self.metrics_log}
