"""Compiled-executable cache — the RO-cache analogue (paper §5.2).

MemPool's software-managed read-only cache keeps the instruction stream hot
so 256 PEs never stall on fetch. Our PEs run a compiled XLA program; the
fetch path is lower+compile. The cache memoizes AOT-compiled executables
keyed on (step identity, arch, shapes, mesh, rules fingerprint), so elastic
restarts and repeated launches never pay recompilation ("cold boot" is the
paper's cache-refill phase; see bench Fig. 15).
"""

from __future__ import annotations

import hashlib
import json
from typing import Any, Callable

import jax

from repro.core import compat


def _fingerprint(*parts: Any) -> str:
    s = json.dumps([str(p) for p in parts], sort_keys=True)
    return hashlib.sha1(s.encode()).hexdigest()[:16]


class CompileCache:
    def __init__(self):
        self._cache: dict[str, Any] = {}
        self.hits = 0
        self.misses = 0

    def get(self, key_parts: tuple, build: Callable[[], Any]):
        key = _fingerprint(*key_parts)
        if key in self._cache:
            self.hits += 1
            return self._cache[key]
        self.misses += 1
        exe = build()
        self._cache[key] = exe
        return exe

    def compile_step(self, fn, args_sds, in_shardings, out_shardings,
                     donate, mesh, tag: str):
        key = (tag, jax.tree.map(lambda s: (s.shape, str(s.dtype)), args_sds),
               tuple(mesh.shape.items()) if hasattr(mesh.shape, "items")
               else mesh.shape)

        def build():
            with compat.set_mesh(mesh):
                return jax.jit(fn, in_shardings=in_shardings,
                               out_shardings=out_shardings,
                               donate_argnums=donate).lower(*args_sds).compile()

        return self.get(key, build)
