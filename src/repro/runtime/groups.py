"""Cluster-of-clusters serving: shard a session across a device mesh.

MemPool scales past one cluster by tiling the hierarchy — PEs form
tiles, tiles form groups, groups form the cluster — and keeping the
latency *within* a group flat while traffic *between* groups pays the
interconnect. This module is the serving-side analogue: the device mesh
is partitioned into **serving groups**, each owning a full engine
session cell (slot pool, paged KV pool + prefix cache, stall ledger,
fault hooks, journal), and a single `ShardedServeSession` front-end
keeps the familiar `submit / poll / stream / cancel / drain` surface
while a two-level scheduler decides *which group* a request lands in
before that group's own `SlotScheduler` decides *which slot*.

Placement is locality-aware the same way MemPool's router is: the
`MeshScheduler` scores each group with the paper's `TopologyModel`,
treating the fraction of a request's prompt already resident in the
group's warm `PrefixCache` as the local-access probability `p_local`
and the group's occupancy as the injected load. A request whose prompt
prefix is cached in group g models as mostly-local traffic there (low
latency -> routed there); a cold request falls through to pure load
balancing. Groups can be drained (stop placing, finish in-flight) or
quarantined (wedged — degraded capacity, not a dead session), mirroring
how a stalled MemPool group degrades bandwidth without wedging its
neighbours.

Layering: this module is pure host-side orchestration over N ordinary
`ServeSession`s — it owns no device code. Building the per-group
sessions (compiling the shared chunk fn, pinning each group's
params/state to its device, carving durable subdirectories) is the
cluster layer's job (`cluster.session.CompiledShardedServeSession`);
everything here works on any list of sessions, scripted test doubles
included.
"""

from __future__ import annotations

import dataclasses
from concurrent.futures import ThreadPoolExecutor
from typing import Iterator, Sequence

import numpy as np

from repro.core.interconnect import TOP_H, TopologyModel

from .engine import StallClock
from .faults import SessionWedged
from .scheduler import QueueFull


@dataclasses.dataclass(frozen=True)
class GroupPlan:
    """How the mesh is carved into serving groups.

    `devices[g]` is where group g's params/state live. With fewer
    devices than groups the assignment wraps (several groups time-share
    a device) — `degraded` flags that: scheduling semantics are intact
    but compute overlap is lost, which is what single-device CPU smoke
    runs exercise.
    """
    n_groups: int
    devices: tuple = ()

    @classmethod
    def build(cls, n_groups: int, devices: Sequence | None = None
              ) -> "GroupPlan":
        if n_groups < 1:
            raise ValueError(f"n_groups must be >= 1, got {n_groups}")
        if devices is None:
            import jax
            devices = jax.devices()
        devices = list(devices)
        if not devices:
            return cls(n_groups=n_groups, devices=())
        return cls(n_groups=n_groups,
                   devices=tuple(devices[g % len(devices)]
                                 for g in range(n_groups)))

    @property
    def degraded(self) -> bool:
        """True when groups share devices (round-robin wrapped)."""
        return len(set(map(id, self.devices))) < self.n_groups


@dataclasses.dataclass
class GroupView:
    """One group's load + locality snapshot, as the placement layer
    sees it. Built per-submit; `overlap_pages` is the measured prefix-
    cache overlap with the request being placed (0 when unpaged)."""
    gid: int
    free_slots: int
    queued: int
    usable_slots: int
    max_queue: int | None
    overlap_pages: int = 0


class MeshScheduler:
    """Level-1 placement: request -> serving group.

    Scores every eligible group with the paper's M/D/1 topology model
    (`TopologyModel.avg_latency`): the fraction of the prompt resident
    in the group's prefix cache is the local-access probability (warm
    cache -> mostly-local traffic -> low modeled latency) and the
    group's slot+queue occupancy is the injected load (busy group ->
    queueing term grows). Ties break on lifetime placements then gid,
    so equal groups round-robin deterministically.

    Quarantined groups (wedged sessions) and draining groups receive
    nothing; a group with a full class queue or zero usable slots is
    skipped for this request. When no group is eligible the placement
    raises `QueueFull` — the sharded analogue of a single session's
    bounded-queue backpressure.
    """

    def __init__(self, n_groups: int, *, page_size: int = 16,
                 topo_spec=TOP_H):
        if n_groups < 1:
            raise ValueError(f"n_groups must be >= 1, got {n_groups}")
        self.n_groups = n_groups
        self.page_size = max(int(page_size), 1)
        # each group plays the role of one tile: chance_local = 1/G
        self.topo = TopologyModel(topo_spec, n_tiles=max(n_groups, 1))
        self.placed = [0] * n_groups
        self.placements = 0
        self.locality_hits = 0
        self.rejections = 0
        self.quarantined: set[int] = set()
        self.draining: set[int] = set()

    # -- scoring ---------------------------------------------------------
    def score(self, view: GroupView, prompt_tokens: int) -> float:
        """Modeled latency of running this request in `view`'s group
        (lower is better). Monotone the two ways the invariant tests
        pin down: decreasing in prefix overlap, increasing in load."""
        covered = min(view.overlap_pages * self.page_size,
                      max(prompt_tokens - 1, 0))
        p_local = covered / max(prompt_tokens, 1)
        running = max(view.usable_slots - view.free_slots, 0)
        cap = view.usable_slots + (view.max_queue
                                   if view.max_queue is not None
                                   else view.usable_slots)
        injected = min((running + view.queued) / max(cap, 1), 1.0)
        return self.topo.avg_latency(injected, p_local=p_local)

    def eligible(self, view: GroupView) -> bool:
        return (view.gid not in self.quarantined
                and view.gid not in self.draining
                and view.usable_slots > 0
                and (view.max_queue is None
                     or view.queued < view.max_queue))

    def place(self, views: Sequence[GroupView], *,
              prompt_tokens: int = 1) -> int:
        """Pick the group for one request; returns its gid exactly once
        (never two groups). Raises `QueueFull` when no group can take
        work."""
        elig = [v for v in views if self.eligible(v)]
        if not elig:
            self.rejections += 1
            raise QueueFull(
                f"no serving group can accept work ({len(views)} groups: "
                f"{sorted(self.quarantined)} quarantined, "
                f"{sorted(self.draining)} draining)")
        best = min(elig, key=lambda v: (self.score(v, prompt_tokens),
                                        self.placed[v.gid], v.gid))
        self.placed[best.gid] += 1
        self.placements += 1
        if best.overlap_pages > 0:
            self.locality_hits += 1
        return best.gid

    # -- group lifecycle -------------------------------------------------
    def quarantine_group(self, gid: int) -> None:
        """Stop placing into a wedged group. In-flight work stays put;
        the session front-end skips the group's polls until recovery."""
        self._check(gid)
        self.quarantined.add(gid)

    def recover_group(self, gid: int) -> None:
        self._check(gid)
        self.quarantined.discard(gid)

    def drain_group(self, gid: int) -> None:
        """Stop placing into a group while it finishes in-flight work
        (e.g. ahead of maintenance). Unlike quarantine, the group keeps
        polling."""
        self._check(gid)
        self.draining.add(gid)

    def undrain_group(self, gid: int) -> None:
        self._check(gid)
        self.draining.discard(gid)

    def _check(self, gid: int) -> None:
        if not 0 <= gid < self.n_groups:
            raise ValueError(f"gid {gid} out of range "
                             f"[0, {self.n_groups})")

    def stats(self) -> dict:
        return {
            "placements": self.placements,
            "placed": list(self.placed),
            "locality_hits": self.locality_hits,
            "locality_rate": self.locality_hits / max(self.placements, 1),
            "rejections": self.rejections,
            "quarantined_groups": sorted(self.quarantined),
            "draining_groups": sorted(self.draining),
        }


@dataclasses.dataclass
class GroupRuntime:
    """One serving group: a full session cell pinned to one device."""
    gid: int
    session: object                     # ServeSession (or a test double)
    device: object = None

    def overlap_pages(self, prompt) -> int:
        """Measured prefix-cache overlap (whole warm pages) between this
        group's paged KV and `prompt`. 0 when the group is unpaged."""
        kv = getattr(self.session, "kv", None)
        if kv is None:
            return 0
        return int(kv.match_pages(np.asarray(prompt, np.int32).reshape(-1)))

    def view(self, prompt=None) -> GroupView:
        lv = self.session.scheduler.load_view()
        return GroupView(
            gid=self.gid,
            free_slots=lv["free_slots"],
            queued=lv["queued"],
            usable_slots=lv["usable_slots"],
            max_queue=lv["max_queue"],
            overlap_pages=(self.overlap_pages(prompt)
                           if prompt is not None else 0))


def _pooled_pct(sample_lists) -> dict:
    """Percentiles over the union of per-group raw samples (percentiles
    of percentiles would be meaningless, so pool the samples)."""
    xs = [t for samples in sample_lists for t in samples]
    pct = lambda q: (float(np.percentile(np.asarray(xs), q)) * 1e3
                     if xs else 0.0)
    return {"p50": pct(50), "p99": pct(99)}


class ShardedServeSession:
    """N serving groups behind the single-session API.

    `submit` runs level-1 placement (`MeshScheduler`) then delegates to
    the chosen group's `ServeSession.submit` (level 2: its own slot
    scheduler); the returned handle is the group's handle with a
    `.group` attribute stamped on. `poll` advances every live group by
    one chunk — concurrently via a thread pool when there is more than
    one group, since each group's device wait releases the GIL — and
    concatenates events in gid order. A group whose poll raises
    `SessionWedged` is quarantined: capacity degrades by one group, the
    session keeps serving, and `recover_group` folds it back in.

    Like `ServeSession`, the front-end is not thread-safe for
    concurrent *user* calls; the internal poll parallelism touches
    disjoint per-group state only.
    """

    def __init__(self, groups: Sequence[GroupRuntime], *,
                 mesh: MeshScheduler | None = None,
                 plan: GroupPlan | None = None):
        if not groups:
            raise ValueError("need at least one serving group")
        self.groups = list(groups)
        page_size = 16
        for g in self.groups:
            kv = getattr(g.session, "kv", None)
            if kv is not None:
                page_size = kv.pool.page_size
                break
        self.mesh = mesh or MeshScheduler(len(self.groups),
                                          page_size=page_size)
        self.plan = plan or GroupPlan(n_groups=len(self.groups),
                                      devices=tuple(g.device
                                                    for g in self.groups))
        self._pool = (ThreadPoolExecutor(
                          max_workers=len(self.groups),
                          thread_name_prefix="serve-group")
                      if len(self.groups) > 1 else None)

    @property
    def n_groups(self) -> int:
        return len(self.groups)

    def _live(self) -> list[GroupRuntime]:
        return [g for g in self.groups
                if g.gid not in self.mesh.quarantined]

    # -- submission ------------------------------------------------------
    def submit(self, prompt, max_new: int, *, klass: str = "latency",
               deadline_s: float | None = None):
        """Place one request into a group and enqueue it there. The
        handle is the group session's handle; `handle.group` records the
        placement. Raises `QueueFull` when no group can take work."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        views = [g.view(prompt) for g in self.groups]
        gid = self.mesh.place(views, prompt_tokens=int(prompt.size))
        handle = self.groups[gid].session.submit(
            prompt, max_new, klass=klass, deadline_s=deadline_s)
        handle.group = gid
        return handle

    def cancel(self, handle) -> bool:
        gid = getattr(handle, "group", None)
        if gid is None:
            return any(g.session.cancel(handle) for g in self.groups)
        return self.groups[gid].session.cancel(handle)

    # -- the chunk boundary ----------------------------------------------
    def _poll_group(self, g: GroupRuntime, timeout_s):
        try:
            return g.gid, g.session.poll(timeout_s), None
        except SessionWedged as e:
            return g.gid, [], e

    def poll(self, timeout_s: float | None = None) -> list:
        """Advance every live group by one chunk; returns the combined
        `(handle, new_tokens, done)` events in gid order. A wedged
        group is quarantined (stops being polled/placed) instead of
        failing the whole session; `stats()["placement"]` lists it."""
        live = self._live()
        if not live:
            return []
        if self._pool is None or len(live) == 1:
            results = [self._poll_group(g, timeout_s) for g in live]
        else:
            results = list(self._pool.map(
                lambda g: self._poll_group(g, timeout_s), live))
        events: list = []
        for gid, evs, wedge in sorted(results, key=lambda r: r[0]):
            for handle, toks, done in evs:
                if getattr(handle, "group", None) is None:
                    handle.group = gid
                events.append((handle, toks, done))
            if wedge is not None:
                self.mesh.quarantine_group(gid)
        return events

    @property
    def busy(self) -> bool:
        """True while any live group has queued/running work or pending
        terminal events."""
        return any(g.session.busy for g in self._live())

    def stream(self, timeout_s: float | None = None) -> Iterator:
        """Yield combined events until every live group runs dry.
        Submitting more work mid-stream extends it."""
        while self.busy:
            yield from self.poll(timeout_s)

    def drain(self, timeout_s: float | None = None) -> dict:
        """Run until every live group completes its submitted requests;
        returns `stats()`. Quarantined groups are excluded — their
        in-flight work resumes after `recover_group`."""
        for _ in self.stream(timeout_s):
            pass
        return self.stats()

    def drain_group(self, gid: int, timeout_s: float | None = None) -> dict:
        """Stop placing into group `gid`, run it dry, and leave it
        draining (call `undrain_group` to return it to rotation).
        Returns the group's stats."""
        self.mesh.drain_group(gid)
        g = self.groups[gid]
        while g.session.busy:
            g.session.poll(timeout_s)
        return g.session.stats()

    def undrain_group(self, gid: int) -> None:
        self.mesh.undrain_group(gid)

    def recover_group(self, gid: int) -> None:
        """Recover a quarantined group's wedged session and return it to
        placement rotation."""
        g = self.groups[gid]
        if getattr(g.session, "_wedged", False):
            g.session.recover_wedged()
        self.mesh.recover_group(gid)

    # -- durability ------------------------------------------------------
    @property
    def recovered(self) -> dict:
        """Terminal requests rebuilt from the journals at restore time.
        One group: the group's `{rid: handle}` map unchanged (drop-in
        for `ServeSession.recovered`); several: keyed `(gid, rid)`."""
        if len(self.groups) == 1:
            return self.groups[0].session.recovered
        out = {}
        for g in self.groups:
            for rid, h in g.session.recovered.items():
                out[(g.gid, rid)] = h
        return out

    def handle(self, gid: int, rid: int):
        return self.groups[gid].session.handle(rid)

    # -- stats -----------------------------------------------------------
    def stats(self) -> dict:
        """Aggregate serving stats plus the per-group breakdown.

        Counters sum across groups; `tokens_per_s` is the sum of the
        groups' windowed rates (they run concurrently); `occupancy_pct`
        is slot-weighted; `stall` is the `StallClock.merge` roll-up of
        the per-group ledgers (one shared wall, counters summed);
        `placement` is the mesh scheduler's ledger; `groups` maps gid to
        that group's full `ServeSession.stats()`.
        """
        per = {g.gid: g.session.stats() for g in self.groups}
        slots = sum(st["slots"] for st in per.values())
        occ = sum(st["occupancy_pct"] * st["slots"] for st in per.values())
        out = {
            "n_groups": len(self.groups),
            "requests_done": sum(st["requests_done"] for st in per.values()),
            "requests_failed": sum(st["requests_failed"]
                                   for st in per.values()),
            "requests_cancelled": sum(st["requests_cancelled"]
                                      for st in per.values()),
            "requests_shed": sum(st["requests_shed"] for st in per.values()),
            "emitted_total": sum(st["emitted_total"] for st in per.values()),
            "tokens_per_s": sum(st["tokens_per_s"] for st in per.values()),
            "occupancy_pct": occ / max(slots, 1),
            "slots": slots,
            "usable_slots": sum(st["usable_slots"] for st in per.values()),
            "queue_peak": max(st["queue_peak"] for st in per.values()),
            "ttft_ms": _pooled_pct(
                [getattr(g.session, "_ttfts", []) for g in self.groups]),
            "latency_ms": _pooled_pct(
                [getattr(g.session, "_latencies", [])
                 for g in self.groups]),
            "stall": StallClock.merge(
                [g.session.clock for g in self.groups]).report(),
            "placement": self.mesh.stats(),
            "groups": per,
        }
        kv_rows = [st["kv"] for st in per.values() if "kv" in st]
        if kv_rows:
            agg = {}
            for key in ("n_pages", "used_pages", "free_pages", "allocs",
                        "alloc_failures", "pages_shared", "cow_forks",
                        "prefix_hits", "prefix_misses", "evictions",
                        "prefill_skipped_tokens", "pool_exhausted"):
                vals = [kv.get(key) for kv in kv_rows if key in kv]
                if vals:
                    agg[key] = type(vals[0])(sum(vals))
            agg["page_size"] = kv_rows[0].get("page_size")
            if agg.get("n_pages"):
                agg["occupancy_pct"] = (100.0 * agg["used_pages"]
                                        / agg["n_pages"])
            out["kv"] = agg
        return out

    def close(self) -> None:
        for g in self.groups:
            g.session.close()
        if self._pool is not None:
            self._pool.shutdown(wait=False)
