"""Shared paged KV pool — the software shared-L1 for serving slots.

MemPool's defining choice is that 256 PEs share one global, multi-banked
L1 scratchpad instead of owning private slices (arXiv 2303.17742); a
core's working set lives wherever a bank is free, and the interconnect
makes every bank one hop away. The serving analogue built here: the model
KV cache stops being a private per-slot rectangle and becomes ONE global
pool of fixed-size KV pages ("banks"). Each slot owns only a small page
table; attention reads/writes are routed through it on device
(`models/attention.paged_update_cache` / `paged_gather`), and slot refill
becomes page allocation + table install instead of a full cache-zero
pass.

Three host-side pieces live in this module:

* `PagePool` — the allocator: a free list over pages `1..n_pages-1`
  (page 0 is the reserved *trash page*, see below) with per-page
  refcounts. `alloc` raises the typed `PoolExhausted` so the session can
  requeue instead of crash; `release` decrements and returns the pages
  that actually became free.
* `PrefixCache` — copy-on-write prefix sharing. Completed requests
  publish their *fully written* prompt pages keyed by a rolling hash of
  page-aligned token prefixes; a later request with the same preamble
  maps those pages read-only (refcount++) and skips their prefill
  entirely — the TTFT collapse for shared system prompts. A shared page
  is never written: the session skips exactly the tokens the shared
  pages cover, so writes land at positions >= the shared region. The one
  exception is an exact full-prompt hit, where the last prompt token
  must still be re-fed (its output is the first sampled token) and would
  write inside a shared page — that page is COW-forked: a fresh page is
  allocated and the shared page's contents device-copied before install.
* `PagedKV` — the per-session façade the `ServeSession` driver talks to:
  `admit(slot, prompt, max_new)` builds the slot's table row (shared +
  fresh pages, prefill-skip count, pending COW copies), `release(slot)`
  returns everything and re-points the row at the trash page, and
  `stats()` reports pool occupancy / pages shared / prefill tokens
  skipped for the serving report.

Why a trash page: the session cell steps ALL slots whenever any slot is
live (`engine.session_chunk_fn`), so a finished slot keeps scatter-
writing K/V at its frozen position every chunk. Its released pages may
already belong to another request, so release must re-point the dead
slot's table at a page nobody reads — page 0. Reads from stale/garbage
pages are harmless (masked attention gives them exactly-zero softmax
weight); only NaN survives the mask (0 * NaN), which is why pages freed
from a corrupted slot are scrubbed on device before reuse.
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

TRASH_PAGE = 0


class PoolExhausted(RuntimeError):
    """Typed allocation failure: the pool has fewer free pages than the
    request needs. Carries the shortfall so the scheduler can reason
    about it (requeue / shed) instead of crashing the session."""

    def __init__(self, needed: int, free: int):
        super().__init__(f"KV pool exhausted: need {needed} pages, "
                         f"{free} free")
        self.needed = needed
        self.free = free


class PagePool:
    """Free-list page allocator with per-page refcounts.

    Pages are integer ids in `[1, n_pages)`; page 0 is the reserved trash
    page and is never handed out. A page's refcount is the number of slot
    tables + prefix-cache entries pointing at it; `release` only frees a
    page when the count hits zero (shared prefix pages survive their
    first owner).
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError(f"n_pages must be >= 2 (page 0 is reserved), "
                             f"got {n_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.refcount = np.zeros(n_pages, np.int32)
        self.refcount[TRASH_PAGE] = 1          # pinned forever
        self._free: list[int] = list(range(n_pages - 1, 0, -1))
        # pages that may hold NaN (freed from a corrupted slot); the
        # session scrubs these on device before they are handed out again
        self.dirty: set[int] = set()
        self.allocs = 0
        self.alloc_failures = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    def alloc(self, n: int) -> list[int]:
        """Take `n` fresh pages (refcount 1 each) or raise `PoolExhausted`
        without taking any."""
        if n < 0:
            raise ValueError(f"alloc of {n} pages")
        if n > len(self._free):
            self.alloc_failures += 1
            raise PoolExhausted(n, len(self._free))
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            assert self.refcount[p] == 0, f"page {p} double-allocated"
            self.refcount[p] = 1
        self.allocs += n
        return pages

    def ref(self, pages) -> None:
        """Add one reference to each page (prefix-cache share)."""
        for p in pages:
            if p == TRASH_PAGE:
                continue
            assert self.refcount[p] > 0, f"ref of free page {p}"
            self.refcount[p] += 1

    def release(self, pages) -> list[int]:
        """Drop one reference per page; returns the pages that became
        free (refcount hit zero) in release order."""
        freed = []
        for p in pages:
            if p == TRASH_PAGE:
                continue
            assert self.refcount[p] > 0, f"release of free page {p}"
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self._free.append(p)
                freed.append(p)
        return freed

    def mark_dirty(self, pages) -> None:
        self.dirty.update(int(p) for p in pages if p != TRASH_PAGE)

    def take_dirty_free(self) -> list[int]:
        """Dirty pages that are currently free — the scrub set. Clears
        the returned pages' dirty marks."""
        out = [p for p in sorted(self.dirty) if self.refcount[p] == 0]
        self.dirty.difference_update(out)
        return out

    def stats(self) -> dict:
        return {"n_pages": self.n_pages, "page_size": self.page_size,
                "used_pages": self.used_pages,
                "free_pages": self.free_pages,
                "occupancy_pct": 100.0 * self.used_pages /
                max(self.n_pages - 1, 1),
                "allocs": self.allocs,
                "alloc_failures": self.alloc_failures}


def _page_key(prev_key: bytes, tokens: np.ndarray) -> bytes:
    """Rolling hash chain: key of page k = H(key of page k-1 || tokens)."""
    h = hashlib.blake2b(prev_key, digest_size=16)
    h.update(np.ascontiguousarray(tokens, np.int32).tobytes())
    return h.digest()


@dataclasses.dataclass
class _PrefixEntry:
    page: int
    tokens: np.ndarray     # the page's token content (page_size,)
    hits: int = 0


class PrefixCache:
    """Hash-chained map from page-aligned token prefixes to pool pages.

    `insert(tokens, pages)` publishes the fully written prompt pages of a
    completed request (each gains a cache reference so it outlives its
    owner); `match(tokens)` walks the chain and returns the longest run
    of shared pages covering a prefix of `tokens`. Entries are evicted
    LRU-ish via `evict(n_pages)` when the pool runs dry.
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        self._chain: dict[bytes, _PrefixEntry] = {}
        self._order: list[bytes] = []          # insertion order for evict
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._chain)

    def insert(self, tokens: np.ndarray, pages) -> int:
        """Publish the fully covered prompt pages. Returns how many new
        pages were published (already-cached prefixes are skipped)."""
        ps = self.pool.page_size
        tokens = np.asarray(tokens, np.int32)
        n_full = min(tokens.size // ps, len(pages))
        key = b"root"
        published = 0
        for k in range(n_full):
            page_toks = tokens[k * ps:(k + 1) * ps]
            key = _page_key(key, page_toks)
            if key in self._chain:
                continue                        # prefix already published
            page = int(pages[k])
            if page == TRASH_PAGE:
                break
            self.pool.ref([page])
            self._chain[key] = _PrefixEntry(page, page_toks.copy())
            self._order.append(key)
            published += 1
        return published

    def match(self, tokens: np.ndarray) -> list[int]:
        """Longest chain of cached pages covering a prefix of `tokens`
        (bit-exact token match, not just hash match). Bumps refcounts is
        NOT done here — the caller refs the pages it actually installs."""
        ps = self.pool.page_size
        tokens = np.asarray(tokens, np.int32)
        key = b"root"
        out: list[int] = []
        for k in range(tokens.size // ps):
            page_toks = tokens[k * ps:(k + 1) * ps]
            key = _page_key(key, page_toks)
            e = self._chain.get(key)
            if e is None or not np.array_equal(e.tokens, page_toks):
                break
            e.hits += 1
            out.append(e.page)
        if out:
            self.hits += 1
        else:
            self.misses += 1
        return out

    def evict(self, n_pages: int) -> list[int]:
        """Drop cache references until `n_pages` pages were freed (or the
        cache is empty). Returns the freed page ids."""
        freed: list[int] = []
        while self._order and len(freed) < n_pages:
            key = self._order.pop(0)
            e = self._chain.pop(key)
            freed += self.pool.release([e.page])
        return freed

    def clear(self) -> list[int]:
        return self.evict(len(self._chain))


@dataclasses.dataclass
class SlotAlloc:
    """What `PagedKV.admit` hands the session for one slot."""

    table: np.ndarray            # (pages_per_slot,) int32 page ids
    prefill_skip: int            # prompt tokens covered by shared pages
    shared_pages: int            # pages mapped read-only from the cache
    cow_copies: list[tuple[int, int]]   # (src, dst) device page copies


class PagedKV:
    """Per-session paged-KV manager: pool + prefix cache + slot tables.

    The session driver calls `admit` at refill boundaries (may raise
    `PoolExhausted` — the request stays queued), `release` whenever a
    slot retires (done, cancelled, shed, killed, quarantined), and
    `publish` when a request completes cleanly to seed the prefix cache.
    All bookkeeping is host-side numpy; the device only ever sees the
    int32 table rows.
    """

    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 pages_per_slot: int, *, prefix_cache: bool = True):
        self.pool = PagePool(n_pages, page_size)
        self.prefix = PrefixCache(self.pool) if prefix_cache else None
        self.n_slots = int(n_slots)
        self.pages_per_slot = int(pages_per_slot)
        # owned: the references this slot must drop on release (includes a
        # COW fork's source page, which stays alive while the copy is
        # pending); table: the page ids the device actually addresses.
        self._slot_owned: list[list[int]] = [[] for _ in range(n_slots)]
        self._slot_table: list[list[int]] = [[] for _ in range(n_slots)]
        self._slot_prompt: list[np.ndarray | None] = [None] * n_slots
        # counters for stats()
        self.pages_shared_total = 0
        self.prefill_skipped_tokens = 0
        self.cow_forks = 0

    # -- admission -----------------------------------------------------------
    def admit(self, slot: int, prompt: np.ndarray,
              max_new: int) -> SlotAlloc:
        """Build slot's page table for `prompt` + up to `max_new` output
        tokens. Shared prefix pages are mapped read-only; the remainder
        is freshly allocated. Raises `PoolExhausted` (allocating nothing)
        when the pool cannot cover the fresh pages even after evicting
        prefix-cache entries."""
        assert not self._slot_owned[slot], f"slot {slot} already mapped"
        ps = self.pool.page_size
        prompt = np.asarray(prompt, np.int32)
        total_tokens = prompt.size + max_new
        n_total = -(-total_tokens // ps)       # ceil
        if n_total > self.pages_per_slot:
            raise ValueError(
                f"request needs {n_total} pages > pages_per_slot "
                f"{self.pages_per_slot} (prompt {prompt.size} + "
                f"max_new {max_new}, page_size {ps})")

        shared = self.prefix.match(prompt) if self.prefix else []
        # the final prompt token must be re-fed (its forward pass emits
        # the first sampled token), so never skip the whole prompt; an
        # exact full-coverage hit COW-forks the page the re-fed token
        # writes into.
        skip = min(len(shared) * ps, max(prompt.size - 1, 0))
        fork_last = bool(shared) and len(shared) * ps > skip
        n_fresh = n_total - len(shared) + (1 if fork_last else 0)

        # hold the matched pages across a possible eviction (the prefix
        # cache may otherwise free exactly the pages we are about to map)
        self.pool.ref(shared)
        try:
            fresh = self.pool.alloc(n_fresh)
        except PoolExhausted:
            if self.prefix is not None:
                self.prefix.evict(n_fresh - self.pool.free_pages)
            try:
                fresh = self.pool.alloc(n_fresh)
            except PoolExhausted:
                self.pool.release(shared)       # allocate-nothing contract
                raise

        cow: list[tuple[int, int]] = []
        mapped = list(shared)
        if fork_last:
            src, dst = mapped[-1], fresh[0]
            mapped[-1] = dst                    # table points at the copy;
            cow.append((src, dst))              # src stays owned (ref held)
            self.cow_forks += 1
        pages = mapped + fresh[(1 if fork_last else 0):]
        table = np.full(self.pages_per_slot, TRASH_PAGE, np.int32)
        table[:len(pages)] = pages
        self._slot_owned[slot] = shared + fresh
        self._slot_table[slot] = pages
        self._slot_prompt[slot] = prompt
        self.pages_shared_total += len(shared)
        self.prefill_skipped_tokens += skip
        return SlotAlloc(table=table, prefill_skip=skip,
                         shared_pages=len(shared), cow_copies=cow)

    # -- retirement ----------------------------------------------------------
    def publish(self, slot: int) -> int:
        """Seed the prefix cache with the slot's fully written prompt
        pages (call on clean request completion, before `release`)."""
        if self.prefix is None or self._slot_prompt[slot] is None:
            return 0
        return self.prefix.insert(self._slot_prompt[slot],
                                  self._slot_table[slot])

    def release(self, slot: int, *, dirty: bool = False) -> list[int]:
        """Return the slot's pages to the pool (shared pages survive as
        long as other references remain). `dirty=True` marks the freed
        pages for a device scrub before reuse (NaN corruption). Returns
        the freed page ids."""
        owned = self._slot_owned[slot]
        self._slot_owned[slot] = []
        self._slot_table[slot] = []
        self._slot_prompt[slot] = None
        freed = self.pool.release(owned)
        if dirty:
            self.pool.mark_dirty(freed)
        return freed

    def reset(self) -> None:
        """Forget everything (wedge recovery: the device pool was rebuilt
        from scratch, so every table, page, and prefix entry is void)."""
        for s in range(self.n_slots):
            self._slot_owned[s] = []
            self._slot_table[s] = []
            self._slot_prompt[s] = None
        self.pool = PagePool(self.pool.n_pages, self.pool.page_size)
        if self.prefix is not None:
            self.prefix = PrefixCache(self.pool)

    def slot_pages(self, slot: int) -> list[int]:
        """The page ids the slot's device table addresses (table order)."""
        return list(self._slot_table[slot])

    def match_len(self, prompt) -> int:
        """Reusable-prefix length in tokens — the scheduler's page-level
        admission score (peek only: no refcounts, no hit accounting)."""
        if self.prefix is None:
            return 0
        ps = self.pool.page_size
        tokens = np.asarray(prompt, np.int32)
        key, n = b"root", 0
        for k in range(tokens.size // ps):
            page_toks = tokens[k * ps:(k + 1) * ps]
            key = _page_key(key, page_toks)
            e = self.prefix._chain.get(key)
            if e is None or not np.array_equal(e.tokens, page_toks):
                break
            n += ps
        return n

    def stats(self) -> dict:
        out = dict(self.pool.stats())
        out.update(pages_shared=self.pages_shared_total,
                   prefill_skipped_tokens=self.prefill_skipped_tokens,
                   cow_forks=self.cow_forks)
        if self.prefix is not None:
            out.update(prefix_entries=len(self.prefix),
                       prefix_hits=self.prefix.hits,
                       prefix_misses=self.prefix.misses)
        return out
