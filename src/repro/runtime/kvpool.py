"""Shared paged KV pool — the software shared-L1 for serving slots.

MemPool's defining choice is that 256 PEs share one global, multi-banked
L1 scratchpad instead of owning private slices (arXiv 2303.17742); a
core's working set lives wherever a bank is free, and the interconnect
makes every bank one hop away. The serving analogue built here: the model
KV cache stops being a private per-slot rectangle and becomes ONE global
pool of fixed-size KV pages ("banks"). Each slot owns only a small page
table; attention reads/writes are routed through it on device
(`models/attention.paged_update_cache` / `paged_gather`), and slot refill
becomes page allocation + table install instead of a full cache-zero
pass.

Three host-side pieces live in this module:

* `PagePool` — the allocator: a free list over pages `1..n_pages-1`
  (page 0 is the reserved *trash page*, see below) with per-page
  refcounts. `alloc` raises the typed `PoolExhausted` so the session can
  requeue instead of crash; `release` decrements and returns the pages
  that actually became free.
* `PrefixCache` — copy-on-write prefix sharing. Completed requests
  publish their *fully written* prompt pages keyed by a rolling hash of
  page-aligned token prefixes; a later request with the same preamble
  maps those pages read-only (refcount++) and skips their prefill
  entirely — the TTFT collapse for shared system prompts. A shared page
  is never written: the session skips exactly the tokens the shared
  pages cover, so writes land at positions >= the shared region. The one
  exception is an exact full-prompt hit, where the last prompt token
  must still be re-fed (its output is the first sampled token) and would
  write inside a shared page — that page is COW-forked: a fresh page is
  allocated and the shared page's contents device-copied before install.
* `PagedKV` — the per-session façade the `ServeSession` driver talks to:
  `admit(slot, prompt, max_new)` builds the slot's table row (shared +
  fresh pages, prefill-skip count, pending COW copies), `release(slot)`
  returns everything and re-points the row at the trash page, and
  `stats()` reports pool occupancy / pages shared / prefill tokens
  skipped for the serving report.

Why a trash page: the session cell steps ALL slots whenever any slot is
live (`engine.session_chunk_fn`), so a finished slot keeps scatter-
writing K/V at its frozen position every chunk. Its released pages may
already belong to another request, so release must re-point the dead
slot's table at a page nobody reads — page 0. Reads from stale/garbage
pages are harmless (masked attention gives them exactly-zero softmax
weight); only NaN survives the mask (0 * NaN), which is why pages freed
from a corrupted slot are scrubbed on device before reuse.
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

TRASH_PAGE = 0


class PoolExhausted(RuntimeError):
    """Typed allocation failure: the pool has fewer free pages than the
    request needs. Carries the shortfall so the scheduler can reason
    about it (requeue / shed) instead of crashing the session."""

    def __init__(self, needed: int, free: int):
        super().__init__(f"KV pool exhausted: need {needed} pages, "
                         f"{free} free")
        self.needed = needed
        self.free = free


class PagePool:
    """Free-list page allocator with per-page refcounts.

    Pages are integer ids in `[1, n_pages)`; page 0 is the reserved trash
    page and is never handed out. A page's refcount is the number of slot
    tables + prefix-cache entries pointing at it; `release` only frees a
    page when the count hits zero (shared prefix pages survive their
    first owner).
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError(f"n_pages must be >= 2 (page 0 is reserved), "
                             f"got {n_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.refcount = np.zeros(n_pages, np.int32)
        self.refcount[TRASH_PAGE] = 1          # pinned forever
        self._free: list[int] = list(range(n_pages - 1, 0, -1))
        # pages that may hold NaN (freed from a corrupted slot); the
        # session scrubs these on device before they are handed out again
        self.dirty: set[int] = set()
        # pages whose content failed an integrity check: permanently out
        # of circulation (they count as used capacity, never re-enter the
        # free list — the bank is fenced off, the cluster keeps serving)
        self.quarantined: set[int] = set()
        self.allocs = 0
        self.alloc_failures = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    def alloc(self, n: int) -> list[int]:
        """Take `n` fresh pages (refcount 1 each) or raise `PoolExhausted`
        without taking any."""
        if n < 0:
            raise ValueError(f"alloc of {n} pages")
        if n > len(self._free):
            self.alloc_failures += 1
            raise PoolExhausted(n, len(self._free))
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            assert self.refcount[p] == 0, f"page {p} double-allocated"
            self.refcount[p] = 1
        self.allocs += n
        return pages

    def ref(self, pages) -> None:
        """Add one reference to each page (prefix-cache share)."""
        for p in pages:
            if p == TRASH_PAGE:
                continue
            assert self.refcount[p] > 0, f"ref of free page {p}"
            self.refcount[p] += 1

    def release(self, pages) -> list[int]:
        """Drop one reference per page; returns the pages that became
        free (refcount hit zero) in release order."""
        freed = []
        for p in pages:
            if p == TRASH_PAGE:
                continue
            assert self.refcount[p] > 0, f"release of free page {p}"
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                if p in self.quarantined:
                    continue               # fenced off: never reallocated
                self._free.append(p)
                freed.append(p)
        return freed

    def quarantine(self, page: int) -> None:
        """Fence a page off permanently: it never re-enters the free list
        (current holders drop their references normally; the page just
        stays dead afterwards)."""
        page = int(page)
        if page == TRASH_PAGE:
            return
        self.quarantined.add(page)
        if self.refcount[page] == 0 and page in self._free:
            self._free.remove(page)

    def mark_dirty(self, pages) -> None:
        self.dirty.update(int(p) for p in pages if p != TRASH_PAGE)

    def take_dirty_free(self) -> list[int]:
        """Dirty pages that are currently free — the scrub set. Clears
        the returned pages' dirty marks."""
        out = [p for p in sorted(self.dirty) if self.refcount[p] == 0]
        self.dirty.difference_update(out)
        return out

    def stats(self) -> dict:
        return {"n_pages": self.n_pages, "page_size": self.page_size,
                "used_pages": self.used_pages,
                "free_pages": self.free_pages,
                "occupancy_pct": 100.0 * self.used_pages /
                max(self.n_pages - 1, 1),
                "allocs": self.allocs,
                "alloc_failures": self.alloc_failures,
                "quarantined_pages": len(self.quarantined)}


def _page_key(prev_key: bytes, tokens: np.ndarray) -> bytes:
    """Rolling hash chain: key of page k = H(key of page k-1 || tokens)."""
    h = hashlib.blake2b(prev_key, digest_size=16)
    h.update(np.ascontiguousarray(tokens, np.int32).tobytes())
    return h.digest()


def page_digests(arrays, n: int) -> list[bytes]:
    """Content checksum per page from a page-major device readback.

    `arrays` is what the session's `page_read_fn` returns: one array per
    pageable cache leaf, each with the page axis first (shape (n, ...)).
    The digest of page j folds page j of every leaf, so any single leaf's
    corruption changes it."""
    host = [np.asarray(a) for a in arrays]
    out = []
    for j in range(n):
        h = hashlib.blake2b(digest_size=16)
        for a in host:
            h.update(np.ascontiguousarray(a[j]).tobytes())
        out.append(h.digest())
    return out


@dataclasses.dataclass
class _PrefixEntry:
    page: int
    tokens: np.ndarray     # the page's token content (page_size,)
    parent: bytes = b"root"    # chain key of the previous page's entry
    hits: int = 0
    last_used: int = 0     # logical tick of the last insert/match touch


class PrefixCache:
    """Hash-chained map from page-aligned token prefixes to pool pages.

    `insert(tokens, pages)` publishes the fully written prompt pages of a
    completed request (each gains a cache reference so it outlives its
    owner); `match(tokens)` walks the chain and returns the longest run
    of shared pages covering a prefix of `tokens`. Under memory pressure
    `evict(n_pages)` drops entries cold-first (LRU by logical touch
    tick), preferring pages the cache is the sole owner of — evicting
    those actually frees memory, instead of only reclaiming pages that
    already had no references.
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        self._chain: dict[bytes, _PrefixEntry] = {}
        self._order: list[bytes] = []          # insertion order (stable)
        self.hits = 0
        self.misses = 0
        self.evictions = 0        # entries dropped under memory pressure
        self._tick = 0            # logical clock for LRU recency

    def _touch(self) -> int:
        self._tick += 1
        return self._tick

    def __len__(self) -> int:
        return len(self._chain)

    def insert(self, tokens: np.ndarray, pages) -> int:
        """Publish the fully covered prompt pages. Returns how many new
        pages were published (already-cached prefixes are skipped)."""
        ps = self.pool.page_size
        tokens = np.asarray(tokens, np.int32)
        n_full = min(tokens.size // ps, len(pages))
        key = b"root"
        published = 0
        for k in range(n_full):
            page_toks = tokens[k * ps:(k + 1) * ps]
            parent, key = key, _page_key(key, page_toks)
            if key in self._chain:
                self._chain[key].last_used = self._touch()   # re-warmed
                continue                        # prefix already published
            page = int(pages[k])
            if page == TRASH_PAGE or page in self.pool.quarantined:
                break
            self.pool.ref([page])
            self._chain[key] = _PrefixEntry(page, page_toks.copy(), parent,
                                            last_used=self._touch())
            self._order.append(key)
            published += 1
        return published

    def match(self, tokens: np.ndarray) -> list[int]:
        """Longest chain of cached pages covering a prefix of `tokens`
        (bit-exact token match, not just hash match). Bumps refcounts is
        NOT done here — the caller refs the pages it actually installs."""
        ps = self.pool.page_size
        tokens = np.asarray(tokens, np.int32)
        key = b"root"
        out: list[int] = []
        for k in range(tokens.size // ps):
            page_toks = tokens[k * ps:(k + 1) * ps]
            key = _page_key(key, page_toks)
            e = self._chain.get(key)
            if e is None or not np.array_equal(e.tokens, page_toks):
                break
            e.hits += 1
            e.last_used = self._touch()
            out.append(e.page)
        if out:
            self.hits += 1
        else:
            self.misses += 1
        return out

    def evict(self, n_pages: int) -> list[int]:
        """Drop cache entries until `n_pages` pages were freed (or the
        cache is empty), coldest first (LRU by last insert/match touch).
        Returns the freed page ids.

        Entries whose page the cache is the *sole* owner of go first:
        dropping one of those actually frees a page, where dropping an
        entry still shared with a running slot frees nothing now and
        only loses future reuse — those are the last resort. Dropping an
        entry cascades to its chain descendants (a suffix is unreachable
        without its prefix), which LRU order already favours: a match
        touches every entry on its path, so a parent is never colder
        than its children."""
        freed: list[int] = []
        while self._chain and len(freed) < n_pages:
            key = min(
                self._chain,
                key=lambda k: (
                    int(self.pool.refcount[self._chain[k].page]) > 1,
                    self._chain[k].last_used))
            freed += self._drop_chain(key)
        return freed

    def _drop_chain(self, key: bytes) -> list[int]:
        """Evict one entry and (transitively) its descendants; returns
        the pages that became free."""
        doomed = {key}
        changed = True
        while changed:
            changed = False
            for k, e in self._chain.items():
                if k not in doomed and e.parent in doomed:
                    doomed.add(k)
                    changed = True
        freed: list[int] = []
        for k in doomed:
            e = self._chain.pop(k)
            self._order.remove(k)
            self.evictions += 1
            freed += self.pool.release([e.page])
        return freed

    def drop_page(self, page: int) -> list[int]:
        """Remove every chain entry routed through `page` — and, because
        a chain suffix is meaningless without its prefix, every entry
        downstream of one (transitively via `parent` links). Releases the
        dropped entries' cache references; returns the pages that became
        free."""
        doomed = {k for k, e in self._chain.items() if e.page == page}
        changed = bool(doomed)
        while changed:
            changed = False
            for k, e in self._chain.items():
                if k not in doomed and e.parent in doomed:
                    doomed.add(k)
                    changed = True
        freed: list[int] = []
        for k in doomed:
            e = self._chain.pop(k)
            self._order.remove(k)
            freed += self.pool.release([e.page])
        return freed

    def clear(self) -> list[int]:
        return self.evict(len(self._chain))


@dataclasses.dataclass
class SlotAlloc:
    """What `PagedKV.admit` hands the session for one slot."""

    table: np.ndarray            # (pages_per_slot,) int32 page ids
    prefill_skip: int            # prompt tokens covered by shared pages
    shared_pages: int            # pages mapped read-only from the cache
    cow_copies: list[tuple[int, int]]   # (src, dst) device page copies


class PagedKV:
    """Per-session paged-KV manager: pool + prefix cache + slot tables.

    The session driver calls `admit` at refill boundaries (may raise
    `PoolExhausted` — the request stays queued), `release` whenever a
    slot retires (done, cancelled, shed, killed, quarantined), and
    `publish` when a request completes cleanly to seed the prefix cache.
    All bookkeeping is host-side numpy; the device only ever sees the
    int32 table rows.
    """

    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 pages_per_slot: int, *, prefix_cache: bool = True):
        self.pool = PagePool(n_pages, page_size)
        self.prefix = PrefixCache(self.pool) if prefix_cache else None
        self.n_slots = int(n_slots)
        self.pages_per_slot = int(pages_per_slot)
        # owned: the references this slot must drop on release (includes a
        # COW fork's source page, which stays alive while the copy is
        # pending); table: the page ids the device actually addresses.
        self._slot_owned: list[list[int]] = [[] for _ in range(n_slots)]
        self._slot_table: list[list[int]] = [[] for _ in range(n_slots)]
        self._slot_prompt: list[np.ndarray | None] = [None] * n_slots
        # counters for stats()
        self.pages_shared_total = 0
        self.prefill_skipped_tokens = 0
        self.cow_forks = 0
        # per-page content checksums, stamped at publish (integrity)
        self.checksums: dict[int, bytes] = {}
        self.integrity_checks = 0
        self.integrity_violations = 0
        self.integrity_repairs = 0
        self._scrub_cursor = 0

    # -- admission -----------------------------------------------------------
    def admit(self, slot: int, prompt: np.ndarray, max_new: int,
              *, verify=None) -> SlotAlloc:
        """Build slot's page table for `prompt` + up to `max_new` output
        tokens. Shared prefix pages are mapped read-only; the remainder
        is freshly allocated. Raises `PoolExhausted` (allocating nothing)
        when the pool cannot cover the fresh pages even after evicting
        prefix-cache entries.

        `verify(pages) -> bad_pages` is the integrity hook: when set,
        prefix-matched pages are content-checked against their publish
        checksums *before* they are shared. Corrupt pages are
        quarantined (chain dropped), the match is retried — it now stops
        at the clean prefix — and the request proceeds with fresh pages
        instead: repair by recompute, never a crash."""
        assert not self._slot_owned[slot], f"slot {slot} already mapped"
        ps = self.pool.page_size
        prompt = np.asarray(prompt, np.int32)
        total_tokens = prompt.size + max_new
        n_total = -(-total_tokens // ps)       # ceil
        if n_total > self.pages_per_slot:
            raise ValueError(
                f"request needs {n_total} pages > pages_per_slot "
                f"{self.pages_per_slot} (prompt {prompt.size} + "
                f"max_new {max_new}, page_size {ps})")

        shared = self.prefix.match(prompt) if self.prefix else []
        if shared and verify is not None:
            bad = list(verify(shared))
            if bad:
                for p in bad:
                    self.quarantine_page(p)
                # the poisoned chain is gone; only the clean prefix (if
                # any) can match now — the rest re-prefills from tokens
                shared = self.prefix.match(prompt) if self.prefix else []
                self.integrity_repairs += 1
        # the final prompt token must be re-fed (its forward pass emits
        # the first sampled token), so never skip the whole prompt; an
        # exact full-coverage hit COW-forks the page the re-fed token
        # writes into.
        skip = min(len(shared) * ps, max(prompt.size - 1, 0))
        fork_last = bool(shared) and len(shared) * ps > skip
        n_fresh = n_total - len(shared) + (1 if fork_last else 0)

        # hold the matched pages across a possible eviction (the prefix
        # cache may otherwise free exactly the pages we are about to map)
        self.pool.ref(shared)
        try:
            fresh = self.pool.alloc(n_fresh)
        except PoolExhausted:
            if self.prefix is not None:
                evicted = self.prefix.evict(n_fresh - self.pool.free_pages)
                self._purge_checksums(evicted)
            try:
                fresh = self.pool.alloc(n_fresh)
            except PoolExhausted:
                self.pool.release(shared)       # allocate-nothing contract
                raise

        cow: list[tuple[int, int]] = []
        mapped = list(shared)
        if fork_last:
            src, dst = mapped[-1], fresh[0]
            mapped[-1] = dst                    # table points at the copy;
            cow.append((src, dst))              # src stays owned (ref held)
            self.cow_forks += 1
        pages = mapped + fresh[(1 if fork_last else 0):]
        table = np.full(self.pages_per_slot, TRASH_PAGE, np.int32)
        table[:len(pages)] = pages
        self._slot_owned[slot] = shared + fresh
        self._slot_table[slot] = pages
        self._slot_prompt[slot] = prompt
        self.pages_shared_total += len(shared)
        self.prefill_skipped_tokens += skip
        return SlotAlloc(table=table, prefill_skip=skip,
                         shared_pages=len(shared), cow_copies=cow)

    # -- retirement ----------------------------------------------------------
    def publishable_pages(self, slot: int) -> list[int]:
        """The slot's fully written prompt pages — the set `publish` would
        seed the prefix cache with (and the set whose content the session
        digests for the integrity stamp)."""
        if self.prefix is None or self._slot_prompt[slot] is None:
            return []
        ps = self.pool.page_size
        prompt = self._slot_prompt[slot]
        n_full = min(prompt.size // ps, len(self._slot_table[slot]))
        return [p for p in self._slot_table[slot][:n_full]
                if p != TRASH_PAGE]

    def publish(self, slot: int, *, digests: "dict[int, bytes] | None"
                = None) -> int:
        """Seed the prefix cache with the slot's fully written prompt
        pages (call on clean request completion, before `release`).
        `digests` stamps each page's content checksum; a page that
        already carries a stamp keeps it (re-stamping a shared page from
        possibly-corrupted current content would mask the corruption)."""
        if self.prefix is None or self._slot_prompt[slot] is None:
            return 0
        published = self.prefix.insert(self._slot_prompt[slot],
                                       self._slot_table[slot])
        for page, digest in (digests or {}).items():
            if int(page) in self.pool.quarantined:
                continue
            self.checksums.setdefault(int(page), digest)
        return published

    def release(self, slot: int, *, dirty: bool = False) -> list[int]:
        """Return the slot's pages to the pool (shared pages survive as
        long as other references remain). `dirty=True` marks the freed
        pages for a device scrub before reuse (NaN corruption). Returns
        the freed page ids."""
        owned = self._slot_owned[slot]
        self._slot_owned[slot] = []
        self._slot_table[slot] = []
        self._slot_prompt[slot] = None
        freed = self.pool.release(owned)
        self._purge_checksums(freed)
        if dirty:
            self.pool.mark_dirty(freed)
        return freed

    # -- integrity -----------------------------------------------------------
    def _purge_checksums(self, pages) -> None:
        """Stamps die with the content: a freed page's next occupant has
        different bytes, and a stale stamp would read as corruption."""
        for p in pages:
            self.checksums.pop(int(p), None)

    def verify(self, pages, digests) -> list[int]:
        """Compare current content digests against the publish stamps.
        Returns the pages whose content changed (unstamped pages are
        skipped — nothing to compare against)."""
        bad = []
        for p, d in zip(pages, digests):
            want = self.checksums.get(int(p))
            if want is None:
                continue
            self.integrity_checks += 1
            if d != want:
                bad.append(int(p))
        return bad

    def quarantine_page(self, page: int) -> list[int]:
        """Detected corruption on `page`: fence it off in the pool, drop
        every prefix chain routed through it (transitively — a suffix
        without its prefix is meaningless), and purge dead stamps. Slots
        currently mapping the page keep running (attention through a
        perturbed-but-finite page is the *old* failure mode; new sharers
        are what this protects). Returns pages freed by the chain drop."""
        page = int(page)
        self.integrity_violations += 1
        self.pool.quarantine(page)        # before drop: release() routes
        freed = []                        # around the free list
        if self.prefix is not None:
            freed = self.prefix.drop_page(page)
        self._purge_checksums(freed)
        self.checksums.pop(page, None)
        return freed

    def scrub_candidates(self, limit: int) -> list[int]:
        """Round-robin slice of the stamped pages for the background
        integrity scrub (a few per chunk boundary keeps the cost bounded
        while every published page is eventually re-checked)."""
        pages = sorted(self.checksums)
        if not pages or limit <= 0:
            return []
        n = min(int(limit), len(pages))
        out = [pages[(self._scrub_cursor + i) % len(pages)]
               for i in range(n)]
        self._scrub_cursor = (self._scrub_cursor + n) % len(pages)
        return out

    def reset(self) -> None:
        """Forget everything (wedge recovery: the device pool was rebuilt
        from scratch, so every table, page, and prefix entry is void)."""
        for s in range(self.n_slots):
            self._slot_owned[s] = []
            self._slot_table[s] = []
            self._slot_prompt[s] = None
        self.pool = PagePool(self.pool.n_pages, self.pool.page_size)
        if self.prefix is not None:
            evictions = self.prefix.evictions   # lifetime counter survives
            self.prefix = PrefixCache(self.pool)
            self.prefix.evictions = evictions
        self.checksums = {}
        self._scrub_cursor = 0

    def slot_pages(self, slot: int) -> list[int]:
        """The page ids the slot's device table addresses (table order)."""
        return list(self._slot_table[slot])

    def match_len(self, prompt) -> int:
        """Reusable-prefix length in tokens — the scheduler's page-level
        admission score (peek only: no refcounts, no hit accounting)."""
        if self.prefix is None:
            return 0
        ps = self.pool.page_size
        tokens = np.asarray(prompt, np.int32)
        key, n = b"root", 0
        for k in range(tokens.size // ps):
            page_toks = tokens[k * ps:(k + 1) * ps]
            key = _page_key(key, page_toks)
            e = self.prefix._chain.get(key)
            if e is None or not np.array_equal(e.tokens, page_toks):
                break
            n += ps
        return n

    def match_pages(self, prompt) -> int:
        """Measured full-page prefix overlap — `match_len` in pages."""
        return self.match_len(prompt) // self.pool.page_size

    # -- durability ----------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able image of every host-side structure: pool refcounts /
        free list / dirty + quarantine sets, slot tables + prompts, the
        prefix chain (keys, parents, token content), checksums, counters.
        Bit-exact round-trip with `load_snapshot`."""
        return {
            "refcount": self.pool.refcount.tolist(),
            "free": list(self.pool._free),
            "dirty": sorted(self.pool.dirty),
            "quarantined": sorted(self.pool.quarantined),
            "allocs": self.pool.allocs,
            "alloc_failures": self.pool.alloc_failures,
            "slot_owned": [list(o) for o in self._slot_owned],
            "slot_table": [list(t) for t in self._slot_table],
            "slot_prompt": [None if p is None else p.tolist()
                            for p in self._slot_prompt],
            "chain": None if self.prefix is None else [
                {"key": k.hex(), "parent": e.parent.hex(),
                 "page": e.page, "tokens": e.tokens.tolist(),
                 "hits": e.hits, "last_used": e.last_used}
                for k in self.prefix._order
                for e in (self.prefix._chain[k],)],
            "prefix_hits": 0 if self.prefix is None else self.prefix.hits,
            "prefix_misses": (0 if self.prefix is None
                              else self.prefix.misses),
            "prefix_evictions": (0 if self.prefix is None
                                 else self.prefix.evictions),
            "prefix_tick": (0 if self.prefix is None
                            else self.prefix._tick),
            "checksums": {str(p): d.hex()
                          for p, d in sorted(self.checksums.items())},
            "pages_shared_total": self.pages_shared_total,
            "prefill_skipped_tokens": self.prefill_skipped_tokens,
            "cow_forks": self.cow_forks,
            "integrity_checks": self.integrity_checks,
            "integrity_violations": self.integrity_violations,
            "integrity_repairs": self.integrity_repairs,
            "scrub_cursor": self._scrub_cursor,
        }

    def load_snapshot(self, d: dict) -> None:
        """Rebuild the pool/cache/tables in place from `snapshot()`."""
        self.pool.refcount = np.asarray(d["refcount"], np.int32)
        self.pool._free = [int(p) for p in d["free"]]
        self.pool.dirty = {int(p) for p in d["dirty"]}
        self.pool.quarantined = {int(p) for p in d.get("quarantined", [])}
        self.pool.allocs = int(d["allocs"])
        self.pool.alloc_failures = int(d["alloc_failures"])
        self._slot_owned = [[int(p) for p in o] for o in d["slot_owned"]]
        self._slot_table = [[int(p) for p in t] for t in d["slot_table"]]
        self._slot_prompt = [None if p is None else np.asarray(p, np.int32)
                             for p in d["slot_prompt"]]
        if self.prefix is not None:
            self.prefix._chain = {}
            self.prefix._order = []
            for rec in (d["chain"] or []):
                key = bytes.fromhex(rec["key"])
                self.prefix._chain[key] = _PrefixEntry(
                    int(rec["page"]),
                    np.asarray(rec["tokens"], np.int32),
                    bytes.fromhex(rec["parent"]), int(rec["hits"]),
                    last_used=int(rec.get("last_used", 0)))
                self.prefix._order.append(key)
            self.prefix.hits = int(d.get("prefix_hits", 0))
            self.prefix.misses = int(d.get("prefix_misses", 0))
            self.prefix.evictions = int(d.get("prefix_evictions", 0))
            self.prefix._tick = int(d.get("prefix_tick", 0))
        self.checksums = {int(p): bytes.fromhex(h)
                          for p, h in d.get("checksums", {}).items()}
        self.pages_shared_total = int(d["pages_shared_total"])
        self.prefill_skipped_tokens = int(d["prefill_skipped_tokens"])
        self.cow_forks = int(d["cow_forks"])
        self.integrity_checks = int(d.get("integrity_checks", 0))
        self.integrity_violations = int(d.get("integrity_violations", 0))
        self.integrity_repairs = int(d.get("integrity_repairs", 0))
        self._scrub_cursor = int(d.get("scrub_cursor", 0))

    def stats(self) -> dict:
        out = dict(self.pool.stats())
        out.update(pages_shared=self.pages_shared_total,
                   prefill_skipped_tokens=self.prefill_skipped_tokens,
                   cow_forks=self.cow_forks,
                   integrity_checks=self.integrity_checks,
                   integrity_violations=self.integrity_violations,
                   integrity_repairs=self.integrity_repairs)
        if self.prefix is not None:
            out.update(prefix_entries=len(self.prefix),
                       prefix_hits=self.prefix.hits,
                       prefix_misses=self.prefix.misses,
                       evictions=self.prefix.evictions)
        return out
