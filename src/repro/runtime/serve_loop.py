"""Batched serving driver: continuous batched decode over a KV cache."""

from __future__ import annotations

import time
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.runtime.engine import DecodeEngine, StallClock


class ServeLoop:
    """Greedy batched decoding with a step-compiled decode function.

    `decode_step(params, cache, batch) -> (cache, token)`; requests are
    slotted into the fixed batch (production continuous batching keeps a
    slot -> request map; completed slots are refilled each round).

    `eos_id` (None disables): a slot that emits EOS is *finished* — its
    subsequent tokens are masked to EOS, it stops counting toward emitted
    lengths, and the loop stops early once every slot has finished.

    `chunk` picks the execution engine: 1 (default) is the per-token host
    loop — one dispatch + one host sync per token; K > 1 compiles K decode
    steps into one `lax.scan` program with donated cache/token buffers
    (runtime/engine.py), so the host syncs once per K tokens. Both paths
    produce bit-identical tokens, EOS behaviour, and emitted counts; the
    engine path additionally leaves the input `cache` buffer consumed
    (donated) after `generate`.
    """

    def __init__(self, decode_step: Callable, params, cache, batch_size: int,
                 eos_id: int | None = None, chunk: int = 1,
                 donate: bool = True, engine: DecodeEngine | None = None):
        self.decode_step = decode_step
        self.params = params
        self.cache = cache
        self.batch_size = batch_size
        self.eos_id = eos_id
        self.latencies: list[float] = []
        self.emitted_lengths: np.ndarray | None = None
        self._finished: np.ndarray | None = None
        self._chunk_steps: list[int] | None = None
        self.clock = StallClock()
        # a prebuilt engine (e.g. cached on a compiled program so its scan
        # program compiles once, not per generate) wins over `chunk`
        if engine is None and chunk > 1:
            engine = DecodeEngine(decode_step, chunk, eos_id=eos_id,
                                  donate=donate)
        self._engine = engine
        self.chunk = engine.chunk if engine is not None else chunk

    def generate(self, prompt_tokens: np.ndarray, max_new: int,
                 start_pos: int = 0) -> np.ndarray:
        """prompt_tokens: (B, 1) last prompt token per slot."""
        if self._engine is not None:
            return self._generate_chunked(prompt_tokens, max_new, start_pos)
        prompt_tokens = np.asarray(prompt_tokens)
        B = prompt_tokens.shape[0]
        out = np.empty((B, 1 + max_new), np.int32)       # one host buffer
        out[:, 0] = prompt_tokens[:, 0]
        tok = jnp.asarray(prompt_tokens, jnp.int32)
        finished = np.zeros(B, bool)
        emitted = np.zeros(B, np.int64)
        pos = start_pos
        self.latencies = []
        self.clock = StallClock()
        w = 0
        for _ in range(max_new):
            t0 = self.clock.dispatch()
            self.cache, tok = self.decode_step(
                self.params, self.cache,
                {"tokens": tok, "pos": jnp.asarray(pos, jnp.int32)})
            self.clock.sync(tok)
            self.latencies.append(time.perf_counter() - t0)
            step_tok = np.asarray(tok)
            emitted += ~finished
            if self.eos_id is not None:
                # already-finished slots hold EOS regardless of the argmax
                step_tok = np.where(finished[:, None], self.eos_id, step_tok)
                finished |= step_tok[:, 0] == self.eos_id
                tok = jnp.asarray(step_tok)
            out[:, 1 + w] = step_tok[:, 0]
            w += 1
            pos += 1
            if self.eos_id is not None and finished.all():
                break
        self.emitted_lengths = emitted
        self._finished = finished
        self._chunk_steps = None
        return out[:, :1 + w]

    def _generate_chunked(self, prompt_tokens, max_new: int,
                          start_pos: int) -> np.ndarray:
        out, cache, finished, emitted = self._engine.generate(
            self.params, self.cache, prompt_tokens, max_new, start_pos)
        self.cache = cache
        self.clock = self._engine.clock
        self.latencies = [dt for dt, _ in self._engine.chunk_latencies]
        self._chunk_steps = [n for _, n in self._engine.chunk_latencies]
        self.emitted_lengths = emitted
        self._finished = finished
        return out

    def stats(self) -> dict:
        """Latency stats over the post-warmup steps (first step — or first
        chunk, on the engine path — dropped: it carries compilation). With
        zero or one recorded sample there are no measured steps, so
        throughput/percentiles report 0.0 rather than the fake `1/epsilon`
        numbers an empty array would produce; `decode_steps` counts the
        decode steps covered by the measured samples. After a `generate`,
        `emitted_per_slot` reports how many tokens each slot emitted before
        (and including) its EOS, and `finished_slots` how many slots hit
        EOS. `stall` carries the StallClock ledger (host-sync count,
        dispatch-gap and device-wait seconds, stall_pct).
        """
        lat = np.asarray(self.latencies[1:], np.float64)
        if self._chunk_steps is not None:
            steps = np.asarray(self._chunk_steps[1:], np.int64)
            tokens = int(steps.sum())
            if lat.size == 0 or tokens == 0:
                st = {"decode_steps": 0, "p50_ms": 0.0, "p99_ms": 0.0,
                      "tokens_per_s_per_slot": 0.0}
            else:
                per_tok = lat / np.maximum(steps, 1)
                st = {"decode_steps": tokens,
                      "p50_ms": float(np.percentile(per_tok, 50) * 1e3),
                      "p99_ms": float(np.percentile(per_tok, 99) * 1e3),
                      "tokens_per_s_per_slot": float(
                          tokens / max(lat.sum(), 1e-9))}
        elif lat.size == 0:
            st = {"decode_steps": 0, "p50_ms": 0.0, "p99_ms": 0.0,
                  "tokens_per_s_per_slot": 0.0}
        else:
            st = {"decode_steps": int(lat.size),
                  "p50_ms": float(np.percentile(lat, 50) * 1e3),
                  "p99_ms": float(np.percentile(lat, 99) * 1e3),
                  "tokens_per_s_per_slot": float(1.0 / max(lat.mean(), 1e-9))}
        st["chunk"] = self.chunk
        st["stall"] = self.clock.report()
        if self.emitted_lengths is not None:
            st["emitted_per_slot"] = [int(n) for n in self.emitted_lengths]
            if self.eos_id is not None:
                st["finished_slots"] = int(self._finished.sum())
        return st
