"""Serving drivers: batch programs (`ServeLoop`) and request-level
continuous batching (`ServeSession`).

`ServeLoop` is the fixed-batch driver: one rectangular batch of prompts
runs to completion, so a slot that finishes early idles until the slowest
request drains — the software analogue of MemPool's stalled-PE problem.

`ServeSession` is the request-level driver: a fixed slot pool stepped by
the scan-compiled session cell (runtime/engine.py), with a host-side
`SlotScheduler` (runtime/scheduler.py) evicting finished slots and
admitting queued requests between chunks. Steady-state decode stays
allocation-free (the whole pool state is donated through every chunk and
refill) and the host syncs once per K tokens.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from pathlib import Path
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

HISTORY = 4096          # sliding-window length for session stats records


def chunked_latency_stats(samples) -> dict:
    """Per-token latency stats from `(seconds, steps)` chunk samples.

    The first sample is dropped (it carries compilation); with zero
    post-warmup samples the figures report 0.0 rather than fake
    `1/epsilon` numbers. Shared by `ServeLoop.stats` (engine path) and
    the session's legacy-shaped one-shot stats so the two cannot drift.
    """
    samples = list(samples)
    lat = np.asarray([dt for dt, _ in samples[1:]], np.float64)
    steps = np.asarray([n for _, n in samples[1:]], np.int64)
    tokens = int(steps.sum())
    if lat.size == 0 or tokens == 0:
        return {"decode_steps": 0, "p50_ms": 0.0, "p99_ms": 0.0,
                "tokens_per_s_per_slot": 0.0}
    per_tok = lat / np.maximum(steps, 1)
    return {"decode_steps": tokens,
            "p50_ms": float(np.percentile(per_tok, 50) * 1e3),
            "p99_ms": float(np.percentile(per_tok, 99) * 1e3),
            "tokens_per_s_per_slot": float(tokens / max(lat.sum(), 1e-9))}

from repro.runtime.engine import (DecodeEngine, StallClock, make_nan_scan,
                                  make_slot_corrupt, make_slot_restore,
                                  make_slot_snapshot)
from repro.runtime.faults import FaultPlan, SessionCrashed, SessionWedged
from repro.runtime.journal import Journal, read_events, replay
from repro.runtime.kvpool import PagedKV, PoolExhausted, page_digests
from repro.runtime.scheduler import (CANCELLED, CLASSES, DONE, FAILED, QUEUED,
                                     REASON_CANCELLED, REASON_POOL,
                                     REASON_RETRIES, REASON_SHED, RUNNING,
                                     Request, RequestHandle, SlotScheduler,
                                     deserialize_request, serialize_request)


class ServeLoop:
    """Greedy batched decoding with a step-compiled decode function.

    `decode_step(params, cache, batch) -> (cache, token)`; requests are
    slotted into the fixed batch (production continuous batching keeps a
    slot -> request map; completed slots are refilled each round).

    `eos_id` (None disables): a slot that emits EOS is *finished* — its
    subsequent tokens are masked to EOS, it stops counting toward emitted
    lengths, and the loop stops early once every slot has finished.

    `chunk` picks the execution engine: 1 (default) is the per-token host
    loop — one dispatch + one host sync per token; K > 1 compiles K decode
    steps into one `lax.scan` program with donated cache/token buffers
    (runtime/engine.py), so the host syncs once per K tokens. Both paths
    produce bit-identical tokens, EOS behaviour, and emitted counts; the
    engine path additionally leaves the input `cache` buffer consumed
    (donated) after `generate`.
    """

    def __init__(self, decode_step: Callable, params, cache, batch_size: int,
                 eos_id: int | None = None, chunk: int = 1,
                 donate: bool = True, engine: DecodeEngine | None = None):
        self.decode_step = decode_step
        self.params = params
        self.cache = cache
        self.batch_size = batch_size
        self.eos_id = eos_id
        self.latencies: list[float] = []
        self.emitted_lengths: np.ndarray | None = None
        self._finished: np.ndarray | None = None
        self._chunk_steps: list[int] | None = None
        self.clock = StallClock()
        # a prebuilt engine (e.g. cached on a compiled program so its scan
        # program compiles once, not per generate) wins over `chunk`
        if engine is None and chunk > 1:
            engine = DecodeEngine(decode_step, chunk, eos_id=eos_id,
                                  donate=donate)
        self._engine = engine
        self.chunk = engine.chunk if engine is not None else chunk

    def generate(self, prompt_tokens: np.ndarray, max_new: int,
                 start_pos: int = 0) -> np.ndarray:
        """prompt_tokens: (B, 1) last prompt token per slot."""
        if self._engine is not None:
            return self._generate_chunked(prompt_tokens, max_new, start_pos)
        prompt_tokens = np.asarray(prompt_tokens)
        B = prompt_tokens.shape[0]
        out = np.empty((B, 1 + max_new), np.int32)       # one host buffer
        out[:, 0] = prompt_tokens[:, 0]
        tok = jnp.asarray(prompt_tokens, jnp.int32)
        finished = np.zeros(B, bool)
        emitted = np.zeros(B, np.int64)
        pos = start_pos
        self.latencies = []
        self.clock = StallClock()
        w = 0
        for _ in range(max_new):
            t0 = self.clock.dispatch()
            self.cache, tok = self.decode_step(
                self.params, self.cache,
                {"tokens": tok, "pos": jnp.asarray(pos, jnp.int32)})
            self.clock.sync(tok)
            self.latencies.append(time.perf_counter() - t0)
            step_tok = np.asarray(tok)
            emitted += ~finished
            if self.eos_id is not None:
                # already-finished slots hold EOS regardless of the argmax
                step_tok = np.where(finished[:, None], self.eos_id, step_tok)
                finished |= step_tok[:, 0] == self.eos_id
                tok = jnp.asarray(step_tok)
            out[:, 1 + w] = step_tok[:, 0]
            w += 1
            pos += 1
            if self.eos_id is not None and finished.all():
                break
        self.emitted_lengths = emitted
        self._finished = finished
        self._chunk_steps = None
        return out[:, :1 + w]

    def _generate_chunked(self, prompt_tokens, max_new: int,
                          start_pos: int) -> np.ndarray:
        out, cache, finished, emitted = self._engine.generate(
            self.params, self.cache, prompt_tokens, max_new, start_pos)
        self.cache = cache
        self.clock = self._engine.clock
        self.latencies = [dt for dt, _ in self._engine.chunk_latencies]
        self._chunk_steps = [n for _, n in self._engine.chunk_latencies]
        self.emitted_lengths = emitted
        self._finished = finished
        return out

    def stats(self) -> dict:
        """Latency stats over the post-warmup steps (first step — or first
        chunk, on the engine path — dropped: it carries compilation). With
        zero or one recorded sample there are no measured steps, so
        throughput/percentiles report 0.0 rather than the fake `1/epsilon`
        numbers an empty array would produce; `decode_steps` counts the
        decode steps covered by the measured samples. After a `generate`,
        `emitted_per_slot` reports how many tokens each slot emitted before
        (and including) its EOS, and `finished_slots` how many slots hit
        EOS. `stall` carries the StallClock ledger (host-sync count,
        dispatch-gap and device-wait seconds, stall_pct).
        """
        lat = np.asarray(self.latencies[1:], np.float64)
        if self._chunk_steps is not None:
            st = chunked_latency_stats(zip(self.latencies, self._chunk_steps))
        elif lat.size == 0:
            st = {"decode_steps": 0, "p50_ms": 0.0, "p99_ms": 0.0,
                  "tokens_per_s_per_slot": 0.0}
        else:
            st = {"decode_steps": int(lat.size),
                  "p50_ms": float(np.percentile(lat, 50) * 1e3),
                  "p99_ms": float(np.percentile(lat, 99) * 1e3),
                  "tokens_per_s_per_slot": float(1.0 / max(lat.mean(), 1e-9))}
        st["chunk"] = self.chunk
        st["stall"] = self.clock.report()
        if self.emitted_lengths is not None:
            st["emitted_per_slot"] = [int(n) for n in self.emitted_lengths]
            if self.eos_id is not None:
                st["finished_slots"] = int(self._finished.sum())
        return st


# ----------------------------------------------------------------------------
# Request-level serving: continuous batching over a slot pool
# ----------------------------------------------------------------------------


def _class_counters() -> dict:
    return {"submitted": 0, "done": 0, "cancelled": 0, "failed": 0,
            "shed": 0, "preempted": 0, "retries": 0, "deadline_miss": 0,
            "ttfts": deque(maxlen=HISTORY), "lats": deque(maxlen=HISTORY)}


_NO_TOKENS = None    # lazily-built empty (0,) int32 event payload


def _no_tokens() -> np.ndarray:
    global _NO_TOKENS
    if _NO_TOKENS is None:
        _NO_TOKENS = np.empty(0, np.int32)
    return _NO_TOKENS


class ServeSession:
    """A long-lived slot pool serving a stream of independent requests.

    ::

        sess = cluster.compile(ServeSessionProgram(slots=8)).open()
        h = sess.submit(prompt, max_new=64, klass="latency",
                        deadline_s=0.5)            # -> RequestHandle
        for handle, toks, done in sess.stream():   # incremental tokens
            ...
        sess.drain()                               # run queue dry
        h.result()                                 # (T,) np.int32

    The device side is one scan-compiled chunk program (`chunk_fn`) that
    advances every live slot K steps — per-slot prompt prefill, position
    tracking, EOS/budget masking all on device — plus a refill program
    (`refill_fn`) that recycles finished slots in place. The host wakes
    once per chunk: harvest emitted tokens, free finished slots, admit
    queued requests, dispatch the next chunk. Both programs donate the
    pool state, so steady-state serving allocates nothing.

    Robustness layer (the MemPool stance — one stalled PE never wedges
    the cluster, a dead PE only costs its own lanes):

    * **priority classes** — requests carry ``klass`` ("latency" |
      "throughput" | "best_effort") and an optional ``deadline_s``;
      admission is class-ranked with anti-starvation aging, overload
      sheds only best-effort work (see `SlotScheduler`);
    * **preemption** — a ready latency request queued behind a full pool
      checkpoints the lowest-priority running slot (`snapshot_fn`),
      requeues it at the front of its class, and takes the slot; the
      victim resumes bit-identically (`restore_fn`) as soon as capacity
      frees. Progress is guaranteed: preemption only happens at chunk
      boundaries, so a resumed victim always decodes at least one full
      chunk before it can be preempted again;
    * **fault detection + recovery** — an optional NaN sentinel scan
      (`nan_check`) and a `FaultPlan` (`faults=`) feed a recovery path
      that quarantines dead slots (the pool degrades, never crashes),
      discards poisoned partial output, and requeues the victim with
      bounded retries + exponential backoff;
    * **watchdog** — `poll(timeout_s=...)` (or the session-wide
      ``watchdog_s``) bounds every device wait on a watchdog thread and
      raises `SessionWedged` (StallClock ledger attached) instead of
      blocking forever; `recover_wedged()` rebuilds the pool via
      ``state_factory`` and requeues everything that was running;
    * **per-class SLO accounting** — TTFT/latency percentiles,
      deadline misses, preemptions, retries and sheds per class in
      `stats()["classes"]`.
    """

    def __init__(self, chunk_fn: Callable, refill_fn: Callable, params,
                 state: dict, *, n_slots: int, chunk: int,
                 max_prompt: int, max_seq: int | None = None,
                 eos_id: int | None = None, max_queue: int | None = None,
                 admission: str = "fifo",
                 shed_watermark: int | None = None, aging_rounds: int = 8,
                 preempt: bool = True,
                 snapshot_fn: Callable | None = None,
                 restore_fn: Callable | None = None,
                 nan_scan_fn: Callable | None = None,
                 corrupt_fn: Callable | None = None,
                 state_factory: Callable | None = None,
                 watchdog_s: float | None = None,
                 max_retries: int = 2, retry_backoff_s: float = 0.05,
                 nan_check: bool = False,
                 faults: "FaultPlan | None" = None,
                 kv: "PagedKV | None" = None,
                 page_copy_fn: Callable | None = None,
                 page_scrub_fn: Callable | None = None,
                 durable_dir: "str | Path | None" = None,
                 snapshot_every: int | None = None,
                 journal_fsync: bool | int = True,
                 page_read_fn: Callable | None = None,
                 page_flip_fn: Callable | None = None,
                 scrub_pages: int = 2,
                 crash_hook: Callable | None = None,
                 resume: bool = False,
                 journal_group: int | None = None):
        if kv is not None and preempt:
            raise ValueError("paged KV serving does not support slot "
                             "preemption (slot snapshots do not carry page "
                             "tables); open the session with preempt=False")
        self._chunk_fn = chunk_fn
        self._refill_fn = refill_fn
        self.params = params
        self.state = state
        self.n_slots = n_slots
        self.chunk = chunk
        self.max_prompt = max_prompt
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.preempt = preempt
        self.watchdog_s = watchdog_s
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        # paged KV pool (runtime/kvpool.py): host-side page allocator +
        # prefix cache; refill installs page tables instead of zeroing
        # cache rows, and `longest_prefix` admission scores actual
        # page-level reuse instead of raw prompt length
        self.kv = kv
        self._page_copy_fn = page_copy_fn
        self._page_scrub_fn = page_scrub_fn
        self.scheduler = SlotScheduler(n_slots, max_queue=max_queue,
                                       policy=admission,
                                       shed_watermark=shed_watermark,
                                       aging_rounds=aging_rounds,
                                       prefix_score=(kv.match_len
                                                     if kv is not None
                                                     else None),
                                       page_size=(kv.pool.page_size
                                                  if kv is not None
                                                  else None))
        self.clock = StallClock()
        # checkpoint/restore + fault machinery; the engine defaults cover
        # flat (batch-axis-0) caches, model caches pass steps.py helpers
        self._snapshot_fn = snapshot_fn
        self._restore_fn = restore_fn
        self._nan_scan_fn = nan_scan_fn
        self._corrupt_fn = corrupt_fn
        self._state_factory = state_factory
        self._nan_check = nan_check
        self._faults = faults
        self._wedged = False
        self._chunk_index = 0
        self._refill_failures = 0
        # bounded histories: a session lives for an open-ended request
        # stream, so per-chunk and per-request records keep a sliding
        # window (percentiles cover the recent window; totals are counters)
        self.chunk_latencies: deque[tuple[float, int]] = deque(
            maxlen=HISTORY)
        self.handles: dict[int, RequestHandle] = {}    # in-flight only
        self._pending_release: set[int] = set()
        # slots whose request completed cleanly: their prompt pages seed
        # the prefix cache before the pages are released (paged KV only)
        self._pending_publish: set[int] = set()
        self._n_pool_exhausted = 0
        # host table freed but device row still active (preempted / dead
        # slots): folded into the next refill's release mask
        self._pending_deactivate: set[int] = set()
        self._pending_events: list = []     # terminal events awaiting poll
        self._busy_steps = 0
        self._total_steps = 0
        self._emitted_total = 0
        self._per_chunk_emitted: deque[int] = deque(maxlen=HISTORY)
        self._ttfts: deque[float] = deque(maxlen=HISTORY)
        self._latencies: deque[float] = deque(maxlen=HISTORY)
        self._n_done = 0
        self._n_cancelled = 0
        self._n_failed = 0
        self._n_preemptions = 0
        self._n_retries = 0
        self._deadline_miss = 0
        self._class_stats = {k: _class_counters() for k in CLASSES}
        # -- durability + integrity layer --------------------------------
        # journal: a write-ahead log of the request lifecycle (submit /
        # admit / commit / finish) — a token is *delivered* only after its
        # commit record is fsync-durable, so a crash-restart can replay to
        # a consistent scheduler state with exactly-once delivery (greedy
        # decode regenerates committed prefixes deterministically; harvest
        # suppresses them instead of re-delivering).
        self._durable_dir = Path(durable_dir) if durable_dir else None
        self._snapshot_every = snapshot_every
        self._page_read_fn = page_read_fn
        self._page_flip_fn = page_flip_fn
        self._scrub_pages = scrub_pages
        self._crash_hook = crash_hook
        self._journal: Journal | None = None
        self._ckpt = None                   # lazily-built CheckpointManager
        self._snapshots_taken = 0
        self._last_snapshot_chunk = -1
        self._restored_step: int | None = None
        self._replayed_requests = 0         # live requests reinstalled
        self._resubmitted = 0               # of those, requeued (re-prefill)
        self._deduped_tokens = 0            # regenerated-but-suppressed
        self._restore_s = 0.0               # measured MTTR of _recover()
        self._prefix_pages_expected = 0     # admission-predicted page reuse
        # requests that finished *before* a crash: their handles, rebuilt
        # from the journal at restore (terminal, tokens = committed stream)
        self.recovered: dict[int, RequestHandle] = {}
        # serving-group id stamped on every journal event (sharded
        # sessions; None leaves the single-group format untouched)
        self._journal_group = journal_group
        if self._durable_dir is not None:
            self._durable_dir.mkdir(parents=True, exist_ok=True)
            if resume:
                self._recover()
            self._journal = Journal(self._durable_dir / "journal.jsonl",
                                    fsync=journal_fsync,
                                    tag=(None if journal_group is None
                                         else {"group": journal_group}))
            if resume:
                self._journal.append({
                    "ev": "restore",
                    "snapshot_step": self._restored_step,
                    "replayed": self._replayed_requests,
                    "restore_s": self._restore_s})
                self._journal.commit()

    # -- lazily-built fault/checkpoint programs ---------------------------
    def _get_snapshot_fn(self) -> Callable:
        if self._snapshot_fn is None:
            self._snapshot_fn = make_slot_snapshot()
        return self._snapshot_fn

    def _get_restore_fn(self) -> Callable:
        if self._restore_fn is None:
            self._restore_fn = make_slot_restore()
        return self._restore_fn

    def _get_nan_scan_fn(self) -> Callable:
        if self._nan_scan_fn is None:
            self._nan_scan_fn = make_nan_scan()
        return self._nan_scan_fn

    def _get_corrupt_fn(self) -> Callable:
        if self._corrupt_fn is None:
            self._corrupt_fn = make_slot_corrupt()
        return self._corrupt_fn

    def attach_faults(self, plan: FaultPlan) -> None:
        """Arm a `FaultPlan` against this session (chaos testing)."""
        self._faults = plan

    # -- request lifecycle ----------------------------------------------
    def submit(self, prompt, max_new: int, *, klass: str = "latency",
               deadline_s: float | None = None) -> RequestHandle:
        """Enqueue one request; admitted to a slot at a chunk boundary.

        `klass` picks the priority class; `deadline_s` (optional) is the
        SLO deadline counted from now, used for per-class deadline-miss
        accounting. Raises `scheduler.QueueFull` when the class queue is
        at capacity. Under overload (`shed_watermark`) a best-effort
        submission may come back already failed with reason "shed" —
        check `handle.failed` or let `result()` raise `RequestFailed`.
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size > self.max_prompt:
            raise ValueError(f"prompt of {prompt.size} tokens exceeds the "
                             f"session's max_prompt={self.max_prompt}")
        # the request's last KV write lands at position P + max_new - 2
        # (the step consuming prompt token P emits token #1), so it fits
        # iff P + max_new - 1 <= max_seq — exactly the old ServeProgram
        # bound of P + N <= max_seq once run(prompt)'s +1 budget is counted
        if (self.max_seq is not None
                and prompt.size + max_new - 1 > self.max_seq):
            raise ValueError(f"prompt ({prompt.size}) + max_new ({max_new}) "
                             f"exceeds the session's max_seq={self.max_seq}")
        req = self.scheduler.submit(prompt, max_new, klass=klass,
                                    deadline_s=deadline_s)
        self._class_stats[klass]["submitted"] += 1
        if self._journal is not None:
            self._journal.append({
                "ev": "submit", "rid": req.rid,
                "prompt": prompt.tolist(),
                "max_new": int(max_new), "klass": klass,
                "deadline_s": deadline_s})
        handle = RequestHandle(req)
        if not handle.done:             # the submission itself may have
            self.handles[req.rid] = handle      # been shed under overload
        self._retire_shed(self._pending_events)
        return handle

    def cancel(self, handle: RequestHandle) -> bool:
        """Cancel a request. Queued: removed now. Running: its slot is
        freed (and refillable) at the next chunk boundary."""
        was_queued = handle._req.state == QUEUED
        ok = self.scheduler.cancel(handle._req)
        if ok:
            self._n_cancelled += 1
            self._class_stats[handle.klass]["cancelled"] += 1
            if self._journal is not None:
                self._journal.append({
                    "ev": "finish", "rid": handle.id,
                    "status": "cancelled", "reason": REASON_CANCELLED})
                self._journal.commit()
            if was_queued:                  # terminal now; running requests
                self.handles.pop(handle.id, None)   # retire at the boundary
        return ok

    # -- the chunk boundary ---------------------------------------------
    def _retire_shed(self, events: list) -> None:
        """Surface requests the scheduler shed under overload as terminal
        events (empty payload, done=True) and count them per class."""
        for req in self.scheduler.pop_shed():
            self._class_stats[req.klass]["shed"] += 1
            if self._journal is not None:
                self._journal.append({"ev": "finish", "rid": req.rid,
                                      "status": "failed",
                                      "reason": REASON_SHED})
            handle = self.handles.pop(req.rid, None)
            if handle is not None:
                events.append((handle, _no_tokens(), True))

    def _fail_request(self, req, reason: str, events: list) -> None:
        self.scheduler.fail(req, reason)
        if self._journal is not None:
            self._journal.append({"ev": "finish", "rid": req.rid,
                                  "status": "failed", "reason": reason})
        self._class_stats[req.klass]["failed"] += 1
        self._n_failed += 1
        handle = self.handles.pop(req.rid, None)
        if handle is not None:
            events.append((handle, _no_tokens(), True))

    def _restart_request(self, req, events: list) -> None:
        """Fault recovery for a running request whose slot died: discard
        the poisoned partial output (greedy decode is deterministic, so a
        restart reproduces it bit-identically) and requeue with bounded
        retries + exponential backoff; past `max_retries` the request
        fails terminally with reason "retries_exhausted"."""
        req.tokens.clear()
        req.hit_eos = False
        req.snapshot = None
        req.retries += 1
        if req.retries > self.max_retries:
            self._fail_request(req, REASON_RETRIES, events)
            return
        self._class_stats[req.klass]["retries"] += 1
        self._n_retries += 1
        backoff = self.retry_backoff_s * (2 ** (req.retries - 1))
        self.scheduler.requeue(req, front=False, backoff_s=backoff)

    def _recover_slot(self, slot: int, quarantine: bool,
                      events: list) -> None:
        """A device row was detected dead (kill fault) or poisoned (NaN
        scan) at harvest: free it before any of its output is surfaced.
        `quarantine=True` retires the slot for good (pool degrades);
        False recycles it (the refill zeroes the rows)."""
        req = self.scheduler._slots[slot]
        if req is not None:
            self.scheduler.release(slot)
        self._pending_deactivate.add(slot)
        if self.kv is not None:
            # the slot's rows may hold NaN: its freed pages are marked
            # dirty and scrubbed on device before they can be reused
            self.kv.release(slot, dirty=True)
        if quarantine:
            self.scheduler.quarantine(slot)
        if req is None:
            return
        if req.state == RUNNING:
            self._restart_request(req, events)
        else:                               # cancelled mid-flight: retire
            self.handles.pop(req.rid, None)

    def _preempt_for_latency(self) -> None:
        """Checkpoint lowest-priority running slots so that ready latency
        requests stuck behind a full pool get in this boundary. The victim
        is snapshotted (bit-exact slot state incl. cache rows), requeued
        at the front of its class with its aging reset, and resumes via
        `restore_fn` as soon as capacity frees."""
        now = time.perf_counter()
        ready_lat = [r for r in self.scheduler._queues["latency"]
                     if r.not_before <= now]
        if not ready_lat:
            return
        need = len(ready_lat) - len(self.scheduler.free_slots())
        snapshot = None
        for _ in range(max(need, 0)):
            victim = self.scheduler.preempt_victim(for_rank=0)
            if victim is None:
                break
            slot, req = victim
            snapshot = snapshot or self._get_snapshot_fn()
            req.snapshot = jax.device_get(
                snapshot(self.state, np.int32(slot)))
            req.preemptions += 1
            req.wait_rounds = 0     # resume on capacity, not aging boost
            self._class_stats[req.klass]["preempted"] += 1
            self._n_preemptions += 1
            self.scheduler.release(slot)
            self._pending_deactivate.add(slot)
            self.scheduler.requeue(req, front=True)

    def _alloc_pages(self, fresh: list, events: list) -> list:
        """Paged KV admission: build each fresh slot's page table. A
        request the pool cannot cover right now is un-admitted and
        requeued at the front (its pages free as running slots retire);
        when the whole pool is idle and empty and it *still* does not
        fit, it fails terminally with the typed reason "pool_exhausted".
        A scripted `page_alloc_fail` fault forces the exhausted path for
        one boundary (always a requeue, never terminal)."""
        forced = (self._faults is not None
                  and self._faults.page_alloc_failed(self._chunk_index))
        # shared prefix pages are checksum-verified before a new request
        # may attach to them; a mismatch quarantines the page and the
        # admit falls back to fresh pages (recompute repairs the prefix)
        verify = (self._verify_pages if self._page_read_fn is not None
                  else None)
        kept: list = []
        for slot, req in fresh:
            try:
                if forced:
                    raise PoolExhausted(0, self.kv.pool.free_pages)
                alloc = self.kv.admit(slot, req.prompt, req.max_new,
                                      verify=verify)
                self._prefix_pages_expected += req.prefix_pages_expected
            except PoolExhausted:
                self._n_pool_exhausted += 1
                self.scheduler.release(slot)
                if (not forced and not kept
                        and self.scheduler.running == 0
                        and self.kv.pool.used_pages == 0):
                    self._fail_request(req, REASON_POOL, events)
                else:
                    self.scheduler.requeue(req, front=True)
                continue
            kept.append((slot, req, alloc))
        return kept

    def _admit_and_refill(self, events: list) -> None:
        for slot, req in list(self.scheduler.running_requests()):
            if req.state != RUNNING:            # cancelled mid-flight
                self._pending_release.add(slot)
                self.handles.pop(req.rid, None)     # retired
        for slot in self._pending_release:
            self.scheduler.release(slot)
            self._pending_deactivate.add(slot)
            if self.kv is not None:
                if slot in self._pending_publish:
                    # seed the prefix cache; stamp a content checksum on
                    # each published page so later admits / the background
                    # scrub can detect silent corruption before reuse
                    digests = None
                    if self._page_read_fn is not None:
                        pp = self.kv.publishable_pages(slot)
                        if pp:
                            arrs = self._page_read_fn(
                                self.state, np.asarray(pp, np.int32))
                            digests = dict(
                                zip(pp, page_digests(arrs, len(pp))))
                    self.kv.publish(slot, digests=digests)
                self.kv.release(slot)
        self._pending_release.clear()
        self._pending_publish.clear()
        self._retire_shed(events)       # sheds triggered since last poll
        if self.kv is not None:
            # pages freed from a corrupted slot may hold NaN — the one
            # thing masked attention cannot hide — scrub before reuse
            dirty = self.kv.pool.take_dirty_free()
            if dirty:
                self.state = self._page_scrub_fn(
                    self.state, np.asarray(dirty, np.int32))
        if self.preempt:
            self._preempt_for_latency()
        admits = self.scheduler.admit()
        if not admits and not self._pending_deactivate:
            return
        release = np.zeros(self.n_slots, bool)
        if self._pending_deactivate:
            release[sorted(self._pending_deactivate)] = True
        fresh = [(s, r) for s, r in admits if r.snapshot is None]
        resumed = [(s, r) for s, r in admits if r.snapshot is not None]
        kv_fresh = []
        if self.kv is not None and fresh:
            kv_fresh = self._alloc_pages(fresh, events)
            fresh = [(s, r) for s, r, _ in kv_fresh]
        granted = fresh + resumed       # still slot-assigned after alloc
        try:
            if self._faults is not None:
                self._faults.check_refill(self._chunk_index)
            if fresh or release.any():
                admit = np.zeros(self.n_slots, bool)
                pbuf = np.zeros((self.n_slots, self.max_prompt), np.int32)
                plen = np.zeros(self.n_slots, np.int32)
                budget = np.zeros(self.n_slots, np.int32)
                for slot, req in fresh:
                    admit[slot] = True
                    pbuf[slot, :req.prompt.size] = req.prompt
                    plen[slot] = req.prompt.size
                    budget[slot] = req.max_new
                if self.kv is not None:
                    pages = np.zeros((self.n_slots, self.kv.pages_per_slot),
                                     np.int32)
                    start = np.zeros(self.n_slots, np.int32)
                    cow_src: list[int] = []
                    cow_dst: list[int] = []
                    for slot, req, alloc in kv_fresh:
                        pages[slot] = alloc.table
                        start[slot] = alloc.prefill_skip
                        for s, d in alloc.cow_copies:
                            cow_src.append(s)
                            cow_dst.append(d)
                    self.state = self._refill_fn(self.state, admit, release,
                                                 pbuf, plen, budget,
                                                 pages, start)
                    if cow_src:     # COW fork: copy before the next chunk
                        self.state = self._page_copy_fn(
                            self.state, np.asarray(cow_src, np.int32),
                            np.asarray(cow_dst, np.int32))
                else:
                    self.state = self._refill_fn(self.state, admit, release,
                                                 pbuf, plen, budget)
            for slot, req in resumed:
                self.state = self._get_restore_fn()(
                    self.state, np.int32(slot), req.snapshot)
                req.snapshot = None
            self._pending_deactivate.clear()
            self._refill_failures = 0
            if self._journal is not None:
                for slot, req in granted:
                    self._journal.append({"ev": "admit", "rid": req.rid,
                                          "slot": slot,
                                          "chunk": self._chunk_index})
        except Exception:
            # un-admit the round (reverse order restores queue positions);
            # pending deactivations retry at the next boundary. Bounded:
            # persistent refill failure must surface, not spin forever.
            for slot, req in reversed(granted):
                if self.kv is not None:
                    self.kv.release(slot)
                self.scheduler.release(slot)
                self.scheduler.requeue(req, front=True)
            self._refill_failures += 1
            if self._refill_failures > self.max_retries:
                raise

    def _watchdog_wait(self, arrays, timeout: float, chunk_idx: int,
                       wedge: bool) -> None:
        """Bound the device wait: block_until_ready runs on a watchdog
        thread while the driver waits at most `timeout` seconds. An
        injected wedge simply never finishes the wait — exactly what a
        hung device looks like from the host."""
        t0 = time.perf_counter()
        finished = threading.Event()
        errs: list[BaseException] = []
        if not wedge:
            def _wait():
                try:
                    jax.block_until_ready(arrays)
                except Exception as e:      # surfaced on the driver thread
                    errs.append(e)
                finished.set()
            threading.Thread(target=_wait, daemon=True).start()
        if not finished.wait(timeout):
            self._wedged = True
            raise SessionWedged(chunk_idx, timeout, self.clock.report())
        if errs:
            raise errs[0]
        self.clock.sync_done(t0)

    def _handle_idle_queue(self, events: list) -> None:
        """Nothing running but work queued: either the pool is fully
        quarantined (fail everything — it can never run) or every queued
        request is gated by retry backoff (sleep to the earliest gate and
        re-admit, so drain() cannot livelock)."""
        if not self.scheduler.queued:
            return
        if self.scheduler.usable_slots == 0:
            for req in list(self.scheduler.queued_requests()):
                self._fail_request(req, REASON_RETRIES, events)
            return
        gates = [r.not_before for r in self.scheduler.queued_requests()]
        wait = min(gates) - time.perf_counter()
        if wait > 0:
            time.sleep(min(wait, 0.25))
        self._admit_and_refill(events)

    def recover_wedged(self) -> None:
        """Recover from `SessionWedged`: rebuild the pool state from
        ``state_factory`` (the wedged buffers are unrecoverable — their
        program never completed), requeue every running request with a
        retry charged, and clear the wedge latch. Requests past
        `max_retries` fail terminally; their events surface on the next
        poll."""
        if self._state_factory is None:
            raise RuntimeError("recover_wedged() needs a state_factory "
                               "(a zero-arg callable rebuilding the pool "
                               "state); pass it to the session or open() "
                               "the program with one")
        events = self._pending_events
        for slot, req in list(self.scheduler.running_requests()):
            self.scheduler.release(slot)
            if req.state == RUNNING:
                self._restart_request(req, events)
            else:
                self.handles.pop(req.rid, None)
        self._pending_release.clear()
        self._pending_publish.clear()
        self._pending_deactivate.clear()
        self.state = self._state_factory()
        if self.kv is not None:
            self.kv.reset()     # the rebuilt pool holds no pages/tables
        self._wedged = False

    # -- durability: journal + snapshots + integrity ---------------------
    def handle(self, rid: int) -> RequestHandle | None:
        """Look up a request handle by id — in-flight first, then the
        `recovered` map (requests that finished before a crash, rebuilt
        from the journal at restore)."""
        return self.handles.get(rid) or self.recovered.get(rid)

    def close(self) -> None:
        """Land the in-flight snapshot write and close the journal
        (idempotent). A failed async snapshot write raises here rather
        than vanishing with the daemon thread."""
        if self._ckpt is not None:
            self._ckpt.wait()
        if self._journal is not None:
            self._journal.close()

    def _verify_pages(self, pages) -> list[int]:
        """Checksum-verify device pages against their publish-time stamps;
        returns the mismatching page ids (unstamped pages are skipped)."""
        pages = [int(p) for p in pages]
        if not pages or self._page_read_fn is None:
            return []
        arrs = self._page_read_fn(self.state, np.asarray(pages, np.int32))
        return self.kv.verify(pages, page_digests(arrs, len(pages)))

    def _inject_bit_flip(self, page: int | None) -> None:
        """Scripted silent-corruption fault: perturb one KV page on
        device. Defaults to the first *stamped* (shared) page so the
        checksum path — not luck — must catch it."""
        if self._page_flip_fn is None or self.kv is None:
            raise RuntimeError("a bit_flip fault needs a paged session "
                               "(kv=) with page_flip_fn")
        if page is None:
            stamped = sorted(self.kv.checksums)
            page = stamped[0] if stamped else 1
        self.state = self._page_flip_fn(self.state,
                                        np.asarray([page], np.int32))

    def _live_requests(self) -> list:
        """Every request the scheduler still holds: queued + slot-resident
        (including done-pending-release — their finish records are already
        journaled, so restore retires them and frees the slot)."""
        out = list(self.scheduler.queued_requests())
        out.extend(r for _, r in self.scheduler.running_requests())
        return out

    def _get_ckpt(self):
        if self._ckpt is None:
            from repro.checkpoint.manager import CheckpointManager
            # sync writes: the state is small relative to a training
            # checkpoint and an async writer thread contends with the
            # poll loop for the GIL — measured slower than writing inline
            self._ckpt = CheckpointManager(self._durable_dir / "snapshots",
                                           keep=2, async_save=False)
        return self._ckpt

    def _save_snapshot(self) -> None:
        """One bit-exact session snapshot: the device state pytree plus
        the host bookkeeping needed to resume — serialized requests, page
        pool / prefix cache / page tables (`kv.snapshot()`), and the
        journal high-water mark that ties the snapshot to its log tail."""
        meta = {
            "chunk_index": self._chunk_index,
            "journal_seq": self._journal.seq if self._journal else 0,
            "next_rid": self.scheduler._next_rid,
            "requests": [serialize_request(r)
                         for r in self._live_requests()],
            "quarantined_slots": self.scheduler.quarantined,
            "pending_deactivate": sorted(self._pending_deactivate),
            "kv": self.kv.snapshot() if self.kv is not None else None,
        }
        self._get_ckpt().save_session(self._chunk_index, self.state, meta)
        self._snapshots_taken += 1
        self._last_snapshot_chunk = self._chunk_index
        if self._journal is not None:
            self._journal.append({"ev": "snapshot",
                                  "step": self._chunk_index})
            self._journal.commit()

    def _recover(self) -> None:
        """Crash recovery: load the latest snapshot (if any), then replay
        the journal over it. The snapshot is authoritative for device +
        scheduler state; the journal contributes (a) terminal statuses and
        the committed token stream per request, and (b) requests submitted
        after the snapshot. Requests running at the snapshot resume in
        their slot bit-identically; everything else in flight re-prefills
        from its prompt with already-committed tokens suppressed at
        harvest (exactly-once delivery). Never raises on a torn journal
        tail — an fsync'd prefix is always recoverable."""
        t0 = time.perf_counter()
        summary = replay(read_events(self._durable_dir / "journal.jsonl"))
        meta = None
        if (self._durable_dir / "snapshots").exists():
            step = self._get_ckpt().latest_session_step()
            if step is not None:
                state, meta = self._get_ckpt().restore_session(
                    step, like=self.state)
                self.state = jax.device_put(state)
                self._restored_step = step
                self._chunk_index = int(meta["chunk_index"])
                self._last_snapshot_chunk = self._chunk_index
                self.scheduler._next_rid = int(meta["next_rid"])
                for s in meta.get("quarantined_slots") or []:
                    self.scheduler._quarantined.add(int(s))
                self._pending_deactivate.update(
                    int(s) for s in meta.get("pending_deactivate") or [])
                if self.kv is not None and meta.get("kv"):
                    self.kv.load_snapshot(meta["kv"])
        self.scheduler._next_rid = max(
            self.scheduler._next_rid,
            max(summary.requests, default=-1) + 1)
        snap_reqs = ({int(d["rid"]): d for d in meta["requests"]}
                     if meta else {})
        occupied = {int(d["slot"]) for d in snap_reqs.values()
                    if d.get("slot") is not None}
        resumed: set[int] = set()
        now = time.perf_counter()
        for rid in sorted(set(summary.requests) | set(snap_reqs)):
            rr = summary.requests.get(rid)
            d = snap_reqs.get(rid)
            committed = (rr.committed if rr is not None
                         else list(d.get("tokens") or []))
            status = rr.status if rr is not None else None
            if status is None and d is not None and d["state"] in (
                    DONE, CANCELLED, FAILED):
                status = d["state"]
            if d is not None:
                req = deserialize_request(d)
            elif rr is not None and rr.prompt is not None:
                req = Request(rid=rid,
                              prompt=np.asarray(rr.prompt, np.int32),
                              max_new=int(rr.max_new), klass=rr.klass,
                              deadline_s=rr.deadline_s)
            else:
                continue    # no submit record survived: nothing to rebuild
            if status is not None:
                # terminal before the crash: surface via `recovered`; any
                # slot the snapshot still held for it frees below
                req.state = status
                req.tokens = list(committed)
                if rr is not None and rr.reason is not None:
                    req.fail_reason = rr.reason
                req.slot = None
                self.recovered[rid] = RequestHandle(req)
                continue
            # in flight at the crash
            req.suppress_until = max(req.suppress_until, len(committed))
            self._replayed_requests += 1
            self._class_stats[req.klass]["submitted"] += 1
            if (d is not None and d["state"] == RUNNING
                    and d.get("slot") is not None):
                slot = int(d["slot"])
                req.state = RUNNING
                req.slot = slot
                req.started_at = now
                self.scheduler._slots[slot] = req
                resumed.add(slot)
            else:
                # queued at the snapshot, submitted after it, or preempted
                # (device snapshots are not persisted): re-prefill from
                # the prompt; the committed prefix regenerates suppressed
                req.state = QUEUED
                req.slot = None
                req.tokens = []
                req.hit_eos = False
                req.snapshot = None
                req.not_before = 0.0
                self.scheduler._queues[req.klass].append(req)
                self._resubmitted += 1
            self.handles[rid] = RequestHandle(req)
        # slots the snapshot had occupied but we did not resume: free the
        # device row (and any page tables) before the first refill
        for slot in sorted(occupied - resumed):
            self._pending_deactivate.add(slot)
            if self.kv is not None:
                self.kv.release(slot)
        self._restore_s = time.perf_counter() - t0

    def poll(self, timeout_s: float | None = None
             ) -> list[tuple[RequestHandle, np.ndarray, bool]]:
        """Advance the session by one chunk. Returns the chunk's events:
        `(handle, new_tokens, done)` per request that emitted or finished
        (failed/shed requests surface as `(handle, empty, True)`).
        A no-op (empty list) when no request is queued or running.

        `timeout_s` (or the session-wide ``watchdog_s``) bounds the
        device wait: past it, `SessionWedged` is raised instead of
        blocking forever, and the session refuses further polls until
        `recover_wedged()`."""
        if self._wedged:
            raise RuntimeError("session is wedged; call recover_wedged() "
                               "before polling again")
        # scripted silent corruption lands *before* admission, so the
        # admit-time checksum verify — not luck — must catch it before
        # the page is shared with a new request
        if self._faults is not None:
            for page in self._faults.bit_flips(self._chunk_index):
                self._inject_bit_flip(page)
        events, self._pending_events = self._pending_events, []
        self._admit_and_refill(events)
        if self.scheduler.running == 0:
            self._handle_idle_queue(events)
            if self.scheduler.running == 0:
                return events
        chunk_idx = self._chunk_index
        timeout = timeout_s if timeout_s is not None else self.watchdog_s
        if (timeout is None and self._faults is not None
                and self._faults.pending_wedge):
            raise RuntimeError("a wedge fault is scripted but nothing "
                               "bounds the device wait: set watchdog_s "
                               "or pass poll(timeout_s=...)")
        if self._faults is not None:
            corrupted = self._faults.corrupts(chunk_idx)
            if corrupted:
                mask = np.zeros(self.n_slots, bool)
                mask[corrupted] = True
                self.state = self._get_corrupt_fn()(self.state, mask)
        t0 = self.clock.dispatch()
        self.state, toks, emit, busy, _all_done = self._chunk_fn(
            self.params, self.state)
        self._chunk_index += 1
        wedge = self._faults is not None and self._faults.wedged(chunk_idx)
        if timeout is None:
            self.clock.sync(toks, emit, busy)
        else:
            self._watchdog_wait((toks, emit, busy), timeout, chunk_idx,
                                wedge)
        dt = time.perf_counter() - t0
        toks, emit, busy = (np.asarray(toks), np.asarray(emit),
                            np.asarray(busy))
        now = time.perf_counter()
        self.chunk_latencies.append((dt, int(busy.max(initial=0))))
        self._total_steps += self.chunk
        self._busy_steps += int(busy.sum())
        # fault detection runs before harvest, so a dead slot's tokens are
        # never surfaced — detection frees the slot and requeues its work
        if self._faults is not None:
            for slot in self._faults.kills(chunk_idx):
                self._recover_slot(slot, quarantine=True, events=events)
        if self._nan_check or (self._faults is not None
                               and self._faults.has_corruption):
            flags = np.asarray(self._get_nan_scan_fn()(self.state))
            if flags.any():
                running = {s for s, _ in self.scheduler.running_requests()}
                for slot in np.flatnonzero(flags):
                    if int(slot) in running:
                        self._recover_slot(int(slot), quarantine=False,
                                           events=events)
        n_emitted = 0
        for slot, req in list(self.scheduler.running_requests()):
            new = toks[slot][emit[slot]]
            deliver = new
            if new.size:
                if req.first_token_at is None:
                    req.first_token_at = now
                    self._ttfts.append(now - req.submitted_at)
                    self._class_stats[req.klass]["ttfts"].append(
                        now - req.submitted_at)
                base = req.emitted
                new_list = new.tolist()
                req.tokens.extend(new_list)
                n_emitted += new.size
                if self.eos_id is not None and np.any(new == self.eos_id):
                    req.hit_eos = True
                skip = 0
                if req.suppress_until > base:
                    # exactly-once after restore: these tokens were
                    # journal-committed (delivered) before the crash, and
                    # greedy decode just regenerated them bit-identically
                    skip = min(req.suppress_until - base, new.size)
                    self._deduped_tokens += skip
                    deliver = new[skip:]
            done = req.hit_eos or req.emitted >= req.max_new
            if done:
                req.state = DONE
                req.finished_at = now
                self._pending_release.add(slot)
                self._pending_publish.add(slot)     # clean completion:
                self._n_done += 1                   # prompt pages reusable
                lat = now - req.submitted_at
                self._latencies.append(lat)
                cs = self._class_stats[req.klass]
                cs["done"] += 1
                cs["lats"].append(lat)
                if req.deadline_s is not None and lat > req.deadline_s:
                    cs["deadline_miss"] += 1
                    self._deadline_miss += 1
            if deliver.size or done:
                handle = self.handles.pop(req.rid) if done \
                    else self.handles[req.rid]      # retire done requests
                events.append((handle, deliver, done))
                if self._journal is not None:
                    if deliver.size:
                        self._journal.append({
                            "ev": "commit", "rid": req.rid,
                            "tokens": new_list[skip:],
                            "chunk": chunk_idx})
                    if done:
                        self._journal.append({
                            "ev": "finish", "rid": req.rid,
                            "status": "done", "reason": None})
        self._emitted_total += n_emitted
        self._per_chunk_emitted.append(n_emitted)
        # background integrity scrub: re-verify a bounded round-robin
        # slice of the stamped (shared) pages each chunk; a bad page is
        # quarantined and its cached chain dropped, so the prefix
        # recomputes on next use instead of spreading. Runs after harvest
        # (admit-time verify is the first line of defense — the scrub
        # covers pages no admission is currently touching).
        if (self.kv is not None and self._page_read_fn is not None
                and self._scrub_pages):
            cand = self.kv.scrub_candidates(self._scrub_pages)
            for page in self._verify_pages(cand):
                self.kv.quarantine_page(page)
        if self._journal is not None:
            # one fsync per chunk: everything above becomes durable before
            # the events are handed to the caller
            self._journal.commit()
        # periodic bit-exact snapshot, taken at the end of the poll: the
        # device is already synced by the harvest, so the capture's
        # device_get costs no pipeline overlap, and every event of this
        # chunk is committed at the same boundary — snapshot + journal
        # tail always describe a consistent state
        if (self._snapshot_every and self._durable_dir is not None
                and self._chunk_index > 0
                and self._chunk_index % self._snapshot_every == 0
                and self._chunk_index != self._last_snapshot_chunk):
            self._save_snapshot()
        if self._faults is not None and self._faults.crashed(chunk_idx):
            if self._crash_hook is not None:
                self._crash_hook(chunk_idx)     # e.g. SIGKILL ourselves
            raise SessionCrashed(chunk_idx)
        return events

    @property
    def busy(self) -> bool:
        """True while any request is queued, running, or has terminal
        events the next `poll()` will surface."""
        return self.scheduler.busy or bool(self._pending_events)

    def stream(self, timeout_s: float | None = None
               ) -> Iterator[tuple[RequestHandle, np.ndarray, bool]]:
        """Yield `(handle, new_tokens, done)` events until the queue and
        every slot run dry. Submitting more work mid-stream extends it.
        `timeout_s` bounds each chunk's device wait (`SessionWedged`)."""
        while self.scheduler.busy or self._pending_events:
            yield from self.poll(timeout_s)

    def drain(self, timeout_s: float | None = None) -> dict:
        """Run until every submitted request completes; returns stats().
        `timeout_s` bounds each chunk's device wait (`SessionWedged`)."""
        for _ in self.stream(timeout_s):
            pass
        return self.stats()

    # -- stats -----------------------------------------------------------
    def stats(self) -> dict:
        """Session-level serving stats.

        `occupancy_pct` is live-slot-steps over total slot-steps — the
        slot-pool analogue of the paper's PE-utilization figure; `ttft_ms`
        and `latency_ms` are per-request percentiles (chunk-granular, over
        the last `HISTORY` requests); `tokens_per_s` counts emitted tokens
        across all slots over the post-warmup chunk walls (same window);
        `stall` is the StallClock ledger. Counters (`requests_done`,
        `emitted_total`, ...) cover the whole session lifetime.
        """
        rows = list(self.chunk_latencies)
        lat = np.asarray([dt for dt, _ in rows[1:]], np.float64)
        emitted = np.asarray(list(self._per_chunk_emitted)[1:], np.int64)
        tok_s = (float(emitted.sum() / max(lat.sum(), 1e-9))
                 if lat.size else 0.0)
        pct = lambda xs, q: (float(np.percentile(np.asarray(xs), q))
                             if len(xs) else 0.0)
        ttfts, lats = list(self._ttfts), list(self._latencies)
        total = self.n_slots * self._total_steps

        def per_class(k: str) -> dict:
            cs = self._class_stats[k]
            return {
                "submitted": cs["submitted"], "done": cs["done"],
                "cancelled": cs["cancelled"], "failed": cs["failed"],
                "shed": cs["shed"], "preempted": cs["preempted"],
                "retries": cs["retries"],
                "deadline_miss": cs["deadline_miss"],
                "ttft_ms": {"p50": pct(cs["ttfts"], 50) * 1e3,
                            "p99": pct(cs["ttfts"], 99) * 1e3},
                "latency_ms": {"p50": pct(cs["lats"], 50) * 1e3,
                               "p99": pct(cs["lats"], 99) * 1e3},
            }

        out = {
            "requests_done": self._n_done,
            "requests_cancelled": self._n_cancelled,
            "requests_failed": self._n_failed,
            "requests_shed": sum(cs["shed"]
                                 for cs in self._class_stats.values()),
            "emitted_total": self._emitted_total,
            "tokens_per_s": tok_s,
            "occupancy_pct": 100.0 * self._busy_steps / max(total, 1),
            "ttft_ms": {"p50": pct(ttfts, 50) * 1e3,
                        "p99": pct(ttfts, 99) * 1e3},
            "latency_ms": {"p50": pct(lats, 50) * 1e3,
                           "p99": pct(lats, 99) * 1e3},
            "preemptions": self._n_preemptions,
            "retries": self._n_retries,
            "deadline_miss": self._deadline_miss,
            "classes": {k: per_class(k) for k in CLASSES},
            "quarantined_slots": self.scheduler.quarantined,
            "usable_slots": self.scheduler.usable_slots,
            "queue_peak": self.scheduler.queue_peak,
            "admitted_order": list(self.scheduler.admitted_order),
            "slots": self.n_slots,
            "chunk": self.chunk,
            "stall": self.clock.report(),
        }
        if self.kv is not None:
            out["kv"] = dict(self.kv.stats(),
                             pool_exhausted=self._n_pool_exhausted,
                             prefix_pages_expected=self._prefix_pages_expected)
        if self._durable_dir is not None or self._page_read_fn is not None:
            kv = self.kv
            out["durability"] = {
                "journal_bytes": (self._journal.bytes_written
                                  if self._journal else 0),
                "journal_events": (self._journal.seq
                                   if self._journal else 0),
                "snapshots": self._snapshots_taken,
                "snapshot_every": self._snapshot_every,
                "restored_step": self._restored_step,
                "replayed_requests": self._replayed_requests,
                "resubmitted": self._resubmitted,
                "recovered_terminal": len(self.recovered),
                "deduped_tokens": self._deduped_tokens,
                "integrity_checks": kv.integrity_checks if kv else 0,
                "integrity_violations": kv.integrity_violations if kv else 0,
                "integrity_repairs": kv.integrity_repairs if kv else 0,
                "quarantined_pages": (len(kv.pool.quarantined)
                                      if kv else 0),
                "restore_s": self._restore_s,
            }
        if self._faults is not None:
            out["faults"] = self._faults.summary()
        return out
