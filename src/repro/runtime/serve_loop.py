"""Batched serving driver: continuous batched decode over a KV cache."""

from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


class ServeLoop:
    """Greedy batched decoding with a step-compiled decode function.

    `decode_step(params, cache, batch) -> (cache, token)`; requests are
    slotted into the fixed batch (production continuous batching keeps a
    slot -> request map; completed slots are refilled each round).

    `eos_id` (None disables): a slot that emits EOS is *finished* — its
    subsequent tokens are masked to EOS, it stops counting toward emitted
    lengths, and the loop stops early once every slot has finished.
    """

    def __init__(self, decode_step: Callable, params, cache, batch_size: int,
                 eos_id: int | None = None):
        self.decode_step = decode_step
        self.params = params
        self.cache = cache
        self.batch_size = batch_size
        self.eos_id = eos_id
        self.latencies: list[float] = []
        self.emitted_lengths: np.ndarray | None = None
        self._finished: np.ndarray | None = None

    def generate(self, prompt_tokens: np.ndarray, max_new: int,
                 start_pos: int = 0) -> np.ndarray:
        """prompt_tokens: (B, 1) last prompt token per slot."""
        tok = jnp.asarray(prompt_tokens, jnp.int32)
        out = [np.asarray(tok)]
        B = out[0].shape[0]
        finished = np.zeros(B, bool)
        emitted = np.zeros(B, np.int64)
        pos = start_pos
        for _ in range(max_new):
            t0 = time.perf_counter()
            self.cache, tok = self.decode_step(
                self.params, self.cache,
                {"tokens": tok, "pos": jnp.asarray(pos, jnp.int32)})
            jax.block_until_ready(tok)
            self.latencies.append(time.perf_counter() - t0)
            step_tok = np.asarray(tok)
            emitted += ~finished
            if self.eos_id is not None:
                # already-finished slots hold EOS regardless of the argmax
                step_tok = np.where(finished[:, None], self.eos_id, step_tok)
                finished |= step_tok[:, 0] == self.eos_id
                tok = jnp.asarray(step_tok)
            out.append(step_tok)
            pos += 1
            if self.eos_id is not None and finished.all():
                break
        self.emitted_lengths = emitted
        self._finished = finished
        return np.concatenate(out, axis=1)

    def stats(self) -> dict:
        """Latency stats over the post-warmup steps (first step dropped —
        it carries compilation). With zero or one recorded step there are
        no measured samples, so throughput/percentiles report 0.0 rather
        than the fake `1/epsilon` numbers an empty array would produce;
        `decode_steps` counts the same warmup-dropped array the percentiles
        are computed over. After a `generate`, `emitted_per_slot` reports
        how many tokens each slot emitted before (and including) its EOS,
        and `finished_slots` how many slots hit EOS.
        """
        lat = np.asarray(self.latencies[1:], np.float64)
        if lat.size == 0:
            st = {"decode_steps": 0, "p50_ms": 0.0, "p99_ms": 0.0,
                  "tokens_per_s_per_slot": 0.0}
        else:
            st = {"decode_steps": int(lat.size),
                  "p50_ms": float(np.percentile(lat, 50) * 1e3),
                  "p99_ms": float(np.percentile(lat, 99) * 1e3),
                  "tokens_per_s_per_slot": float(1.0 / max(lat.mean(), 1e-9))}
        if self.emitted_lengths is not None:
            st["emitted_per_slot"] = [int(n) for n in self.emitted_lengths]
            if self.eos_id is not None:
                st["finished_slots"] = int(self._finished.sum())
        return st
