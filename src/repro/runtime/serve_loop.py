"""Serving drivers: batch programs (`ServeLoop`) and request-level
continuous batching (`ServeSession`).

`ServeLoop` is the fixed-batch driver: one rectangular batch of prompts
runs to completion, so a slot that finishes early idles until the slowest
request drains — the software analogue of MemPool's stalled-PE problem.

`ServeSession` is the request-level driver: a fixed slot pool stepped by
the scan-compiled session cell (runtime/engine.py), with a host-side
`SlotScheduler` (runtime/scheduler.py) evicting finished slots and
admitting queued requests between chunks. Steady-state decode stays
allocation-free (the whole pool state is donated through every chunk and
refill) and the host syncs once per K tokens.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Callable, Iterator

import jax.numpy as jnp
import numpy as np

HISTORY = 4096          # sliding-window length for session stats records


def chunked_latency_stats(samples) -> dict:
    """Per-token latency stats from `(seconds, steps)` chunk samples.

    The first sample is dropped (it carries compilation); with zero
    post-warmup samples the figures report 0.0 rather than fake
    `1/epsilon` numbers. Shared by `ServeLoop.stats` (engine path) and
    the session's legacy-shaped one-shot stats so the two cannot drift.
    """
    samples = list(samples)
    lat = np.asarray([dt for dt, _ in samples[1:]], np.float64)
    steps = np.asarray([n for _, n in samples[1:]], np.int64)
    tokens = int(steps.sum())
    if lat.size == 0 or tokens == 0:
        return {"decode_steps": 0, "p50_ms": 0.0, "p99_ms": 0.0,
                "tokens_per_s_per_slot": 0.0}
    per_tok = lat / np.maximum(steps, 1)
    return {"decode_steps": tokens,
            "p50_ms": float(np.percentile(per_tok, 50) * 1e3),
            "p99_ms": float(np.percentile(per_tok, 99) * 1e3),
            "tokens_per_s_per_slot": float(tokens / max(lat.sum(), 1e-9))}

from repro.runtime.engine import DecodeEngine, StallClock
from repro.runtime.scheduler import (DONE, QUEUED, RUNNING, RequestHandle,
                                     SlotScheduler)


class ServeLoop:
    """Greedy batched decoding with a step-compiled decode function.

    `decode_step(params, cache, batch) -> (cache, token)`; requests are
    slotted into the fixed batch (production continuous batching keeps a
    slot -> request map; completed slots are refilled each round).

    `eos_id` (None disables): a slot that emits EOS is *finished* — its
    subsequent tokens are masked to EOS, it stops counting toward emitted
    lengths, and the loop stops early once every slot has finished.

    `chunk` picks the execution engine: 1 (default) is the per-token host
    loop — one dispatch + one host sync per token; K > 1 compiles K decode
    steps into one `lax.scan` program with donated cache/token buffers
    (runtime/engine.py), so the host syncs once per K tokens. Both paths
    produce bit-identical tokens, EOS behaviour, and emitted counts; the
    engine path additionally leaves the input `cache` buffer consumed
    (donated) after `generate`.
    """

    def __init__(self, decode_step: Callable, params, cache, batch_size: int,
                 eos_id: int | None = None, chunk: int = 1,
                 donate: bool = True, engine: DecodeEngine | None = None):
        self.decode_step = decode_step
        self.params = params
        self.cache = cache
        self.batch_size = batch_size
        self.eos_id = eos_id
        self.latencies: list[float] = []
        self.emitted_lengths: np.ndarray | None = None
        self._finished: np.ndarray | None = None
        self._chunk_steps: list[int] | None = None
        self.clock = StallClock()
        # a prebuilt engine (e.g. cached on a compiled program so its scan
        # program compiles once, not per generate) wins over `chunk`
        if engine is None and chunk > 1:
            engine = DecodeEngine(decode_step, chunk, eos_id=eos_id,
                                  donate=donate)
        self._engine = engine
        self.chunk = engine.chunk if engine is not None else chunk

    def generate(self, prompt_tokens: np.ndarray, max_new: int,
                 start_pos: int = 0) -> np.ndarray:
        """prompt_tokens: (B, 1) last prompt token per slot."""
        if self._engine is not None:
            return self._generate_chunked(prompt_tokens, max_new, start_pos)
        prompt_tokens = np.asarray(prompt_tokens)
        B = prompt_tokens.shape[0]
        out = np.empty((B, 1 + max_new), np.int32)       # one host buffer
        out[:, 0] = prompt_tokens[:, 0]
        tok = jnp.asarray(prompt_tokens, jnp.int32)
        finished = np.zeros(B, bool)
        emitted = np.zeros(B, np.int64)
        pos = start_pos
        self.latencies = []
        self.clock = StallClock()
        w = 0
        for _ in range(max_new):
            t0 = self.clock.dispatch()
            self.cache, tok = self.decode_step(
                self.params, self.cache,
                {"tokens": tok, "pos": jnp.asarray(pos, jnp.int32)})
            self.clock.sync(tok)
            self.latencies.append(time.perf_counter() - t0)
            step_tok = np.asarray(tok)
            emitted += ~finished
            if self.eos_id is not None:
                # already-finished slots hold EOS regardless of the argmax
                step_tok = np.where(finished[:, None], self.eos_id, step_tok)
                finished |= step_tok[:, 0] == self.eos_id
                tok = jnp.asarray(step_tok)
            out[:, 1 + w] = step_tok[:, 0]
            w += 1
            pos += 1
            if self.eos_id is not None and finished.all():
                break
        self.emitted_lengths = emitted
        self._finished = finished
        self._chunk_steps = None
        return out[:, :1 + w]

    def _generate_chunked(self, prompt_tokens, max_new: int,
                          start_pos: int) -> np.ndarray:
        out, cache, finished, emitted = self._engine.generate(
            self.params, self.cache, prompt_tokens, max_new, start_pos)
        self.cache = cache
        self.clock = self._engine.clock
        self.latencies = [dt for dt, _ in self._engine.chunk_latencies]
        self._chunk_steps = [n for _, n in self._engine.chunk_latencies]
        self.emitted_lengths = emitted
        self._finished = finished
        return out

    def stats(self) -> dict:
        """Latency stats over the post-warmup steps (first step — or first
        chunk, on the engine path — dropped: it carries compilation). With
        zero or one recorded sample there are no measured steps, so
        throughput/percentiles report 0.0 rather than the fake `1/epsilon`
        numbers an empty array would produce; `decode_steps` counts the
        decode steps covered by the measured samples. After a `generate`,
        `emitted_per_slot` reports how many tokens each slot emitted before
        (and including) its EOS, and `finished_slots` how many slots hit
        EOS. `stall` carries the StallClock ledger (host-sync count,
        dispatch-gap and device-wait seconds, stall_pct).
        """
        lat = np.asarray(self.latencies[1:], np.float64)
        if self._chunk_steps is not None:
            st = chunked_latency_stats(zip(self.latencies, self._chunk_steps))
        elif lat.size == 0:
            st = {"decode_steps": 0, "p50_ms": 0.0, "p99_ms": 0.0,
                  "tokens_per_s_per_slot": 0.0}
        else:
            st = {"decode_steps": int(lat.size),
                  "p50_ms": float(np.percentile(lat, 50) * 1e3),
                  "p99_ms": float(np.percentile(lat, 99) * 1e3),
                  "tokens_per_s_per_slot": float(1.0 / max(lat.mean(), 1e-9))}
        st["chunk"] = self.chunk
        st["stall"] = self.clock.report()
        if self.emitted_lengths is not None:
            st["emitted_per_slot"] = [int(n) for n in self.emitted_lengths]
            if self.eos_id is not None:
                st["finished_slots"] = int(self._finished.sum())
        return st


# ----------------------------------------------------------------------------
# Request-level serving: continuous batching over a slot pool
# ----------------------------------------------------------------------------


class ServeSession:
    """A long-lived slot pool serving a stream of independent requests.

    ::

        sess = cluster.compile(ServeSessionProgram(slots=8)).open()
        h = sess.submit(prompt, max_new=64)        # -> RequestHandle
        for handle, toks, done in sess.stream():   # incremental tokens
            ...
        sess.drain()                               # run queue dry
        h.result()                                 # (T,) np.int32

    The device side is one scan-compiled chunk program (`chunk_fn`) that
    advances every live slot K steps — per-slot prompt prefill, position
    tracking, EOS/budget masking all on device — plus a refill program
    (`refill_fn`) that recycles finished slots in place. The host wakes
    once per chunk: harvest emitted tokens, free finished slots, admit
    queued requests, dispatch the next chunk. Both programs donate the
    pool state, so steady-state serving allocates nothing.
    """

    def __init__(self, chunk_fn: Callable, refill_fn: Callable, params,
                 state: dict, *, n_slots: int, chunk: int,
                 max_prompt: int, max_seq: int | None = None,
                 eos_id: int | None = None, max_queue: int | None = None,
                 admission: str = "fifo"):
        self._chunk_fn = chunk_fn
        self._refill_fn = refill_fn
        self.params = params
        self.state = state
        self.n_slots = n_slots
        self.chunk = chunk
        self.max_prompt = max_prompt
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.scheduler = SlotScheduler(n_slots, max_queue=max_queue,
                                       policy=admission)
        self.clock = StallClock()
        # bounded histories: a session lives for an open-ended request
        # stream, so per-chunk and per-request records keep a sliding
        # window (percentiles cover the recent window; totals are counters)
        self.chunk_latencies: deque[tuple[float, int]] = deque(
            maxlen=HISTORY)
        self.handles: dict[int, RequestHandle] = {}    # in-flight only
        self._pending_release: set[int] = set()
        self._busy_steps = 0
        self._total_steps = 0
        self._emitted_total = 0
        self._per_chunk_emitted: deque[int] = deque(maxlen=HISTORY)
        self._ttfts: deque[float] = deque(maxlen=HISTORY)
        self._latencies: deque[float] = deque(maxlen=HISTORY)
        self._n_done = 0
        self._n_cancelled = 0

    # -- request lifecycle ----------------------------------------------
    def submit(self, prompt, max_new: int) -> RequestHandle:
        """Enqueue one request; admitted to a slot at a chunk boundary.
        Raises `scheduler.QueueFull` when the bounded queue is at capacity.
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size > self.max_prompt:
            raise ValueError(f"prompt of {prompt.size} tokens exceeds the "
                             f"session's max_prompt={self.max_prompt}")
        # the request's last KV write lands at position P + max_new - 2
        # (the step consuming prompt token P emits token #1), so it fits
        # iff P + max_new - 1 <= max_seq — exactly the old ServeProgram
        # bound of P + N <= max_seq once run(prompt)'s +1 budget is counted
        if (self.max_seq is not None
                and prompt.size + max_new - 1 > self.max_seq):
            raise ValueError(f"prompt ({prompt.size}) + max_new ({max_new}) "
                             f"exceeds the session's max_seq={self.max_seq}")
        req = self.scheduler.submit(prompt, max_new)
        handle = RequestHandle(req)
        self.handles[req.rid] = handle
        return handle

    def cancel(self, handle: RequestHandle) -> bool:
        """Cancel a request. Queued: removed now. Running: its slot is
        freed (and refillable) at the next chunk boundary."""
        was_queued = handle._req.state == QUEUED
        ok = self.scheduler.cancel(handle._req)
        if ok:
            self._n_cancelled += 1
            if was_queued:                  # terminal now; running requests
                self.handles.pop(handle.id, None)   # retire at the boundary
        return ok

    # -- the chunk boundary ---------------------------------------------
    def _admit_and_refill(self) -> None:
        release = np.zeros(self.n_slots, bool)
        for slot, req in list(self.scheduler.running_requests()):
            if req.state != RUNNING:            # cancelled mid-flight
                self._pending_release.add(slot)
                self.handles.pop(req.rid, None)     # retired
        for slot in self._pending_release:
            self.scheduler.release(slot)
            release[slot] = True
        self._pending_release.clear()
        admits = self.scheduler.admit()
        if not admits and not release.any():
            return
        admit = np.zeros(self.n_slots, bool)
        pbuf = np.zeros((self.n_slots, self.max_prompt), np.int32)
        plen = np.zeros(self.n_slots, np.int32)
        budget = np.zeros(self.n_slots, np.int32)
        for slot, req in admits:
            admit[slot] = True
            pbuf[slot, :req.prompt.size] = req.prompt
            plen[slot] = req.prompt.size
            budget[slot] = req.max_new
        self.state = self._refill_fn(self.state, admit, release, pbuf,
                                     plen, budget)

    def poll(self) -> list[tuple[RequestHandle, np.ndarray, bool]]:
        """Advance the session by one chunk. Returns the chunk's events:
        `(handle, new_tokens, done)` per request that emitted or finished.
        A no-op (empty list) when no request is queued or running."""
        self._admit_and_refill()
        if self.scheduler.running == 0:
            return []
        t0 = self.clock.dispatch()
        self.state, toks, emit, busy, _all_done = self._chunk_fn(
            self.params, self.state)
        self.clock.sync(toks, emit, busy)
        dt = time.perf_counter() - t0
        toks, emit, busy = (np.asarray(toks), np.asarray(emit),
                            np.asarray(busy))
        now = time.perf_counter()
        self.chunk_latencies.append((dt, int(busy.max(initial=0))))
        self._total_steps += self.chunk
        self._busy_steps += int(busy.sum())
        events = []
        n_emitted = 0
        for slot, req in list(self.scheduler.running_requests()):
            new = toks[slot][emit[slot]]
            if new.size:
                if req.first_token_at is None:
                    req.first_token_at = now
                    self._ttfts.append(now - req.submitted_at)
                req.tokens.extend(int(t) for t in new)
                n_emitted += new.size
                if self.eos_id is not None and np.any(new == self.eos_id):
                    req.hit_eos = True
            done = req.hit_eos or req.emitted >= req.max_new
            if done:
                req.state = DONE
                req.finished_at = now
                self._pending_release.add(slot)
                self._n_done += 1
                self._latencies.append(now - req.submitted_at)
            if new.size or done:
                handle = self.handles.pop(req.rid) if done \
                    else self.handles[req.rid]      # retire done requests
                events.append((handle, new, done))
        self._emitted_total += n_emitted
        self._per_chunk_emitted.append(n_emitted)
        return events

    def stream(self) -> Iterator[tuple[RequestHandle, np.ndarray, bool]]:
        """Yield `(handle, new_tokens, done)` events until the queue and
        every slot run dry. Submitting more work mid-stream extends it."""
        while self.scheduler.busy:
            yield from self.poll()

    def drain(self) -> dict:
        """Run until every submitted request completes; returns stats()."""
        for _ in self.stream():
            pass
        return self.stats()

    # -- stats -----------------------------------------------------------
    def stats(self) -> dict:
        """Session-level serving stats.

        `occupancy_pct` is live-slot-steps over total slot-steps — the
        slot-pool analogue of the paper's PE-utilization figure; `ttft_ms`
        and `latency_ms` are per-request percentiles (chunk-granular, over
        the last `HISTORY` requests); `tokens_per_s` counts emitted tokens
        across all slots over the post-warmup chunk walls (same window);
        `stall` is the StallClock ledger. Counters (`requests_done`,
        `emitted_total`, ...) cover the whole session lifetime.
        """
        rows = list(self.chunk_latencies)
        lat = np.asarray([dt for dt, _ in rows[1:]], np.float64)
        emitted = np.asarray(list(self._per_chunk_emitted)[1:], np.int64)
        tok_s = (float(emitted.sum() / max(lat.sum(), 1e-9))
                 if lat.size else 0.0)
        pct = lambda xs, q: (float(np.percentile(np.asarray(xs), q))
                             if len(xs) else 0.0)
        ttfts, lats = list(self._ttfts), list(self._latencies)
        total = self.n_slots * self._total_steps
        return {
            "requests_done": self._n_done,
            "requests_cancelled": self._n_cancelled,
            "emitted_total": self._emitted_total,
            "tokens_per_s": tok_s,
            "occupancy_pct": 100.0 * self._busy_steps / max(total, 1),
            "ttft_ms": {"p50": pct(ttfts, 50) * 1e3,
                        "p99": pct(ttfts, 99) * 1e3},
            "latency_ms": {"p50": pct(lats, 50) * 1e3,
                           "p99": pct(lats, 99) * 1e3},
            "queue_peak": self.scheduler.queue_peak,
            "admitted_order": list(self.scheduler.admitted_order),
            "slots": self.n_slots,
            "chunk": self.chunk,
            "stall": self.clock.report(),
        }
