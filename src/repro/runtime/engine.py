"""Device-resident execution engine — burying the host round-trip.

MemPool's headline number is <2% execution stalls at 256 cores: every PE has
an independent instruction path and the DMA engine streams operands, so
cores never wait on a slow shared frontend. Our runtime's "frontend" is the
Python host loop — one dispatch plus one `block_until_ready` per decode
token (or train step) is the execution stall of the TPU translation, and at
small models it dominates wall time.

This module rolls the loop onto the device:

* `make_decode_chunk` compiles K decode steps into ONE `lax.scan` program.
  EOS masking, the per-slot emitted counter, and the all-finished early-exit
  all live inside the scan (`lax.cond` skips the model body once every slot
  has finished), so the host syncs once per K tokens instead of once per
  token. The KV cache and the token/flag buffers are donated
  (`donate_argnums`), so steady-state decode re-uses the same device
  allocations chunk after chunk.
* `make_train_chunk` is the same treatment for training: a scan over a
  stacked batch of `steps_per_sync` micro-iterations with the whole train
  state donated; the straggler detector and logger sample at chunk
  granularity.
* `StallClock` is the stall-accounting layer: host-sync count, dispatch-gap
  time (host-side work between one sync finishing and the next dispatch —
  the paper's execution stall), and device-wait time, reported as a
  `stall_pct` figure to track against the paper's <2%.

The chunk programs are pure functions of explicit carries — no hidden
state — so they compose with any decode/train step built by
`models/steps.py` (or a scripted stand-in in tests).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


# ----------------------------------------------------------------------------
# Stall accounting
# ----------------------------------------------------------------------------


@dataclasses.dataclass
class StallClock:
    """Host-side stall ledger for a device-resident loop.

    Call `dispatch()` right before handing work to the device and
    `sync(*arrays)` when the host blocks on results. The gap between one
    sync completing and the next dispatch is host-only time — the device
    sits idle, the direct analogue of MemPool's execution stall. `sync`
    time itself is the host waiting on the *device* (compute, not stall).
    """

    host_syncs: int = 0
    dispatch_gap_s: float = 0.0
    device_wait_s: float = 0.0
    _t_start: float = dataclasses.field(default_factory=time.perf_counter)
    _last_sync_end: float | None = None

    def dispatch(self) -> float:
        now = time.perf_counter()
        if self._last_sync_end is not None:
            self.dispatch_gap_s += now - self._last_sync_end
        return now

    def sync(self, *arrays) -> float:
        """Block on `arrays`; returns the post-sync timestamp."""
        t0 = time.perf_counter()
        if arrays:
            jax.block_until_ready(arrays)
        now = time.perf_counter()
        self.host_syncs += 1
        self.device_wait_s += now - t0
        self._last_sync_end = now
        return now

    def report(self) -> dict:
        wall = time.perf_counter() - self._t_start
        return {
            "host_syncs": self.host_syncs,
            "dispatch_gap_s": self.dispatch_gap_s,
            "device_wait_s": self.device_wait_s,
            "wall_s": wall,
            "stall_pct": 100.0 * self.dispatch_gap_s / max(wall, 1e-12),
        }


# ----------------------------------------------------------------------------
# Scan-compiled multi-token decode
# ----------------------------------------------------------------------------


def decode_chunk_fn(decode_step: Callable, chunk: int,
                    eos_id: int | None = None) -> Callable:
    """The pure K-step decode program (unjitted — see `make_decode_chunk`).

    Signature::

        chunk_fn(params, cache, tok, finished, emitted, pos, remaining)
          -> (cache, tok, finished, emitted, pos, n_steps, all_done, tokens)

    `tok` (B, 1) is the last sampled token, `finished`/`emitted` the per-slot
    EOS flags and emitted-token counters, `pos` the decode position and
    `remaining` how many tokens the caller still wants (both traced int32
    scalars, so one compiled program serves every chunk of a generation).
    `tokens` is (B, K); only the first `n_steps` columns are valid — padding
    steps (past `remaining`, or after every slot finished) are skipped with
    `lax.cond`, i.e. the model body does not run for them.

    Step semantics replicate the per-token host loop bit for bit: `emitted`
    counts a slot's tokens up to and including its EOS; a finished slot's
    tokens are masked to EOS before being fed back and recorded.
    """

    def chunk_fn(params, cache, tok, finished, emitted, pos, remaining):
        def body(carry, k):
            cache, tok, finished, emitted, pos, n = carry
            stop = k >= remaining
            if eos_id is not None:
                stop = jnp.logical_or(stop, jnp.all(finished))
            active = jnp.logical_not(stop)

            def run(operand):
                cache, tok = operand
                return decode_step(params, cache,
                                   {"tokens": tok, "pos": pos})

            def skip(operand):
                return operand

            new_cache, raw_tok = jax.lax.cond(active, run, skip, (cache, tok))
            if eos_id is not None:
                # finished slots (and padding steps) hold EOS regardless of
                # the argmax — exactly the host loop's masking order
                mask = jnp.logical_or(finished, stop)
                out_tok = jnp.where(mask[:, None], eos_id, raw_tok)
                new_finished = jnp.where(active,
                                         jnp.logical_or(
                                             finished,
                                             out_tok[:, 0] == eos_id),
                                         finished)
            else:
                out_tok = raw_tok
                new_finished = finished
            new_emitted = emitted + jnp.where(
                active, jnp.logical_not(finished).astype(emitted.dtype), 0)
            step = active.astype(jnp.int32)
            carry = (new_cache, out_tok, new_finished, new_emitted,
                     pos + step, n + step)
            return carry, out_tok

        init = (cache, tok, finished, emitted, pos, jnp.zeros((), jnp.int32))
        (cache, tok, finished, emitted, pos, n), toks = jax.lax.scan(
            body, init, jnp.arange(chunk, dtype=jnp.int32))
        all_done = (jnp.all(finished) if eos_id is not None
                    else jnp.zeros((), bool))
        tokens = jnp.moveaxis(toks[..., 0], 0, 1)        # (K, B, 1) -> (B, K)
        return cache, tok, finished, emitted, pos, n, all_done, tokens

    return chunk_fn


def make_decode_chunk(decode_step: Callable, chunk: int, *,
                      eos_id: int | None = None,
                      donate: bool = True) -> Callable:
    """Jit `decode_chunk_fn`, donating the cache/token/flag buffers so
    steady-state decode runs allocation-free. Donated inputs are invalid
    after the call — callers must thread the returned buffers forward."""
    fn = decode_chunk_fn(decode_step, chunk, eos_id)
    return jax.jit(fn, donate_argnums=(1, 2, 3, 4) if donate else ())


class DecodeEngine:
    """Drives a scan-compiled decode program chunk by chunk.

    One `generate` produces up to `max_new` tokens with `ceil(T / K)` host
    syncs instead of `T`. Per-chunk wall times land in `chunk_latencies`
    as `(seconds, steps)` pairs and the stall ledger in `clock`.
    """

    def __init__(self, decode_step: Callable, chunk: int = 16, *,
                 eos_id: int | None = None, donate: bool = True):
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        self.chunk = chunk
        self.eos_id = eos_id
        self.donate = donate
        self._chunk_fn = make_decode_chunk(decode_step, chunk,
                                           eos_id=eos_id, donate=donate)
        self.clock = StallClock()
        self.chunk_latencies: list[tuple[float, int]] = []

    def generate(self, params, cache, start_tok: np.ndarray, max_new: int,
                 start_pos: int = 0):
        """Returns (out (B, 1 + T) np.int32, cache, finished, emitted).

        `out[:, 0]` is the start token; T <= max_new generation columns
        follow (shorter when every slot hits EOS early). `cache` is the
        final donated-through KV cache; the caller's input cache buffer is
        consumed.
        """
        start_tok = np.asarray(start_tok)
        B = start_tok.shape[0]
        out = np.empty((B, 1 + max_new), np.int32)       # one host buffer
        out[:, 0] = start_tok[:, 0]
        tok = jnp.asarray(start_tok, jnp.int32)
        finished = jnp.zeros((B,), bool)
        emitted = jnp.zeros((B,), jnp.int32)
        pos = jnp.asarray(start_pos, jnp.int32)
        self.clock = StallClock()
        self.chunk_latencies = []
        w = 0
        while w < max_new:
            remaining = max_new - w
            t0 = self.clock.dispatch()
            (cache, tok, finished, emitted, pos, n, all_done,
             toks) = self._chunk_fn(params, cache, tok, finished, emitted,
                                    pos, jnp.asarray(remaining, jnp.int32))
            self.clock.sync(n, all_done, toks)
            dt = time.perf_counter() - t0
            n = int(n)
            self.chunk_latencies.append((dt, n))
            out[:, 1 + w:1 + w + n] = np.asarray(toks)[:, :n]
            w += n
            if n < min(self.chunk, remaining) or bool(all_done):
                break
        return (out[:, :1 + w], cache, np.asarray(finished),
                np.asarray(emitted, np.int64))


# ----------------------------------------------------------------------------
# Scan-compiled multi-step training
# ----------------------------------------------------------------------------


def make_train_chunk(train_step: Callable, *, donate: bool = True) -> Callable:
    """Roll `train_step` into a scan over a stacked batch.

    `chunk(state, batches)` runs one step per leading-dim slice of
    `batches` and returns `(state, metrics)` with every metric stacked
    (shape (k, ...)). The train state is donated, so steady-state training
    re-uses the param/opt-state buffers; the chunk length is inferred from
    the stacked batch (jit re-specializes per distinct length — at most two
    per run: the steady chunk and the final partial one).
    """

    def chunk(state, batches):
        def body(s, b):
            return train_step(s, b)
        return jax.lax.scan(body, state, batches)

    return jax.jit(chunk, donate_argnums=(0,) if donate else ())


def stack_batches(batches: list) -> dict:
    """Stack host/device batch pytrees on a new leading step axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
