"""Device-resident execution engine — burying the host round-trip.

MemPool's headline number is <2% execution stalls at 256 cores: every PE has
an independent instruction path and the DMA engine streams operands, so
cores never wait on a slow shared frontend. Our runtime's "frontend" is the
Python host loop — one dispatch plus one `block_until_ready` per decode
token (or train step) is the execution stall of the TPU translation, and at
small models it dominates wall time.

This module rolls the loop onto the device:

* `make_decode_chunk` compiles K decode steps into ONE `lax.scan` program.
  EOS masking, the per-slot emitted counter, and the all-finished early-exit
  all live inside the scan (`lax.cond` skips the model body once every slot
  has finished), so the host syncs once per K tokens instead of once per
  token. The KV cache and the token/flag buffers are donated
  (`donate_argnums`), so steady-state decode re-uses the same device
  allocations chunk after chunk.
* `make_train_chunk` is the same treatment for training: a scan over a
  stacked batch of `steps_per_sync` micro-iterations with the whole train
  state donated; the straggler detector and logger sample at chunk
  granularity.
* `StallClock` is the stall-accounting layer: host-sync count, dispatch-gap
  time (host-side work between one sync finishing and the next dispatch —
  the paper's execution stall), and device-wait time, reported as a
  `stall_pct` figure to track against the paper's <2%.

The chunk programs are pure functions of explicit carries — no hidden
state — so they compose with any decode/train step built by
`models/steps.py` (or a scripted stand-in in tests).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


# ----------------------------------------------------------------------------
# Stall accounting
# ----------------------------------------------------------------------------


@dataclasses.dataclass
class StallClock:
    """Host-side stall ledger for a device-resident loop.

    Call `dispatch()` right before handing work to the device and
    `sync(*arrays)` when the host blocks on results. The gap between one
    sync completing and the next dispatch is host-only time — the device
    sits idle, the direct analogue of MemPool's execution stall. `sync`
    time itself is the host waiting on the *device* (compute, not stall).
    """

    host_syncs: int = 0
    dispatch_gap_s: float = 0.0
    device_wait_s: float = 0.0
    _t_start: float = dataclasses.field(default_factory=time.perf_counter)
    _last_sync_end: float | None = None

    def dispatch(self) -> float:
        now = time.perf_counter()
        if self._last_sync_end is not None:
            self.dispatch_gap_s += now - self._last_sync_end
        return now

    def sync(self, *arrays) -> float:
        """Block on `arrays`; returns the post-sync timestamp."""
        t0 = time.perf_counter()
        if arrays:
            jax.block_until_ready(arrays)
        now = time.perf_counter()
        self.host_syncs += 1
        self.device_wait_s += now - t0
        self._last_sync_end = now
        return now

    def sync_done(self, t_wait_start: float) -> float:
        """Record a sync whose device wait happened externally (e.g. on a
        watchdog thread): the wait ran from `t_wait_start` to now."""
        now = time.perf_counter()
        self.host_syncs += 1
        self.device_wait_s += now - t_wait_start
        self._last_sync_end = now
        return now

    def report(self) -> dict:
        wall = time.perf_counter() - self._t_start
        return {
            "host_syncs": self.host_syncs,
            "dispatch_gap_s": self.dispatch_gap_s,
            "device_wait_s": self.device_wait_s,
            "wall_s": wall,
            "stall_pct": 100.0 * self.dispatch_gap_s / max(wall, 1e-12),
        }

    @staticmethod
    def merge(clocks) -> "StallClock":
        """Fold per-group ledgers into one aggregate clock.

        Additive counters (syncs, gaps, device waits) sum; wall time does
        NOT — concurrent ledgers cover the same wall-clock span, so the
        merged clock keeps the earliest member start and `report()`
        divides the summed gap by ONE shared wall, never N overlapping
        copies of it. The merged `stall_pct` is therefore host-idle
        device-seconds per wall second — a load-average-style figure
        that can exceed 100% when several groups stall concurrently
        inside the same span (cap: 100% x n_groups). Per-group ratios
        live in each member's own report. An empty merge is a fresh
        clock.
        """
        clocks = list(clocks)
        if not clocks:
            return StallClock()
        out = StallClock(
            host_syncs=sum(c.host_syncs for c in clocks),
            dispatch_gap_s=sum(c.dispatch_gap_s for c in clocks),
            device_wait_s=sum(c.device_wait_s for c in clocks),
            _t_start=min(c._t_start for c in clocks))
        ends = [c._last_sync_end for c in clocks
                if c._last_sync_end is not None]
        out._last_sync_end = max(ends) if ends else None
        return out


# ----------------------------------------------------------------------------
# Scan-compiled multi-token decode
# ----------------------------------------------------------------------------


def decode_chunk_fn(decode_step: Callable, chunk: int,
                    eos_id: int | None = None) -> Callable:
    """The pure K-step decode program (unjitted — see `make_decode_chunk`).

    Signature::

        chunk_fn(params, cache, tok, finished, emitted, pos, remaining)
          -> (cache, tok, finished, emitted, pos, n_steps, all_done, tokens)

    `tok` (B, 1) is the last sampled token, `finished`/`emitted` the per-slot
    EOS flags and emitted-token counters, `pos` the decode position and
    `remaining` how many tokens the caller still wants (both traced int32
    scalars, so one compiled program serves every chunk of a generation).
    `tokens` is (B, K); only the first `n_steps` columns are valid — padding
    steps (past `remaining`, or after every slot finished) are skipped with
    `lax.cond`, i.e. the model body does not run for them.

    Step semantics replicate the per-token host loop bit for bit: `emitted`
    counts a slot's tokens up to and including its EOS; a finished slot's
    tokens are masked to EOS before being fed back and recorded.
    """

    def chunk_fn(params, cache, tok, finished, emitted, pos, remaining):
        def body(carry, k):
            cache, tok, finished, emitted, pos, n = carry
            stop = k >= remaining
            if eos_id is not None:
                stop = jnp.logical_or(stop, jnp.all(finished))
            active = jnp.logical_not(stop)

            def run(operand):
                cache, tok = operand
                return decode_step(params, cache,
                                   {"tokens": tok, "pos": pos})

            def skip(operand):
                return operand

            new_cache, raw_tok = jax.lax.cond(active, run, skip, (cache, tok))
            if eos_id is not None:
                # finished slots (and padding steps) hold EOS regardless of
                # the argmax — exactly the host loop's masking order
                mask = jnp.logical_or(finished, stop)
                out_tok = jnp.where(mask[:, None], eos_id, raw_tok)
                new_finished = jnp.where(active,
                                         jnp.logical_or(
                                             finished,
                                             out_tok[:, 0] == eos_id),
                                         finished)
            else:
                out_tok = raw_tok
                new_finished = finished
            new_emitted = emitted + jnp.where(
                active, jnp.logical_not(finished).astype(emitted.dtype), 0)
            step = active.astype(jnp.int32)
            carry = (new_cache, out_tok, new_finished, new_emitted,
                     pos + step, n + step)
            return carry, out_tok

        init = (cache, tok, finished, emitted, pos, jnp.zeros((), jnp.int32))
        (cache, tok, finished, emitted, pos, n), toks = jax.lax.scan(
            body, init, jnp.arange(chunk, dtype=jnp.int32))
        all_done = (jnp.all(finished) if eos_id is not None
                    else jnp.zeros((), bool))
        tokens = jnp.moveaxis(toks[..., 0], 0, 1)        # (K, B, 1) -> (B, K)
        return cache, tok, finished, emitted, pos, n, all_done, tokens

    return chunk_fn


def make_decode_chunk(decode_step: Callable, chunk: int, *,
                      eos_id: int | None = None,
                      donate: bool = True) -> Callable:
    """Jit `decode_chunk_fn`, donating the cache/token/flag buffers so
    steady-state decode runs allocation-free. Donated inputs are invalid
    after the call — callers must thread the returned buffers forward."""
    fn = decode_chunk_fn(decode_step, chunk, eos_id)
    return jax.jit(fn, donate_argnums=(1, 2, 3, 4) if donate else ())


class DecodeEngine:
    """Drives a scan-compiled decode program chunk by chunk.

    One `generate` produces up to `max_new` tokens with `ceil(T / K)` host
    syncs instead of `T`. Per-chunk wall times land in `chunk_latencies`
    as `(seconds, steps)` pairs and the stall ledger in `clock`.
    """

    def __init__(self, decode_step: Callable, chunk: int = 16, *,
                 eos_id: int | None = None, donate: bool = True):
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        self.chunk = chunk
        self.eos_id = eos_id
        self.donate = donate
        self._decode_step = decode_step
        # scan programs keyed by scan length: the steady chunk is K; a tail
        # chunk (max_new % K) compiles a short-scan variant once and reuses
        # it, instead of running K iterations with every step masked off
        self._chunk_fns: dict[int, Callable] = {
            chunk: make_decode_chunk(decode_step, chunk, eos_id=eos_id,
                                     donate=donate)}
        self.clock = StallClock()
        self.chunk_latencies: list[tuple[float, int]] = []

    def _fn_for(self, k: int) -> Callable:
        fn = self._chunk_fns.get(k)
        if fn is None:
            fn = make_decode_chunk(self._decode_step, k, eos_id=self.eos_id,
                                   donate=self.donate)
            self._chunk_fns[k] = fn
        return fn

    def generate(self, params, cache, start_tok: np.ndarray, max_new: int,
                 start_pos: int = 0):
        """Returns (out (B, 1 + T) np.int32, cache, finished, emitted).

        `out[:, 0]` is the start token; T <= max_new generation columns
        follow (shorter when every slot hits EOS early). `cache` is the
        final donated-through KV cache; the caller's input cache buffer is
        consumed.
        """
        start_tok = np.asarray(start_tok)
        B = start_tok.shape[0]
        out = np.empty((B, 1 + max_new), np.int32)       # one host buffer
        out[:, 0] = start_tok[:, 0]
        tok = jnp.asarray(start_tok, jnp.int32)
        finished = jnp.zeros((B,), bool)
        emitted = jnp.zeros((B,), jnp.int32)
        pos = jnp.asarray(start_pos, jnp.int32)
        self.clock = StallClock()
        self.chunk_latencies = []
        w = 0
        while w < max_new:
            remaining = max_new - w
            k = min(self.chunk, remaining)      # tail chunk: short scan
            t0 = self.clock.dispatch()
            (cache, tok, finished, emitted, pos, n, all_done,
             toks) = self._fn_for(k)(params, cache, tok, finished, emitted,
                                     pos, jnp.asarray(remaining, jnp.int32))
            self.clock.sync(n, all_done, toks)
            dt = time.perf_counter() - t0
            n = int(n)
            self.chunk_latencies.append((dt, n))
            out[:, 1 + w:1 + w + n] = np.asarray(toks)[:, :n]
            w += n
            if n < k or bool(all_done):
                break
        return (out[:, :1 + w], cache, np.asarray(finished),
                np.asarray(emitted, np.int64))


# ----------------------------------------------------------------------------
# Scan-compiled slot-scheduled decode — the continuous-batching session cell
# ----------------------------------------------------------------------------


def init_session_state(cache, n_slots: int, max_prompt: int,
                       pages_per_slot: int | None = None) -> dict:
    """Fresh device state for a ServeSession's slot pool (all slots idle).

    The state is one pytree so the whole pool is donated through every
    chunk: steady-state serving re-uses the same device buffers no matter
    how many requests cycle through the slots.

    `pages_per_slot` (paged KV sessions only) adds the per-slot page
    tables: a (B, pages_per_slot) int32 row per slot, all entries starting
    at the reserved trash page 0 so an idle slot's scatter-writes land
    where nobody reads.
    """
    i32 = lambda *s: jnp.zeros(s, jnp.int32)
    state = {
        "cache": cache,
        "tok": i32(n_slots, 1),                # last sampled token per slot
        "pos": i32(n_slots),                   # per-slot decode position
        "consumed": i32(n_slots),              # prompt tokens consumed
        "prompt_len": i32(n_slots),
        "prompt_buf": i32(n_slots, max_prompt),
        "budget": i32(n_slots),                # max_new per slot
        "emitted": i32(n_slots),
        "finished": jnp.zeros((n_slots,), bool),
        "active": jnp.zeros((n_slots,), bool),
        "age": i32(n_slots),                   # admissions seen by the slot
    }
    if pages_per_slot is not None:
        state["pages"] = i32(n_slots, pages_per_slot)
    return state


def session_chunk_fn(decode_step: Callable, chunk: int,
                     eos_id: int | None = None) -> Callable:
    """The pure K-step session program (unjitted — see `make_session_chunk`).

    Signature::

        chunk_fn(params, state) -> (state, tokens, emit, busy, all_done)

    `state` is the `init_session_state` pytree; every slot advances through
    its own request: while `consumed < prompt_len` the step feeds the next
    prompt token (prefill — outputs discarded until the step that consumes
    the last prompt token, whose output is the request's first emitted
    token), afterwards it feeds back its own sampled token. Slots are
    *done* — frozen in place, position not advancing — once inactive,
    finished (EOS), or out of budget (`emitted == budget`); `lax.cond`
    skips the model body entirely when every slot is done. Each slot keeps
    its own `pos`, so a freshly refilled slot restarts at position 0 while
    its neighbours are mid-generation.

    Returns per-chunk `tokens` (B, K) raw step outputs, `emit` (B, K) bool
    (which of them are emitted tokens of the slot's request — step order),
    `busy` (B,) how many of the K steps each slot was live for (occupancy
    accounting), and `all_done` for the host's early exit.
    """

    def _done(s):
        return (~s["active"]) | s["finished"] | (s["emitted"] >= s["budget"])

    def chunk_fn(params, state):
        p_max = state["prompt_buf"].shape[1]

        def body(s, _):
            done = _done(s)
            fed_prompt = (~done) & (s["consumed"] < s["prompt_len"])
            idx = jnp.clip(s["consumed"], 0, p_max - 1)
            p_tok = jnp.take_along_axis(s["prompt_buf"], idx[:, None], axis=1)
            in_tok = jnp.where(fed_prompt[:, None], p_tok, s["tok"])

            def run(operand):
                cache, tok = operand
                batch = {"tokens": tok, "pos": s["pos"]}
                if "pages" in s:        # paged KV: per-slot page tables
                    batch["pages"] = s["pages"]
                return decode_step(params, cache,
                                   batch)

            def skip(operand):
                return operand

            new_cache, raw = jax.lax.cond(jnp.any(~done), run, skip,
                                          (s["cache"], in_tok))
            consumed = s["consumed"] + fed_prompt
            # the step that consumed the last prompt token emits the first
            # token; pure-prefill outputs are discarded
            emit = (~done) & (consumed >= s["prompt_len"])
            finished = s["finished"]
            if eos_id is not None:
                finished = finished | (emit & (raw[:, 0] == eos_id))
            s = dict(s, cache=new_cache,
                     tok=jnp.where(done[:, None], s["tok"], raw),
                     pos=s["pos"] + (~done), consumed=consumed,
                     emitted=s["emitted"] + emit, finished=finished)
            return s, (raw[:, 0], emit, ~done)

        state, (toks, emit, live) = jax.lax.scan(
            body, state, None, length=chunk)
        return (state, jnp.moveaxis(toks, 0, 1), jnp.moveaxis(emit, 0, 1),
                jnp.sum(live, axis=0, dtype=jnp.int32),
                jnp.all(_done(state)))

    return chunk_fn


def make_session_chunk(decode_step: Callable, chunk: int, *,
                       eos_id: int | None = None,
                       donate: bool = True) -> Callable:
    """Jit `session_chunk_fn`, donating the whole slot-pool state pytree so
    steady-state serving runs allocation-free. The donated state is invalid
    after the call — the caller threads the returned state forward."""
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    fn = session_chunk_fn(decode_step, chunk, eos_id)
    return jax.jit(fn, donate_argnums=(1,) if donate else ())


def _default_cache_zero(cache, mask):
    """Zero masked batch rows of a flat cache (batch axis 0 on every leaf).
    Model caches with stacked layer axes pass `steps.zero_cache_slots`."""
    def one(c):
        shape = (mask.shape[0],) + (1,) * (c.ndim - 1)
        return jnp.where(mask.reshape(shape), jnp.zeros((), c.dtype), c)
    return jax.tree.map(one, cache)


def make_session_refill(*, cache_zero: Callable | None = None,
                        donate: bool = True) -> Callable:
    """Compile the slot-refill program: `refill(state, admit, release,
    prompt_buf, prompt_len, budget) -> state`.

    `admit`/`release` are (B,) bool masks; admitted slots get their cache
    rows zeroed (recurrent block states must not leak across requests),
    position/counters reset, the new request's prompt row and budget
    installed, and `age` bumped; released slots just go inactive. Rows of
    the new-request arrays outside `admit` are ignored. The state is
    donated, so refills recycle the pool's buffers in place — the DMA-refill
    analogue of the paper's always-addressable L1 slots.
    """
    cache_zero = cache_zero or _default_cache_zero

    def refill(state, admit, release, prompt_buf, prompt_len, budget):
        zero = jnp.zeros_like(state["pos"])
        pick = lambda new, old: jnp.where(admit, new, old)
        return dict(
            state,
            cache=cache_zero(state["cache"], admit),
            tok=jnp.where(admit[:, None], 0, state["tok"]),
            pos=pick(zero, state["pos"]),
            consumed=pick(zero, state["consumed"]),
            emitted=pick(zero, state["emitted"]),
            finished=jnp.where(admit, False, state["finished"]),
            active=(state["active"] & ~release) | admit,
            age=state["age"] + admit,
            prompt_buf=jnp.where(admit[:, None], prompt_buf,
                                 state["prompt_buf"]),
            prompt_len=pick(prompt_len, state["prompt_len"]),
            budget=pick(budget, state["budget"]),
        )

    return jax.jit(refill, donate_argnums=(0,) if donate else ())


def make_paged_session_refill(*, cache_zero: Callable,
                              donate: bool = True) -> Callable:
    """The paged-KV refill program: `refill(state, admit, release,
    prompt_buf, prompt_len, budget, pages, start) -> state`.

    Differences from `make_session_refill`:

    * `pages` (B, pages_per_slot) installs each admitted slot's page
      table row; released slots' rows are re-pointed at the trash page
      (0) so their frozen-position scatter-writes can never corrupt a
      page that has been reallocated;
    * `start` (B,) is the admitted slot's initial position/consumed
      count — non-zero exactly when shared prefix pages cover the first
      `start` prompt tokens, i.e. the prefill-skip that collapses TTFT;
    * `cache_zero` must be the *paged-aware* zero (`make_paged_cache_ops`
      ["zero_slots"]): only private (recurrent/rolling) leaves are
      zeroed — pool pages are left as-is, which is the point: refill is
      a table install, not a cache wipe.
    """

    def refill(state, admit, release, prompt_buf, prompt_len, budget,
               pages, start):
        start = start.astype(jnp.int32)
        pick = lambda new, old: jnp.where(admit, new, old)
        new_pages = jnp.where(admit[:, None], pages,
                              jnp.where(release[:, None], 0,
                                        state["pages"]))
        return dict(
            state,
            cache=cache_zero(state["cache"], admit),
            tok=jnp.where(admit[:, None], 0, state["tok"]),
            pos=pick(start, state["pos"]),
            consumed=pick(start, state["consumed"]),
            emitted=pick(jnp.zeros_like(state["emitted"]),
                         state["emitted"]),
            finished=jnp.where(admit, False, state["finished"]),
            active=(state["active"] & ~release) | admit,
            age=state["age"] + admit,
            prompt_buf=jnp.where(admit[:, None], prompt_buf,
                                 state["prompt_buf"]),
            prompt_len=pick(prompt_len, state["prompt_len"]),
            budget=pick(budget, state["budget"]),
            pages=new_pages,
        )

    return jax.jit(refill, donate_argnums=(0,) if donate else ())


def make_paged_nan_scan(cache_nan: Callable) -> Callable:
    """Paged corruption sentinel: `nan_scan(state) -> (B,) bool`.
    `cache_nan(cache, tables)` is `make_paged_cache_ops["nan_slots"]` —
    pool leaves are attributed to slots through the page tables."""

    def nan_scan(state):
        return cache_nan(state["cache"], state["pages"])

    return jax.jit(nan_scan)


def make_paged_slot_corrupt(cache_corrupt: Callable,
                            donate: bool = True) -> Callable:
    """Paged fault-injection write: `corrupt(state, mask) -> state` NaNs
    the masked slots' private rows *and* their table-addressed pool
    pages (`make_paged_cache_ops["corrupt_slots"]`)."""

    def corrupt(state, mask):
        return dict(state, cache=cache_corrupt(state["cache"], mask,
                                               state["pages"]))

    return jax.jit(corrupt, donate_argnums=(0,) if donate else ())


def make_page_copy(cache_copy: Callable, donate: bool = True) -> Callable:
    """Pool page copy: `page_copy(state, src, dst) -> state` (the COW
    fork's device half — `src`/`dst` are equal-length page-id vectors).
    Retraces per distinct copy count; forks are rare (one per exact
    full-prefix hit) and almost always a single page."""

    def page_copy(state, src, dst):
        return dict(state, cache=cache_copy(state["cache"], src, dst))

    return jax.jit(page_copy, donate_argnums=(0,) if donate else ())


def make_page_scrub(cache_scrub: Callable, donate: bool = True) -> Callable:
    """Pool page scrub: `page_scrub(state, pages) -> state` zeroes the
    listed pages in every pool leaf. Runs only on pages freed from a
    corrupted slot — NaN is the one thing masked attention cannot hide
    (0 * NaN poisons the gathered V row)."""

    def page_scrub(state, pages):
        return dict(state, cache=cache_scrub(state["cache"], pages))

    return jax.jit(page_scrub, donate_argnums=(0,) if donate else ())


def make_page_read(cache_read: Callable) -> Callable:
    """Pool page readback: `page_read(state, pages) -> tuple of arrays`,
    one per pool leaf, page axis first — the host digests these for the
    per-page integrity checksum. Read-only (never donated); retraces per
    distinct page count, which stays small (publish batches and the
    bounded scrub budget)."""

    def page_read(state, pages):
        return cache_read(state["cache"], pages)

    return jax.jit(page_read)


def make_page_flip(cache_flip: Callable, donate: bool = True) -> Callable:
    """Silent page corruption for the `bit_flip` fault:
    `page_flip(state, pages) -> state` perturbs the pages' float content
    by +1 — finite values the NaN sentinel scan cannot see, so only the
    content checksum catches it."""

    def page_flip(state, pages):
        return dict(state, cache=cache_flip(state["cache"], pages))

    return jax.jit(page_flip, donate_argnums=(0,) if donate else ())


# ----------------------------------------------------------------------------
# Slot-granular checkpoint/resume + fault detection — the elastic layer
# ----------------------------------------------------------------------------
#
# MemPool's robustness story is that every PE executes independently: one
# stalled or dead core never wedges the cluster, because the shared-L1 rows
# it owned stay addressable. The serving analogue: a slot must be
# *individually* checkpointable (preemption snapshots its KV rows + decode
# counters and requeues the request for a bit-identical resume later) and
# *individually* condemnable (a dead or corrupted slot is quarantined and
# the pool degrades instead of crashing). These helpers are the device half
# of that machinery; `ServeSession` (runtime/serve_loop.py) drives them.
#
# The per-request device rows that travel with a slot snapshot. `active`
# and `age` are *slot* properties, not request properties — restore forces
# active=True and bumps age like any other admission.
SLOT_FIELDS = ("tok", "pos", "consumed", "prompt_len", "prompt_buf",
               "budget", "emitted", "finished")


def _default_cache_take(cache, slot):
    """Slice slot `slot` out of a flat cache (batch axis 0 on every leaf).
    Model caches with stacked layer axes pass `steps.take_cache_slot`."""
    return jax.tree.map(
        lambda c: jax.lax.dynamic_index_in_dim(c, slot, axis=0,
                                               keepdims=False), cache)


def _default_cache_put(cache, slot, rows):
    """Inverse of `_default_cache_take` (batch axis 0 on every leaf)."""
    return jax.tree.map(lambda c, r: c.at[slot].set(r), cache, rows)


def _default_cache_fill(cache, mask, value):
    """Fill masked batch rows of a flat cache with `value` (axis 0).
    Non-float leaves are skipped when `value` is not finite (NaN fault
    injection must not touch integer state)."""
    import math

    def one(c):
        if (not jnp.issubdtype(c.dtype, jnp.inexact)
                and not math.isfinite(value)):
            return c
        shape = (mask.shape[0],) + (1,) * (c.ndim - 1)
        return jnp.where(mask.reshape(shape), jnp.asarray(value, c.dtype), c)
    return jax.tree.map(one, cache)


def _default_cache_nan(cache):
    """(B,) bool: any-NaN per batch row of a flat cache (axis 0)."""
    flags = [jnp.any(jnp.isnan(c), axis=tuple(range(1, c.ndim)))
             for c in jax.tree.leaves(cache)
             if jnp.issubdtype(c.dtype, jnp.inexact)]
    if not flags:
        return jnp.zeros((jax.tree.leaves(cache)[0].shape[0],), bool)
    out = flags[0]
    for f in flags[1:]:
        out = out | f
    return out


def make_slot_snapshot(*, cache_take: Callable | None = None) -> Callable:
    """Compile the slot-checkpoint program: `snapshot(state, slot) -> rows`.

    `rows` is the pytree of slot `slot`'s per-request device state — its
    cache rows plus every `SLOT_FIELDS` entry. Nothing is donated: the
    pool state stays live (the slot is released/refilled separately).
    The caller typically `jax.device_get`s the result so the snapshot
    survives the pool's donation cycle on the host.
    """
    cache_take = cache_take or _default_cache_take

    def snapshot(state, slot):
        rows = {k: state[k][slot] for k in SLOT_FIELDS}
        rows["cache"] = cache_take(state["cache"], slot)
        return rows

    return jax.jit(snapshot)


def make_slot_restore(*, cache_put: Callable | None = None,
                      donate: bool = True) -> Callable:
    """Compile the slot-resume program: `restore(state, slot, rows) ->
    state`. Writes a snapshot's rows back into slot `slot` — bit-exact,
    so the resumed request continues exactly where it was preempted —
    marks the slot active, and bumps its `age` (a resume is an admission).
    The pool state is donated, like refill."""
    cache_put = cache_put or _default_cache_put

    def restore(state, slot, rows):
        out = dict(state)
        for k in SLOT_FIELDS:
            out[k] = state[k].at[slot].set(rows[k])
        out["cache"] = cache_put(state["cache"], slot, rows["cache"])
        out["active"] = state["active"].at[slot].set(True)
        out["age"] = state["age"].at[slot].add(1)
        return out

    return jax.jit(restore, donate_argnums=(0,) if donate else ())


def make_nan_scan(*, cache_nan: Callable | None = None) -> Callable:
    """Compile the corruption sentinel: `nan_scan(state) -> (B,) bool`,
    true for any slot whose cache rows hold a NaN. One device reduction
    per chunk when the session runs with fault detection on; the driver
    quarantines/requeues flagged slots instead of streaming garbage."""
    cache_nan = cache_nan or _default_cache_nan

    def nan_scan(state):
        return cache_nan(state["cache"])

    return jax.jit(nan_scan)


def make_slot_corrupt(*, cache_fill: Callable | None = None,
                      donate: bool = True) -> Callable:
    """Compile the fault-injection write: `corrupt(state, mask) -> state`
    with masked slots' float cache rows set to NaN (integer rows
    untouched). Only the fault harness calls this."""
    cache_fill = cache_fill or _default_cache_fill

    def corrupt(state, mask):
        return dict(state,
                    cache=cache_fill(state["cache"], mask, float("nan")))

    return jax.jit(corrupt, donate_argnums=(0,) if donate else ())


# ----------------------------------------------------------------------------
# Scan-compiled multi-step training
# ----------------------------------------------------------------------------


def make_train_chunk(train_step: Callable, *, donate: bool = True) -> Callable:
    """Roll `train_step` into a scan over a stacked batch.

    `chunk(state, batches)` runs one step per leading-dim slice of
    `batches` and returns `(state, metrics)` with every metric stacked
    (shape (k, ...)). The train state is donated, so steady-state training
    re-uses the param/opt-state buffers; the chunk length is inferred from
    the stacked batch (jit re-specializes per distinct length — at most two
    per run: the steady chunk and the final partial one).
    """

    def chunk(state, batches):
        def body(s, b):
            return train_step(s, b)
        return jax.lax.scan(body, state, batches)

    return jax.jit(chunk, donate_argnums=(0,) if donate else ())


def stack_batches(batches: list) -> dict:
    """Stack host/device batch pytrees on a new leading step axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
