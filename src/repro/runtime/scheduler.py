"""Request-level slot scheduler for the continuous-batching serve session.

MemPool keeps hundreds of PEs under 2% stall because the shared-L1 banks
are always addressable and the DMA engine refills them while compute
proceeds. The serving analogue: a fixed pool of decode slots (the batch
rows of the compiled session cell) that must never sit idle while work is
queued. This module is the host-side half of that machinery — per-class
bounded request queues plus a slot table with pluggable admission order;
the device-side half (per-slot refill, masked stepping, slot
snapshot/restore) lives in `runtime/engine.py`.

Priority classes (the SLO layer):

* every request carries a class — ``latency`` (interactive, jumps the
  queue), ``throughput`` (bulk), or ``best_effort`` (sheddable) — and an
  optional ``deadline_s`` used for SLO accounting;
* admission orders by *effective* priority: class rank minus an
  anti-starvation aging boost (one rank per ``aging_rounds`` admission
  rounds waited), so a best-effort request that has waited long enough
  eventually outranks fresh latency traffic — no class starves;
* overload shedding: when the total queue depth crosses
  ``shed_watermark``, the newest queued *best-effort* requests are failed
  with reason ``"shed"`` until the depth is back at the watermark.
  Latency and throughput work is never shed — they get per-class
  `QueueFull` backpressure instead.

Invariants the scheduler maintains (property-tested in
tests/test_scheduler.py):

* a slot is assigned to at most one running request at a time;
* a request is admitted only from a queue, and at most once per queue
  residence (preemption legitimately requeues and re-admits);
* same-class FIFO admission preserves submit order ("longest_prefix"
  reorders by prompt length within a priority rank — longest first —
  with submit order as the tie-break);
* at equal age, a latency request is never admitted behind a throughput
  request, and throughput never behind best-effort;
* shedding only ever fails best-effort requests;
* cancelling a queued request removes it; cancelling a running request
  marks it for harvest so the driver frees the slot at the next chunk
  boundary;
* `submit` applies backpressure: a bounded per-class queue raises
  `QueueFull` instead of growing without limit;
* a quarantined slot (the driver's fault response to a dead device row)
  is never assigned again — the pool degrades instead of crashing.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Iterator

import numpy as np

QUEUED = "queued"
RUNNING = "running"
DONE = "done"
CANCELLED = "cancelled"
FAILED = "failed"

ADMISSION_POLICIES = ("fifo", "longest_prefix")

CLASSES = ("latency", "throughput", "best_effort")
CLASS_RANK = {k: i for i, k in enumerate(CLASSES)}

# typed failure reasons carried by RequestFailed
REASON_CANCELLED = "cancelled"
REASON_SHED = "shed"
REASON_RETRIES = "retries_exhausted"
REASON_POOL = "pool_exhausted"      # paged KV: request can never fit


class QueueFull(RuntimeError):
    """The session's bounded request queue is at capacity (backpressure)."""


class RequestFailed(RuntimeError):
    """`result()` on a request that did not complete: carries the typed
    `reason` ("cancelled" | "shed" | "retries_exhausted") and whatever
    tokens were emitted before the failure (`partial_tokens`)."""

    def __init__(self, rid: int, reason: str, partial_tokens=None):
        super().__init__(f"request {rid} failed: {reason}")
        self.rid = rid
        self.reason = reason
        self.partial_tokens = (np.asarray([], np.int32)
                               if partial_tokens is None
                               else np.asarray(partial_tokens, np.int32))


@dataclasses.dataclass
class Request:
    """One decode request moving through the slot pool."""

    rid: int
    prompt: np.ndarray                      # (P,) int32, P >= 1
    max_new: int
    klass: str = "latency"
    deadline_s: float | None = None
    submitted_at: float = dataclasses.field(default_factory=time.perf_counter)
    state: str = QUEUED
    slot: int | None = None
    tokens: list = dataclasses.field(default_factory=list)
    started_at: float | None = None
    first_token_at: float | None = None
    finished_at: float | None = None
    hit_eos: bool = False
    fail_reason: str | None = None
    wait_rounds: int = 0                    # admission rounds spent queued
    retries: int = 0                        # fault-recovery restarts
    preemptions: int = 0                    # times checkpointed + requeued
    not_before: float = 0.0                 # retry backoff gate (perf_counter)
    snapshot: Any = None                    # preempted slot state (resume)
    prefix_pages_expected: int = 0          # measured page overlap at admit
    suppress_until: int = 0                 # exactly-once: tokens already
    #                                         journal-committed before a
    #                                         crash are regenerated but not
    #                                         re-delivered

    @property
    def emitted(self) -> int:
        return len(self.tokens)

    @property
    def rank(self) -> int:
        return CLASS_RANK[self.klass]

    def effective_rank(self, aging_rounds: int) -> int:
        """Class rank minus the anti-starvation aging boost."""
        return self.rank - self.wait_rounds // aging_rounds


class RequestHandle:
    """The caller's view of a submitted request (returned by `submit`)."""

    def __init__(self, req: Request):
        self._req = req
        # serving group the request was placed in (sharded sessions
        # stamp this at submit; None under a plain single session)
        self.group: int | None = None

    @property
    def id(self) -> int:
        return self._req.rid

    @property
    def state(self) -> str:
        return self._req.state

    @property
    def klass(self) -> str:
        return self._req.klass

    @property
    def deadline_s(self) -> float | None:
        return self._req.deadline_s

    @property
    def done(self) -> bool:
        return self._req.state in (DONE, CANCELLED, FAILED)

    @property
    def ok(self) -> bool:
        return self._req.state == DONE

    @property
    def cancelled(self) -> bool:
        return self._req.state == CANCELLED

    @property
    def failed(self) -> bool:
        return self._req.state == FAILED

    @property
    def fail_reason(self) -> str | None:
        r = self._req
        return (REASON_CANCELLED if r.state == CANCELLED
                else r.fail_reason if r.state == FAILED else None)

    @property
    def tokens(self) -> np.ndarray:
        """Tokens emitted so far (includes EOS when the request hit it)."""
        return np.asarray(self._req.tokens, np.int32)

    @property
    def hit_eos(self) -> bool:
        return self._req.hit_eos

    def result(self) -> np.ndarray:
        """Completed tokens. Raises `RequestFailed` (typed reason, partial
        tokens attached) for a cancelled/shed/retries-exhausted request —
        a failure is never indistinguishable from success."""
        if not self.done:
            raise RuntimeError(f"request {self.id} is still {self.state}; "
                               f"drain() or poll() the session first")
        reason = self.fail_reason
        if reason is not None:
            raise RequestFailed(self.id, reason, self._req.tokens)
        return self.tokens

    @property
    def ttft_s(self) -> float | None:
        r = self._req
        if r.first_token_at is None:
            return None
        return r.first_token_at - r.submitted_at

    @property
    def latency_s(self) -> float | None:
        r = self._req
        if r.finished_at is None:
            return None
        return r.finished_at - r.submitted_at

    @property
    def missed_deadline(self) -> bool:
        r = self._req
        return (r.deadline_s is not None and r.finished_at is not None
                and (r.finished_at - r.submitted_at) > r.deadline_s)

    def __repr__(self) -> str:
        return (f"RequestHandle(id={self.id}, state={self.state}, "
                f"klass={self.klass}, emitted={self._req.emitted})")


class SlotScheduler:
    """Per-class bounded request queues + slot table with class-aware,
    aging-boosted admission.

    Pure host-side bookkeeping: it never touches device buffers, so the
    policy is unit-testable independent of the compiled session cell.

    `max_queue` bounds each class queue (QueueFull past it);
    `shed_watermark` bounds the *total* queue depth by failing the newest
    best-effort requests (reason "shed"); `aging_rounds` is the
    anti-starvation knob — every `aging_rounds` admission rounds a queued
    request waits, its effective priority rises one class rank.
    """

    def __init__(self, n_slots: int, *, max_queue: int | None = None,
                 policy: str = "fifo", shed_watermark: int | None = None,
                 aging_rounds: int = 8, prefix_score=None,
                 page_size: int | None = None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        if policy not in ADMISSION_POLICIES:
            raise ValueError(f"unknown admission policy {policy!r}; "
                             f"expected one of {ADMISSION_POLICIES}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if shed_watermark is not None and shed_watermark < 1:
            raise ValueError(f"shed_watermark must be >= 1, "
                             f"got {shed_watermark}")
        if aging_rounds < 1:
            raise ValueError(f"aging_rounds must be >= 1, got {aging_rounds}")
        self.n_slots = n_slots
        self.max_queue = max_queue
        self.policy = policy
        self.shed_watermark = shed_watermark
        self.aging_rounds = aging_rounds
        # paged-KV upgrade of "longest_prefix": a callable
        # `prompt -> reusable prefix tokens` (PagedKV.match_len) turns the
        # prompt-length heuristic into actual page-level reuse scoring;
        # `page_size` converts the score to pages for the admit decision's
        # `prefix_pages_expected` (correlated with kv prefix hits in stats)
        self.prefix_score = prefix_score
        self.page_size = page_size
        self._queues: dict[str, deque[Request]] = {k: deque() for k in CLASSES}
        self._slots: list[Request | None] = [None] * n_slots
        self._quarantined: set[int] = set()
        self._next_rid = 0
        # rids in admission order — bounded: a session admits without limit
        self.admitted_order: deque[int] = deque(maxlen=4096)
        self.queue_peak = 0
        self.shed_count: dict[str, int] = {k: 0 for k in CLASSES}
        # requests shed since the driver last drained them (pop_shed):
        # shedding happens inside submit(), so the session discovers the
        # victims here rather than by scanning its handle table
        self._shed_log: list[Request] = []

    # -- queue -----------------------------------------------------------
    def submit(self, prompt, max_new: int, *, klass: str = "latency",
               deadline_s: float | None = None) -> Request:
        if klass not in CLASSES:
            raise ValueError(f"unknown class {klass!r}; "
                             f"expected one of {CLASSES}")
        q = self._queues[klass]
        if self.max_queue is not None and len(q) >= self.max_queue:
            raise QueueFull(f"the {klass} queue is at capacity "
                            f"({self.max_queue}); drain or poll first")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("prompt must hold at least one token")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
        req = Request(rid=self._next_rid, prompt=prompt, max_new=max_new,
                      klass=klass, deadline_s=deadline_s)
        self._next_rid += 1
        q.append(req)
        self.queue_peak = max(self.queue_peak, self.queued)
        self.shed_overflow()
        return req

    def shed_overflow(self) -> list[Request]:
        """Overload protection: while the total queue depth exceeds the
        watermark, fail the newest queued best-effort requests with reason
        "shed". Latency/throughput work is never shed. Returns the shed
        requests (so the driver can surface events)."""
        shed: list[Request] = []
        if self.shed_watermark is None:
            return shed
        be = self._queues["best_effort"]
        while self.queued > self.shed_watermark and be:
            req = be[-1]                       # newest best-effort first
            self.fail(req, REASON_SHED)        # fail() dequeues it
            shed.append(req)
        self._shed_log.extend(shed)
        return shed

    def pop_shed(self) -> list[Request]:
        """Requests shed since the last call (driver event/stats hook)."""
        out, self._shed_log = self._shed_log, []
        return out

    def fail(self, req: Request, reason: str) -> None:
        """Terminal failure (shed / retries exhausted). Queued requests are
        dequeued; the caller releases the slot of a running one."""
        if req.state == QUEUED:
            self._queues[req.klass].remove(req)
        req.state = FAILED
        req.fail_reason = reason
        req.finished_at = time.perf_counter()
        self.shed_count[req.klass] += (reason == REASON_SHED)

    def cancel(self, req: Request) -> bool:
        """Queued -> removed now; running -> marked (the driver frees the
        slot at the next chunk boundary). Returns False if already over."""
        if req.state == QUEUED:
            self._queues[req.klass].remove(req)
            req.state = CANCELLED
            req.finished_at = time.perf_counter()
            return True
        if req.state == RUNNING:
            req.state = CANCELLED
            req.finished_at = time.perf_counter()
            return True
        return False

    def requeue(self, req: Request, *, front: bool = True,
                backoff_s: float = 0.0) -> None:
        """Put a released (preempted or fault-recovered) request back in
        its class queue — at the front by default, so a preempted request
        resumes as soon as its class gets a slot. `backoff_s` gates
        re-admission (fault retries back off; preemption resumes use 0)."""
        assert req.slot is None, "requeue before release"
        req.state = QUEUED
        req.not_before = (time.perf_counter() + backoff_s if backoff_s > 0
                          else 0.0)
        q = self._queues[req.klass]
        if front:
            q.appendleft(req)
        else:
            q.append(req)
        self.queue_peak = max(self.queue_peak, self.queued)

    # -- slot table ------------------------------------------------------
    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self._slots)
                if r is None and i not in self._quarantined]

    def quarantine(self, slot: int) -> None:
        """Permanently retire a slot (dead device row): it is never
        admitted into again — the pool degrades instead of crashing."""
        assert self._slots[slot] is None, "quarantine of an occupied slot"
        self._quarantined.add(slot)

    @property
    def quarantined(self) -> list[int]:
        return sorted(self._quarantined)

    @property
    def usable_slots(self) -> int:
        return self.n_slots - len(self._quarantined)

    def _admission_key(self, req: Request):
        rank = req.effective_rank(self.aging_rounds)
        if self.policy == "longest_prefix":
            if self.prefix_score is not None:
                # page-level reuse scoring: requests whose prompt prefix
                # is already resident in the shared KV pool go first —
                # they skip that much prefill, so admitting them early
                # frees their slot (and pages) soonest. Uncovered prompt
                # length breaks ties: the longest *remaining* prefill
                # starts earliest, preserving the heuristic's overlap
                # rationale for the part that still has to run.
                reused = int(self.prefix_score(req.prompt))
                if self.page_size:
                    # surfaced on the admit decision: the measured full-
                    # page overlap this request is expected to map
                    req.prefix_pages_expected = reused // self.page_size
                return (rank, -reused, -(req.prompt.size - reused),
                        req.rid)
            # longest prompt first within a rank: long prefills start
            # earliest so their extra slot-steps overlap short turnover
            return (rank, -req.prompt.size, req.rid)
        return (rank, req.rid)

    def admit(self, now: float | None = None) -> list[tuple[int, Request]]:
        """Assign queued requests to free slots: effective-priority order
        (class rank minus aging boost), FIFO within a rank. Requests whose
        retry backoff gate (`not_before`) is still in the future are
        skipped this round. Returns [(slot, request)], already RUNNING."""
        free = self.free_slots()
        if not self.queued:
            return []
        now = time.perf_counter() if now is None else now
        for q in self._queues.values():        # aging: everyone waits a round
            for req in q:
                req.wait_rounds += 1
        if not free:
            return []
        ready = [r for q in self._queues.values() for r in q
                 if r.not_before <= now]
        order = sorted(ready, key=self._admission_key)
        out = []
        for slot, req in zip(free, order):
            assert self._slots[slot] is None, "slot double-assignment"
            assert req.state == QUEUED, "re-admission of a running request"
            self._queues[req.klass].remove(req)
            self._slots[slot] = req
            req.state = RUNNING
            req.slot = slot
            req.started_at = now
            self.admitted_order.append(req.rid)
            out.append((slot, req))
        return out

    def release(self, slot: int) -> None:
        req = self._slots[slot]
        assert req is not None, f"release of a free slot {slot}"
        self._slots[slot] = None
        req.slot = None

    def preempt_victim(self, for_rank: int = 0) -> tuple[int, Request] | None:
        """The running request a queued rank-`for_rank` request should
        displace: strictly lower priority (higher rank) than the claimant,
        preferring the lowest class and, within it, the most recently
        started (least sunk work lost). None when nothing qualifies."""
        victims = [(s, r) for s, r in self.running_requests()
                   if r.state == RUNNING and r.rank > for_rank]
        if not victims:
            return None
        # rid breaks started_at ties (same-round admissions share a
        # timestamp): the later submission has the least sunk work
        return max(victims, key=lambda sr: (sr[1].rank,
                                            sr[1].started_at or 0.0,
                                            sr[1].rid))

    # -- views -----------------------------------------------------------
    @property
    def queued(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def queued_by_class(self) -> dict[str, int]:
        return {k: len(q) for k, q in self._queues.items()}

    def queued_requests(self) -> Iterator[Request]:
        for k in CLASSES:
            yield from self._queues[k]

    @property
    def running(self) -> int:
        return sum(r is not None for r in self._slots)

    def running_requests(self) -> Iterator[tuple[int, Request]]:
        for i, r in enumerate(self._slots):
            if r is not None:
                yield i, r

    @property
    def busy(self) -> bool:
        return self.queued > 0 or self.running > 0

    def load_view(self) -> dict:
        """Host-side load snapshot for the two-level placement layer
        (`runtime/groups.py`): how much of this slot pool's capacity is
        spoken for right now, in plain scalars so `MeshScheduler` can
        score groups without touching scheduler internals."""
        usable = self.usable_slots
        return {"usable_slots": usable,
                "free_slots": len(self.free_slots()),
                "running": self.running,
                "queued": self.queued,
                "max_queue": self.max_queue,
                "occupancy": self.running / max(usable, 1)}


# ----------------------------------------------------------------------------
# Durability: Request <-> JSON (session snapshots)
# ----------------------------------------------------------------------------

def serialize_request(req: Request) -> dict:
    """JSON-able image of a request for the session snapshot. Wall-clock
    timestamps and preemption device snapshots are deliberately dropped:
    times from a dead process are meaningless, and a preempted request
    re-prefills on restore (journal-committed tokens are suppressed, so
    delivery stays exactly-once and bit-identical either way)."""
    return {"rid": req.rid, "prompt": req.prompt.tolist(),
            "max_new": req.max_new, "klass": req.klass,
            "deadline_s": req.deadline_s, "state": req.state,
            "slot": req.slot, "tokens": list(req.tokens),
            "hit_eos": req.hit_eos, "fail_reason": req.fail_reason,
            "wait_rounds": req.wait_rounds, "retries": req.retries,
            "preemptions": req.preemptions,
            "prefix_pages_expected": req.prefix_pages_expected,
            "suppress_until": req.suppress_until,
            "had_snapshot": req.snapshot is not None}


def deserialize_request(d: dict) -> Request:
    """Inverse of `serialize_request` (fresh timestamps, no device
    snapshot — see there)."""
    req = Request(rid=int(d["rid"]),
                  prompt=np.asarray(d["prompt"], np.int32),
                  max_new=int(d["max_new"]), klass=str(d["klass"]),
                  deadline_s=d.get("deadline_s"))
    req.state = str(d["state"])
    req.slot = d.get("slot")
    req.tokens = [int(t) for t in d.get("tokens", [])]
    req.hit_eos = bool(d.get("hit_eos", False))
    req.fail_reason = d.get("fail_reason")
    req.wait_rounds = int(d.get("wait_rounds", 0))
    req.retries = int(d.get("retries", 0))
    req.preemptions = int(d.get("preemptions", 0))
    req.prefix_pages_expected = int(d.get("prefix_pages_expected", 0))
    req.suppress_until = int(d.get("suppress_until", 0))
    return req
