"""Request-level slot scheduler for the continuous-batching serve session.

MemPool keeps hundreds of PEs under 2% stall because the shared-L1 banks
are always addressable and the DMA engine refills them while compute
proceeds. The serving analogue: a fixed pool of decode slots (the batch
rows of the compiled session cell) that must never sit idle while work is
queued. This module is the host-side half of that machinery — a bounded
request queue plus a slot table with pluggable admission order; the
device-side half (per-slot refill, masked stepping) lives in
`runtime/engine.py`.

Invariants the scheduler maintains (property-tested in
tests/test_scheduler.py):

* a slot is assigned to at most one running request at a time;
* a request is admitted at most once, and only from the queue;
* FIFO admission preserves submit order ("longest_prefix" reorders by
  prompt length — longest first — with submit order as the tie-break);
* cancelling a queued request removes it; cancelling a running request
  marks it for harvest so the driver frees the slot at the next chunk
  boundary;
* `submit` applies backpressure: a bounded queue raises `QueueFull`
  instead of growing without limit.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Iterator

import numpy as np

QUEUED = "queued"
RUNNING = "running"
DONE = "done"
CANCELLED = "cancelled"

ADMISSION_POLICIES = ("fifo", "longest_prefix")


class QueueFull(RuntimeError):
    """The session's bounded request queue is at capacity (backpressure)."""


@dataclasses.dataclass
class Request:
    """One decode request moving through the slot pool."""

    rid: int
    prompt: np.ndarray                      # (P,) int32, P >= 1
    max_new: int
    submitted_at: float = dataclasses.field(default_factory=time.perf_counter)
    state: str = QUEUED
    slot: int | None = None
    tokens: list = dataclasses.field(default_factory=list)
    started_at: float | None = None
    first_token_at: float | None = None
    finished_at: float | None = None
    hit_eos: bool = False

    @property
    def emitted(self) -> int:
        return len(self.tokens)


class RequestHandle:
    """The caller's view of a submitted request (returned by `submit`)."""

    def __init__(self, req: Request):
        self._req = req

    @property
    def id(self) -> int:
        return self._req.rid

    @property
    def state(self) -> str:
        return self._req.state

    @property
    def done(self) -> bool:
        return self._req.state in (DONE, CANCELLED)

    @property
    def cancelled(self) -> bool:
        return self._req.state == CANCELLED

    @property
    def tokens(self) -> np.ndarray:
        """Tokens emitted so far (includes EOS when the request hit it)."""
        return np.asarray(self._req.tokens, np.int32)

    @property
    def hit_eos(self) -> bool:
        return self._req.hit_eos

    def result(self) -> np.ndarray:
        if not self.done:
            raise RuntimeError(f"request {self.id} is still {self.state}; "
                               f"drain() or poll() the session first")
        return self.tokens

    @property
    def ttft_s(self) -> float | None:
        r = self._req
        if r.first_token_at is None:
            return None
        return r.first_token_at - r.submitted_at

    @property
    def latency_s(self) -> float | None:
        r = self._req
        if r.finished_at is None:
            return None
        return r.finished_at - r.submitted_at

    def __repr__(self) -> str:
        return (f"RequestHandle(id={self.id}, state={self.state}, "
                f"emitted={self._req.emitted})")


class SlotScheduler:
    """Bounded request queue + slot table with pluggable admission order.

    Pure host-side bookkeeping: it never touches device buffers, so the
    policy is unit-testable independent of the compiled session cell.
    """

    def __init__(self, n_slots: int, *, max_queue: int | None = None,
                 policy: str = "fifo"):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        if policy not in ADMISSION_POLICIES:
            raise ValueError(f"unknown admission policy {policy!r}; "
                             f"expected one of {ADMISSION_POLICIES}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.n_slots = n_slots
        self.max_queue = max_queue
        self.policy = policy
        self._queue: deque[Request] = deque()
        self._slots: list[Request | None] = [None] * n_slots
        self._next_rid = 0
        # rids in admission order — bounded: a session admits without limit
        self.admitted_order: deque[int] = deque(maxlen=4096)
        self.queue_peak = 0

    # -- queue -----------------------------------------------------------
    def submit(self, prompt, max_new: int) -> Request:
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            raise QueueFull(f"request queue is at capacity "
                            f"({self.max_queue}); drain or poll first")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("prompt must hold at least one token")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        req = Request(rid=self._next_rid, prompt=prompt, max_new=max_new)
        self._next_rid += 1
        self._queue.append(req)
        self.queue_peak = max(self.queue_peak, len(self._queue))
        return req

    def cancel(self, req: Request) -> bool:
        """Queued -> removed now; running -> marked (the driver frees the
        slot at the next chunk boundary). Returns False if already over."""
        if req.state == QUEUED:
            self._queue.remove(req)
            req.state = CANCELLED
            req.finished_at = time.perf_counter()
            return True
        if req.state == RUNNING:
            req.state = CANCELLED
            req.finished_at = time.perf_counter()
            return True
        return False

    # -- slot table ------------------------------------------------------
    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self._slots) if r is None]

    def admit(self) -> list[tuple[int, Request]]:
        """Assign queued requests to free slots per the admission policy.
        Returns [(slot, request)] for this round, already marked RUNNING."""
        free = self.free_slots()
        if not free or not self._queue:
            return []
        if self.policy == "longest_prefix":
            # longest prompt first: long prefills start earliest so their
            # extra slot-steps overlap the short requests' turnover
            order = sorted(self._queue,
                           key=lambda r: (-r.prompt.size, r.rid))
        else:
            order = list(self._queue)
        out = []
        for slot, req in zip(free, order):
            assert self._slots[slot] is None, "slot double-assignment"
            assert req.state == QUEUED, "re-admission of a running request"
            self._queue.remove(req)
            self._slots[slot] = req
            req.state = RUNNING
            req.slot = slot
            req.started_at = time.perf_counter()
            self.admitted_order.append(req.rid)
            out.append((slot, req))
        return out

    def release(self, slot: int) -> None:
        req = self._slots[slot]
        assert req is not None, f"release of a free slot {slot}"
        self._slots[slot] = None
        req.slot = None

    # -- views -----------------------------------------------------------
    @property
    def queued(self) -> int:
        return len(self._queue)

    @property
    def running(self) -> int:
        return sum(r is not None for r in self._slots)

    def running_requests(self) -> Iterator[tuple[int, Request]]:
        for i, r in enumerate(self._slots):
            if r is not None:
                yield i, r

    @property
    def busy(self) -> bool:
        return bool(self._queue) or self.running > 0
