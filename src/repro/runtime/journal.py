"""Crash-consistent write-ahead journal of request lifecycle events.

MemPool's shared L1 is the single structure every PE trusts; our serving
analogue (`ServeSession` + the paged KV pool) concentrates every
in-flight request's state in one process. This module is the durability
half of that trust: an append-only, fsync'd JSONL log of request
lifecycle events (submit / admit / chunk-commit / finish / snapshot /
restore) that a restarted process replays to rebuild a consistent
scheduler state with **exactly-once** token delivery — tokens recorded
by a `commit` event are never re-delivered after a crash; greedy decode
regenerates them bit-identically and the session suppresses the
duplicate prefix (`Request.suppress_until`).

File format (schema-versioned JSONL, one event per line):

    {"version": 1, "kind": "repro-serve-journal"}          <- header
    {"seq": 0, "ev": "submit", "rid": 0, "prompt": [...],
     "max_new": 8, "klass": "throughput", "deadline_s": null}
    {"seq": 1, "ev": "admit", "rid": 0, "slot": 2, "chunk": 1}
    {"seq": 2, "ev": "commit", "rid": 0, "tokens": [5, 9], "chunk": 1}
    {"seq": 3, "ev": "finish", "rid": 0, "status": "done", "reason": null}
    {"seq": 4, "ev": "snapshot", "step": 4}
    {"seq": 5, "ev": "restore", "snapshot_step": 4, "replayed": 3}

Events are appended with a monotonically increasing ``seq`` and flushed
+ fsync'd once per poll (`commit()`), so the on-disk tail is at most one
chunk behind the delivered stream. A process killed mid-write leaves at
worst one torn final line; `read_events` treats a torn/corrupt tail as
the end of the log (the event was never acknowledged) and never raises.
A corrupt or alien header loads as an empty log — cold start, like
`TuneDB`. `compact()` rewrites the file atomically (tmp + `os.replace`)
with the same discipline as `TuneDB.save`.

`replay(events)` is a pure function of the event list — replaying twice
is idempotent by construction, which the property tests assert.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import tempfile
from pathlib import Path
from typing import Iterable

SCHEMA_VERSION = 1
JOURNAL_KIND = "repro-serve-journal"

EVENTS = ("submit", "admit", "commit", "finish", "snapshot", "restore")
FINISH_STATUSES = ("done", "failed", "cancelled")


class Journal:
    """Append-mode handle on a journal file.

    Opening an existing file scans it once to recover the next ``seq``
    (tolerating a torn tail); opening a fresh path writes the header.
    `append` buffers, `commit` flushes + fsyncs — callers batch all of a
    poll's events into one fsync.

    ``fsync`` picks the durability/throughput point: ``True`` fsyncs
    every commit (power-fail durable — the default), ``False`` only
    flushes to the OS (durable against process death: a SIGKILL'd
    process loses nothing the page cache holds, only a kernel crash or
    power cut can), and an int ``K`` group-commits — flush every
    commit, fsync every Kth (Redis ``appendfsync``-style: the power-
    loss window is bounded by K polls, process-crash consistency is
    unchanged).

    ``tag`` (optional) is a dict merged into every appended event — the
    sharded session passes ``{"group": g}`` so each group's journal is
    self-describing (a restore can verify a journal belongs to the group
    directory it sits in). Untagged journals from single-group sessions
    replay identically: the tag is additive, never required.
    """

    def __init__(self, path: str | os.PathLike, *,
                 fsync: bool | int = True,
                 tag: dict | None = None):
        self.path = Path(path)
        self.fsync = fsync
        self.tag = dict(tag or {})
        self.events_written = 0
        self.commits = 0
        self.path.parent.mkdir(parents=True, exist_ok=True)
        valid = self.path.exists() and _header_ok(self.path)
        events = read_events(self.path) if valid else []
        self.seq = (events[-1]["seq"] + 1) if events else 0
        # corrupt/alien header: cold start (truncate), like TuneDB
        self._f = open(self.path, "a" if valid else "w", encoding="utf-8")
        if not valid:
            self._f.write(json.dumps(
                {"version": SCHEMA_VERSION, "kind": JOURNAL_KIND}) + "\n")
            self.commit()

    def append(self, ev: dict) -> int:
        """Buffer one event; returns its assigned seq. Not durable until
        the next `commit()`."""
        if ev.get("ev") not in EVENTS:
            raise ValueError(f"unknown journal event {ev.get('ev')!r}; "
                             f"expected one of {EVENTS}")
        seq = self.seq
        self._f.write(json.dumps({"seq": seq, **self.tag, **ev}) + "\n")
        self.seq += 1
        self.events_written += 1
        return seq

    def flush(self) -> None:
        """Push buffered events to the OS without fsync and without
        advancing the commit counter — durable against process death
        (page cache survives a SIGKILL), not against power loss. The
        next `commit()` covers these events with its fsync policy."""
        self._f.flush()

    def commit(self, *, force: bool = False) -> None:
        """Flush buffered events to the OS; fsync per the journal's
        `fsync` mode (`force=True` always syncs — graceful close)."""
        self._f.flush()
        self.commits += 1
        if self.fsync is True or force and self.fsync:
            os.fsync(self._f.fileno())
        elif (isinstance(self.fsync, int) and self.fsync > 0
                and self.commits % self.fsync == 0):
            os.fsync(self._f.fileno())

    @property
    def bytes_written(self) -> int:
        return self._f.tell()

    def close(self) -> None:
        with contextlib.suppress(ValueError, OSError):
            self.commit(force=True)
        self._f.close()

    def compact(self, events: Iterable[dict]) -> None:
        """Atomically rewrite the journal with `events` (tmp + rename),
        e.g. after a snapshot makes the prefix redundant."""
        self.close()
        fd, tmp = tempfile.mkstemp(dir=self.path.parent,
                                   prefix=self.path.name + ".")
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                f.write(json.dumps({"version": SCHEMA_VERSION,
                                    "kind": JOURNAL_KIND}) + "\n")
                for ev in events:
                    f.write(json.dumps(ev) + "\n")
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.path)
        except BaseException:
            with contextlib.suppress(OSError):
                os.unlink(tmp)
            raise
        self._f = open(self.path, "a", encoding="utf-8")


def _header_ok(path: Path) -> bool:
    try:
        with open(path, encoding="utf-8") as f:
            header = json.loads(f.readline())
    except (OSError, json.JSONDecodeError):
        return False
    return (isinstance(header, dict)
            and header.get("version") == SCHEMA_VERSION
            and header.get("kind") == JOURNAL_KIND)


def read_events(path: str | os.PathLike) -> list[dict]:
    """Read every durable event from a journal file.

    Tolerant by design: a missing file, a corrupt/alien header, or a
    torn final line (process killed mid-write) never raises. A torn or
    corrupt line *ends* the read — everything after an unacknowledged
    write is garbage by definition.
    """
    p = Path(path)
    if not p.exists():
        return []
    try:
        raw = p.read_text(encoding="utf-8")
    except OSError:
        return []
    lines = raw.split("\n")
    if not lines:
        return []
    try:
        header = json.loads(lines[0])
        if (header.get("version") != SCHEMA_VERSION
                or header.get("kind") != JOURNAL_KIND):
            return []
    except (json.JSONDecodeError, AttributeError):
        return []
    out: list[dict] = []
    expect: int | None = None           # a compacted log may start past 0
    for line in lines[1:]:
        if not line.strip():
            continue
        try:
            ev = json.loads(line)
        except json.JSONDecodeError:
            break                       # torn tail: end of the durable log
        if not isinstance(ev, dict) or not isinstance(ev.get("seq"), int):
            break
        if expect is not None and ev["seq"] != expect:
            break                       # out-of-sequence: end of the log
        out.append(ev)
        expect = ev["seq"] + 1
    return out


@dataclasses.dataclass
class ReplayedRequest:
    """Everything the journal knows about one request."""
    rid: int
    prompt: list[int] | None = None
    max_new: int = 0
    klass: str = "throughput"
    deadline_s: float | None = None
    committed: list[int] = dataclasses.field(default_factory=list)
    status: str | None = None           # None = in flight at the crash
    reason: str | None = None
    submit_seq: int | None = None
    admit_seq: int | None = None        # last admit (re-admits overwrite)
    finish_seq: int | None = None
    slot: int | None = None
    group: int | None = None            # serving group (sharded sessions;
    #   None on untagged single-group journals)


@dataclasses.dataclass
class ReplaySummary:
    """Pure fold of a journal's event stream."""
    requests: dict[int, ReplayedRequest] = dataclasses.field(
        default_factory=dict)
    snapshots: list[tuple[int, int]] = dataclasses.field(
        default_factory=list)       # (seq, step)
    restores: int = 0
    last_seq: int = -1

    def committed_counts(self) -> dict[int, int]:
        return {rid: len(r.committed) for rid, r in self.requests.items()}


def replay(events: Iterable[dict]) -> ReplaySummary:
    """Fold an event stream into per-request committed outputs and
    terminal statuses. Pure and deterministic: replay(replay-input) of
    the same list always yields the same summary (idempotence is tested
    property-style)."""
    s = ReplaySummary()
    for ev in events:
        seq = int(ev.get("seq", -1))
        s.last_seq = max(s.last_seq, seq)
        kind = ev.get("ev")
        if kind == "snapshot":
            s.snapshots.append((seq, int(ev["step"])))
            continue
        if kind == "restore":
            s.restores += 1
            continue
        rid = int(ev["rid"])
        r = s.requests.setdefault(rid, ReplayedRequest(rid=rid))
        if "group" in ev:
            r.group = int(ev["group"])
        if kind == "submit":
            r.prompt = [int(t) for t in ev["prompt"]]
            r.max_new = int(ev["max_new"])
            r.klass = str(ev.get("klass", "throughput"))
            r.deadline_s = ev.get("deadline_s")
            r.submit_seq = seq
        elif kind == "admit":
            r.admit_seq = seq
            r.slot = int(ev["slot"])
        elif kind == "commit":
            r.committed.extend(int(t) for t in ev["tokens"])
        elif kind == "finish":
            status = str(ev["status"])
            if status not in FINISH_STATUSES:
                raise ValueError(f"unknown finish status {status!r}")
            r.status = status
            r.reason = ev.get("reason")
            r.finish_seq = seq
    return s
