"""Hierarchical machine topology — the TPU analogue of MemPool's tile/group/cluster.

MemPool (paper Fig. 1)           This module (TPU v5e pod)
---------------------------      -------------------------------------------
tile   : 4 cores + 16 banks,     chip  : MXU+VPU + 16 GiB HBM   (level 0,
         1-cycle local xbar               zero-collective "local" accesses)
group  : 16 tiles, 3-cycle       group : 16-chip ICI mesh axis  (level 1,
         local crossbar                   1-hop neighbor links)
cluster: 4 groups, 5-cycle       pod   : 16x16 2-D ICI torus    (level 2,
         remote crossbars                 <= diameter-hop paths)
multi-cluster over L2/AXI        multi-pod over DCN             (level 3)

The latency/bandwidth numbers drive the sharding planner (core/addressing.py)
and the collective cost model (core/interconnect.py), the same way the paper's
1/3/5-cycle levels drive its hybrid addressing scheme.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax

# ----------------------------------------------------------------------------
# Hardware constants (TPU v5e target, per task spec)
# ----------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12          # FLOP/s per chip
HBM_BW = 819e9                    # B/s per chip
ICI_BW_PER_LINK = 50e9            # B/s per ICI link (one direction)
DCN_BW_PER_HOST = 25e9            # B/s per host across pods (assumed)
HBM_BYTES = 16 * 1024**3          # 16 GiB HBM per chip
VMEM_BYTES = 128 * 1024**2        # ~128 MiB VMEM per chip (v5e ~ 128MB)
MXU_TILE = 128                    # systolic array edge; align matmul dims to this
VPU_LANE = 8 * 128                # (8, 128) vector registers

# MemPool reference constants (used by benchmarks reproducing paper figures)
MEMPOOL = dict(
    n_cores=256, n_banks=1024, l1_bytes=1 << 20, banking_factor=4,
    local_latency=1, group_latency=3, remote_latency=5,
    freq_hz=600e6, peak_ops=256,  # 1 op/core/cycle (MAC counts 2 in paper's GOPS)
)


@dataclasses.dataclass(frozen=True)
class Level:
    """One level of the machine hierarchy (tile/group/cluster/pod analogue)."""
    name: str
    fanout: int          # number of children units at this level
    latency_s: float     # one-way latency to cross this level
    bw_bytes: float      # per-chip bandwidth available at this level


@dataclasses.dataclass(frozen=True)
class Topology:
    """Hierarchical topology with per-level latency/bandwidth.

    `levels[0]` is the chip itself (HBM); higher indices are progressively
    remote — exactly the paper's tile < group < cluster ordering.
    """
    levels: tuple[Level, ...]
    mesh_shape: tuple[int, ...]
    axis_names: tuple[str, ...]

    @property
    def n_chips(self) -> int:
        return math.prod(self.mesh_shape)

    def level(self, name: str) -> Level:
        for lvl in self.levels:
            if lvl.name == name:
                return lvl
        raise KeyError(name)

    def axis_size(self, axis: str) -> int:
        return self.mesh_shape[self.axis_names.index(axis)]

    def bisection_bw(self, axis: str) -> float:
        """Aggregate bandwidth across the bisection of one mesh axis (B/s)."""
        n = self.axis_size(axis)
        other = self.n_chips // n
        # 2-D torus: each row/col contributes 2 wraparound links per cut.
        links = 2 * other
        return links * ICI_BW_PER_LINK

    def ring_allgather_time(self, axis: str, bytes_per_chip: float) -> float:
        """Ring all-gather of `bytes_per_chip` over one axis (α–β model)."""
        n = self.axis_size(axis)
        if n <= 1:
            return 0.0
        lvl = self._axis_level(axis)
        steps = n - 1
        return steps * (lvl.latency_s + bytes_per_chip / lvl.bw_bytes)

    def _axis_level(self, axis: str) -> Level:
        if axis == "pod":
            return self.level("dcn")
        return self.level("ici")


def v5e_topology(mesh_shape: Sequence[int], axis_names: Sequence[str]) -> Topology:
    """Standard v5e hierarchy for the production meshes used in this repo."""
    levels = (
        Level("hbm", 1, 1e-7, HBM_BW),
        Level("ici", 16, 1e-6, 2 * ICI_BW_PER_LINK),   # 2 links per axis direction
        Level("dcn", 2, 1e-5, DCN_BW_PER_HOST),
    )
    return Topology(levels=levels, mesh_shape=tuple(mesh_shape),
                    axis_names=tuple(axis_names))


# ----------------------------------------------------------------------------
# Mesh construction
# ----------------------------------------------------------------------------

def make_mesh(shape: Sequence[int], axis_names: Sequence[str]) -> jax.sharding.Mesh:
    """Build a jax Mesh, tolerating CPU hosts with fewer devices than requested.

    For single-device smoke runs, the caller should pass a shape matching the
    available device count; the production 16x16 / 2x16x16 meshes are built
    by launch/mesh.py under XLA_FLAGS=--xla_force_host_platform_device_count.
    """
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise ValueError(
            f"mesh shape {tuple(shape)} needs {n} devices, but only "
            f"{len(devices)} are visible. Set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} before importing jax "
            f"(see launch/dryrun.py).")
    return jax.make_mesh(tuple(shape), tuple(axis_names))


def smoke_mesh(axis_names: Sequence[str] = ("data", "model")) -> jax.sharding.Mesh:
    """1-chip (or few-chip) mesh for CPU smoke tests — every axis size 1."""
    return jax.make_mesh((1,) * len(axis_names), tuple(axis_names))
