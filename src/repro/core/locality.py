"""Locality analysis of compiled steps — the p_local measurement on TPU.

MemPool evaluates its hybrid addressing by the fraction of requests served by
the local tile (Fig. 5). The GSPMD analogue: of all bytes a step touches, how
many cross the interconnect as collectives? This module parses HLO text
(`compiled.as_text()`) and accounts for every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, giving the §Roofline
collective term and the framework's p_local metric.

Note on accounting: optimized HLO prints operands *without* inline types
(`all-reduce(%fusion.3)`), so operand sizes are derived from the printed
result type + the collective's algebra:

    all-gather      result = operand * g      -> operand = result / g
    all-reduce      result = operand          -> operand = result
    reduce-scatter  result = operand / g      -> operand = result * g
    all-to-all      result = operand          -> operand = result
    collective-permute                          operand = result

`operand_bytes` is the task-literal "sum of operand sizes"; `wire_bytes` is
the ring-algorithm-aware per-chip traffic used for the p_local metric.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

# an HLO instruction line:  %name = TYPE opcode(OPERANDS), attrs...
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<rtype>\([^)]*\)|\S+(?:\{[\d,]*\})?)\s+"
    r"(?P<op>all-gather(?:-start)?|all-reduce(?:-start)?|reduce-scatter"
    r"|all-to-all|ragged-all-to-all|collective-permute(?:-start)?|collective-broadcast)"
    r"\(")

_SHAPE_RE = re.compile(r"(?P<dt>(?:pred|[a-z]\d+[a-z0-9]*))\[(?P<dims>[\d,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dt: str, dims: str) -> float:
    if dt not in _DTYPE_BYTES:
        return 0.0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return float(n) * _DTYPE_BYTES[dt]


def _result_bytes(rtype: str, op: str) -> float:
    """Bytes of the collective's *result*, from the printed result type.

    For `-start` ops the result is a tuple carrying (operand(s), result(s));
    we take the larger half for AG (full side) and half the total for AR/CP
    (both sides equal).
    """
    sizes = [_shape_bytes(m.group("dt"), m.group("dims"))
             for m in _SHAPE_RE.finditer(rtype)]
    if not sizes:
        return 0.0
    if op.endswith("-start") and len(sizes) > 1:
        if op.startswith("all-gather"):
            return max(sizes)           # the gathered full buffer
        return sum(sizes) / 2.0         # (operand, result) of equal size
    return float(sum(sizes))


def _group_size(attrs: str) -> int:
    m = _GROUPS_LIST_RE.search(attrs)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        return int(m.group(2))   # iota form: [num_groups, group_size]<=[total]
    return 1


@dataclasses.dataclass
class CollectiveStats:
    count: int = 0
    operand_bytes: float = 0.0   # task-literal: sum of operand sizes
    wire_bytes: float = 0.0      # ring-algorithm per-chip bytes on the wire


@dataclasses.dataclass
class LocalityReport:
    by_kind: dict[str, CollectiveStats]

    @property
    def operand_bytes(self) -> float:
        return sum(s.operand_bytes for s in self.by_kind.values())

    @property
    def wire_bytes(self) -> float:
        return sum(s.wire_bytes for s in self.by_kind.values())

    @property
    def count(self) -> int:
        return sum(s.count for s in self.by_kind.values())

    def p_local(self, total_bytes_accessed: float) -> float:
        """Fraction of touched bytes served without crossing the interconnect."""
        if total_bytes_accessed <= 0:
            return 1.0
        return max(0.0, 1.0 - self.wire_bytes / total_bytes_accessed)

    def as_dict(self) -> dict:
        return {k: dataclasses.asdict(v) for k, v in sorted(self.by_kind.items())
                } | {"total_operand_bytes": self.operand_bytes,
                     "total_wire_bytes": self.wire_bytes,
                     "total_count": self.count}


def _op_bytes(kind: str, result_bytes: float, g: int) -> tuple[float, float]:
    """(operand_bytes, wire_bytes_per_chip) from result bytes + group size."""
    g = max(g, 1)
    if kind == "all-gather":
        operand = result_bytes / g
        wire = operand * (g - 1)
    elif kind == "all-reduce":
        operand = result_bytes
        wire = operand * 2.0 * (g - 1) / g
    elif kind == "reduce-scatter":
        operand = result_bytes * g
        wire = operand * (g - 1) / g / g * g  # = result*(g-1): ring RS moves
        wire = result_bytes * (g - 1)
    elif kind in ("all-to-all", "ragged-all-to-all"):
        operand = result_bytes
        wire = operand * (g - 1) / g
    else:  # collective-permute, collective-broadcast
        operand = result_bytes
        wire = operand
    return operand, wire


def analyze_hlo(hlo_text: str) -> LocalityReport:
    by_kind: dict[str, CollectiveStats] = defaultdict(CollectiveStats)
    for line in hlo_text.splitlines():
        if ("all-" not in line and "reduce-scatter" not in line
                and "collective-" not in line):
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        op = m.group("op")
        kind = op.removesuffix("-start")
        rb = _result_bytes(m.group("rtype"), op)
        g = _group_size(line)
        operand, wire = _op_bytes(kind, rb, g)
        st = by_kind[kind]
        st.count += 1
        st.operand_bytes += operand
        st.wire_bytes += wire
    return LocalityReport(by_kind=dict(by_kind))


# ----------------------------------------------------------------------------
# cost_analysis / memory_analysis helpers
# ----------------------------------------------------------------------------

def extract_costs(compiled) -> dict[str, float]:
    """Pull flops / bytes-accessed out of compiled.cost_analysis() robustly."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    out = {}
    for k in ("flops", "bytes accessed", "transcendentals"):
        v = ca.get(k)
        if v is not None and not (isinstance(v, float) and math.isnan(v)):
            out[k.replace(" ", "_")] = float(v)
    return out


def extract_memory(compiled) -> dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    return out


def peak_device_bytes(mem: dict[str, float]) -> float:
    """Upper-bound live bytes per device during execution."""
    return (mem.get("argument_size_in_bytes", 0.0)
            + mem.get("output_size_in_bytes", 0.0)
            + mem.get("temp_size_in_bytes", 0.0)
            - mem.get("alias_size_in_bytes", 0.0))
