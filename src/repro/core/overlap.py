"""Compute/communication overlap helpers — the Snitch latency-tolerance analogue.

Snitch hides MemPool's 5-cycle L1 latency with 8 outstanding loads plus
compiler scheduling. The GSPMD analogue is (a) scanning over layers so the
all-gather of layer k+1's weights overlaps layer k's compute (XLA's latency
hiding scheduler does the motion once the collectives are exposed), and
(b) structuring the step so the gradient reduce-scatter of layer k overlaps
the backward compute of layer k-1.

These helpers keep that structure explicit and testable in the model code.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def overlap_report(produce_s: float, consumer_wait_s: float) -> dict:
    """Transfer-vs-compute overlap ledger (paper Fig. 15 steady state).

    `produce_s`: total producer/DMA busy seconds; `consumer_wait_s`: total
    seconds the consumer blocked waiting on the feed. The difference is the
    transfer time that rode under compute; `overlap_pct` is the fraction of
    transfer hidden (100% = fully double-buffered, 0% = serial).
    """
    hidden = max(produce_s - consumer_wait_s, 0.0)
    return {
        "produce_s": produce_s,
        "consumer_wait_s": consumer_wait_s,
        "hidden_s": hidden,
        "overlap_pct": 100.0 * hidden / produce_s if produce_s > 0 else 0.0,
    }


def with_sharding(x, spec: P):
    """Annotate intermediate sharding (no-op under a trivial mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def _batch_axes() -> tuple[str, ...] | None:
    """Batch mesh axes visible in the current mesh context, if any."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return None
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        return axes or None
    except Exception:
        return None


def shard_batch(x, dim: int = 0):
    """Constrain dim `dim` of x to the batch axes, leaving others free.

    Scan/while initial carries built with jnp.zeros have no sharding of
    their own; without this hint GSPMD may choose *replicated* layouts for
    the entire loop state (including stacked residuals), silently multiplying
    the memory footprint by the data-axis size. This is the moral opposite
    of MemPool's sequential region — private data must stay in its tile.
    """
    axes = _batch_axes()
    if axes is None:
        return x
    if x.shape[dim] % max(
            1, _axes_size(axes)):
        return x
    U = P.UNCONSTRAINED
    spec = [U] * x.ndim
    spec[dim] = axes if len(axes) > 1 else axes[0]
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def _axes_size(axes: tuple[str, ...]) -> int:
    mesh = jax.sharding.get_abstract_mesh()
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def shard_batch_tree(tree, dim: int = 0):
    return jax.tree.map(lambda x: shard_batch(x, dim) if hasattr(x, "ndim")
                        and x.ndim > dim else x, tree)


def prefetchable_scan(body: Callable, carry, xs, *, unroll: int = 1,
                      remat_policy: str | None = "dots") -> Any:
    """`lax.scan` over stacked layer weights with a remat policy.

    The scan keeps the HLO compact (one layer body, trip-counted loop), which
    is what lets the 512-chip dry-run compile in reasonable time, and exposes
    the per-iteration weight all-gather for the scheduler to prefetch — the
    framework's "outstanding load".
    """
    policy = _policy(remat_policy)
    fn = jax.checkpoint(body, policy=policy) if policy is not None else body
    return jax.lax.scan(fn, carry, xs, unroll=unroll)


def _policy(name: str | None):
    cp = jax.checkpoint_policies
    if name is None or name == "none":
        return None
    if name == "dots":
        return cp.checkpoint_dots
    if name == "dots_no_batch":
        return cp.checkpoint_dots_with_no_batch_dims
    if name == "nothing":
        return cp.nothing_saveable
    if name == "everything":
        return cp.everything_saveable
    raise ValueError(f"unknown remat policy {name!r}")
