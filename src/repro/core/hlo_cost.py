"""Loop-aware HLO cost analyzer — exact roofline terms from compiled text.

Why this exists: `compiled.cost_analysis()` visits a `while` body ONCE, so a
scanned-layer program (the only way to compile 512-chip programs of 60-100
layer models in reasonable time) under-reports FLOPs/bytes by ~L x. XLA's
compiled text carries `backend_config={"known_trip_count":{"n":...}}` on every
canonicalized while loop, so an instruction-level walk can weight each loop
body by its true trip count, recursively (nested scans: layers x attention
chunks x grad-accumulation microbatches).

Accounting rules:
  flops      — dot: 2 * prod(result) * prod(lhs contracting dims);
               elementwise/compare/select: prod(result); reduce: prod(operand).
  bytes      — operands + results at *fusion boundaries* only (fusion
               internals stay on-chip, the TPU VMEM model); control ops
               (tuple/GTE/parameter/bitcast/constant) are free.
  collectives— per-kind operand/wire bytes (same algebra as core.locality),
               weighted by enclosing trip counts.

Everything is derived from `compiled.as_text()` — the dry-run's "profile".
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

from .locality import _DTYPE_BYTES, _group_size, _op_bytes

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(?P<rtype>\([^)]*\)|\S+)\s+"
    r"(?P<op>[\w\-]+)\((?P<operands>.*)$")
_SHAPE = re.compile(r"(?:pred|[a-z]\d+[a-z0-9]*)\[[\d,]*\]")
_SHAPE_PARSE = re.compile(r"(?P<dt>pred|[a-z]\d+[a-z0-9]*)\[(?P<dims>[\d,]*)\]")
_TRIP = re.compile(r'known_trip_count\\?"?:\{\\?"?n\\?"?:\\?"?(\d+)')
_CALLS = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_NAME = re.compile(r"%([\w.\-]+)")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "and", "or", "xor", "not", "compare", "select", "clamp", "abs", "negate",
    "sign", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "remainder", "atan2", "shift-left", "shift-right-arithmetic",
    "shift-right-logical",
}
TRANSCENDENTAL = {"exponential", "exp", "log", "log-plus-one", "logistic",
                  "tanh", "sqrt", "rsqrt", "cbrt", "sine", "cosine", "tan",
                  "expm1", "erf"}
FREE = {"tuple", "get-tuple-element", "parameter", "bitcast", "constant",
        "after-all", "opt-barrier", "partition-id", "replica-id", "domain",
        "bitcast-convert"}
COLLECTIVES = {"all-gather", "all-gather-start", "all-reduce",
               "all-reduce-start", "reduce-scatter", "all-to-all",
               "ragged-all-to-all", "collective-permute",
               "collective-permute-start", "collective-broadcast"}
NO_BYTES = FREE | {"all-gather-done", "all-reduce-done",
                   "collective-permute-done", "copy-done", "copy-start"}


def _parse_shapes(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for m in _SHAPE_PARSE.finditer(type_str):
        dims = tuple(int(d) for d in m.group("dims").split(",")) \
            if m.group("dims").strip() else ()
        out.append((m.group("dt"), dims))
    return out


def _nbytes(shapes) -> float:
    total = 0.0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 0)
    return total


def _nelems(shapes) -> float:
    total = 0.0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    rtype: str
    rest: str      # operand list + attrs (raw tail of the line)

    @property
    def result_shapes(self):
        return _parse_shapes(self.rtype)


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes: float = 0.0
    coll_operand_bytes: float = 0.0
    coll_wire_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(
        default_factory=lambda: defaultdict(lambda: [0, 0.0, 0.0]))

    def scaled_add(self, other: "Costs", k: float):
        self.flops += other.flops * k
        self.transcendentals += other.transcendentals * k
        self.bytes += other.bytes * k
        self.coll_operand_bytes += other.coll_operand_bytes * k
        self.coll_wire_bytes += other.coll_wire_bytes * k
        for kind, (c, ob, wb) in other.coll_by_kind.items():
            e = self.coll_by_kind[kind]
            e[0] += c * k
            e[1] += ob * k
            e[2] += wb * k

    def as_dict(self) -> dict:
        return {"flops": self.flops, "transcendentals": self.transcendentals,
                "bytes": self.bytes,
                "collective_operand_bytes": self.coll_operand_bytes,
                "collective_wire_bytes": self.coll_wire_bytes,
                "collectives": {k: {"count": v[0], "operand_bytes": v[1],
                                    "wire_bytes": v[2]}
                                for k, v in sorted(self.coll_by_kind.items())}}


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: dict[str, list[Instr]] = {}
        self.entry: str | None = None
        self._parse(hlo_text)
        self._memo: dict[str, Costs] = {}

    def _parse(self, text: str):
        cur: list[Instr] | None = None
        for line in text.splitlines():
            hdr = _COMP_HDR.match(line.strip())
            if hdr and ("->" in line):
                name = hdr.group(1)
                cur = []
                self.computations[name] = cur
                if line.strip().startswith("ENTRY"):
                    self.entry = name
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _INSTR.match(line)
            if m:
                cur.append(Instr(m.group(1), m.group("op"), m.group("rtype"),
                                 m.group("operands")))

    # -- helpers ------------------------------------------------------------
    def _shape_table(self, instrs) -> dict[str, list]:
        return {i.name: i.result_shapes for i in instrs}

    def _operand_names(self, instr: Instr) -> list[str]:
        # operand names appear before attrs; attrs also contain %computation
        # references, so cut at the closing paren of the operand list.
        depth, end = 1, len(instr.rest)
        for idx, ch in enumerate(instr.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = idx
                    break
        return _OPERAND_NAME.findall(instr.rest[:end])

    def _operand_shapes(self, instr: Instr, table) -> list:
        shapes = []
        for n in self._operand_names(instr):
            shapes.extend(table.get(n, []))
        return shapes

    def _operands_split(self, instr: Instr, table) -> list[list]:
        return [table.get(n, []) for n in self._operand_names(instr)]

    def _is_inplace_update_fusion(self, comp_name: str) -> bool:
        """Fusion whose root is a dynamic-update-slice (in-place write)."""
        for ins in self.computations.get(comp_name, []):
            if ins.op == "dynamic-update-slice":
                return True
        return False

    def _fusion_bytes(self, ins: Instr, called: str | None, table) -> float:
        """Boundary bytes of a fusion with slice-aware semantics.

        XLA fuses `dynamic-slice(stacked_buffer)` into consumers and
        `dynamic-update-slice` into producers; the buffer then appears as a
        full-sized operand/result of the fusion even though only one slice
        is touched per call. We map fusion operands to the fused
        computation's parameters: a param consumed only by dynamic-slice
        ops is charged its slice bytes; the aliased DUS target is charged
        the update bytes. Everything else is charged in full.
        """
        rshapes = ins.result_shapes
        operand_names = self._operand_names(ins)
        if called not in self.computations:
            return _nbytes(rshapes) + sum(
                _nbytes(table.get(n, [])) for n in operand_names)
        comp = self.computations[called]
        ctable = self._shape_table(comp)
        # ops that do not force a boundary materialization of their own:
        # convert included — the convert(DUS(convert(x),u)) residual-save
        # pattern is emitted in place on TPU.
        TRANSPARENT = {"bitcast", "reshape", "transpose", "copy", "convert"}
        # parameter index -> internal name
        param_name: dict[int, str] = {}
        for c in comp:
            if c.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", "parameter(" + c.rest)
                if m:
                    param_name[int(m.group(1))] = c.name
        # usage map: internal name -> consuming instrs
        uses: dict[str, list[Instr]] = defaultdict(list)
        by_name = {c.name: c for c in comp}
        for c in comp:
            for n in self._operand_names(c):
                uses[n].append(c)

        def resolve_root(name: str) -> str:
            """Follow bitcast-like chains back to their source name."""
            seen = 0
            while name in by_name and by_name[name].op in TRANSPARENT and \
                    seen < 16:
                ops = self._operand_names(by_name[name])
                if not ops:
                    break
                name = ops[0]
                seen += 1
            return name

        def sliced_reads(name: str, depth: int = 0) -> float | None:
            """If `name` is consumed only via (transparent ->) dynamic-slice,
            return the total sliced bytes read; else None."""
            if depth > 16:
                return None
            total = 0.0
            consumers = uses.get(name, [])
            if not consumers:
                return 0.0
            for c in consumers:
                if c.op == "dynamic-slice":
                    total += _nbytes(c.result_shapes)
                elif c.op in TRANSPARENT:
                    sub = sliced_reads(c.name, depth + 1)
                    if sub is None:
                        return None
                    total += sub
                else:
                    return None
            return total

        dus_targets: set[str] = set()
        dus_update_bytes = 0.0
        for c in comp:
            if c.op == "dynamic-update-slice":
                ops = self._operand_names(c)
                if ops:
                    dus_targets.add(resolve_root(ops[0]))
                if len(ops) > 1:
                    dus_update_bytes += _nbytes(ctable.get(ops[1], []))
        total = 0.0
        for idx, opname in enumerate(operand_names):
            pname = param_name.get(idx)
            full = _nbytes(table.get(opname, []))
            if pname is None:
                total += full
                continue
            if pname in dus_targets:
                continue                      # aliased in place
            sliced = sliced_reads(pname)
            total += full if sliced is None else sliced
        if dus_update_bytes:
            total += dus_update_bytes          # the written slice
        else:
            total += _nbytes(rshapes)
        return total

    def _trip_count(self, instr: Instr) -> float:
        m = _TRIP.search(instr.rest)
        if m:
            return float(m.group(1))
        # fallback: largest integer constant in the condition computation
        c = _COND.search(instr.rest)
        if c and c.group(1) in self.computations:
            consts = [float(x) for i in self.computations[c.group(1)]
                      if i.op == "constant"
                      for x in re.findall(r"constant\((\d+)\)", "constant(" + i.rest)]
            if consts:
                return max(consts)
        return 1.0

    # -- main recursion -----------------------------------------------------
    def computation_costs(self, name: str, *, fused: bool = False) -> Costs:
        key = f"{name}|{fused}"
        if key in self._memo:
            return self._memo[key]
        costs = Costs()
        instrs = self.computations.get(name, [])
        table = self._shape_table(instrs)
        for ins in instrs:
            op = ins.op
            rshapes = ins.result_shapes
            relems = _nelems(rshapes)
            if op == "while":
                trips = self._trip_count(ins)
                body = _CALLS.search(ins.rest)
                if body and body.group(1) in self.computations:
                    costs.scaled_add(
                        self.computation_costs(body.group(1)), trips)
                # loop state stays in place (XLA keeps the tuple buffers
                # alive across iterations); per-iteration IO is already
                # accounted by the body's dynamic-(update-)slice ops.
                continue
            if op == "fusion":
                calls = _CALLS.search(ins.rest)
                called = calls.group(1) if calls else None
                if called in self.computations:
                    sub = self.computation_costs(called, fused=True)
                    c = Costs()
                    c.flops, c.transcendentals = sub.flops, sub.transcendentals
                    costs.scaled_add(c, 1.0)
                costs.bytes += self._fusion_bytes(ins, called, table)
                continue
            if op in ("call", "custom-call", "conditional", "sort", "map",
                      "reduce", "reduce-window", "scatter",
                      "select-and-scatter"):
                calls = _CALLS.search(ins.rest)
                if calls and calls.group(1) in self.computations:
                    sub = self.computation_costs(calls.group(1), fused=True)
                    mult = 1.0
                    if op in ("reduce", "map"):
                        mult = _nelems(self._operand_shapes(ins, table)) / 2
                    elif op in ("reduce-window", "scatter",
                                "select-and-scatter", "sort"):
                        mult = relems
                    c = Costs()
                    c.flops, c.transcendentals = sub.flops, sub.transcendentals
                    costs.scaled_add(c, max(mult, 1.0))
                if not fused:
                    costs.bytes += _nbytes(rshapes) + _nbytes(
                        self._operand_shapes(ins, table))
                continue
            if op == "dynamic-update-slice":
                # in-place: read+write of the updated slice only
                per_op = self._operands_split(ins, table)
                upd = per_op[1] if len(per_op) > 1 else []
                costs.bytes += 2.0 * _nbytes(upd)
                continue
            if op in ("dynamic-slice", "slice", "gather", "copy",
                      "transpose", "reshape", "reverse", "broadcast",
                      "concatenate", "pad"):
                costs.bytes += 2.0 * _nbytes(rshapes)
                continue
            if op in COLLECTIVES:
                kind = op.removesuffix("-start")
                rb = _result_collective_bytes(rshapes, op)
                g = _group_size(ins.rest)
                operand, wire = _op_bytes(kind, rb, g)
                costs.coll_operand_bytes += operand
                costs.coll_wire_bytes += wire
                e = costs.coll_by_kind[kind]
                e[0] += 1
                e[1] += operand
                e[2] += wire
                costs.bytes += _nbytes(rshapes)
                continue
            if op == "dot":
                k = 1.0
                cd = _CDIMS.search(ins.rest)
                # lhs is the first operand
                names = _OPERAND_NAME.findall(ins.rest)
                lhs = table.get(names[0], []) if names else []
                if cd and lhs:
                    dims = [int(x) for x in cd.group(1).split(",") if x]
                    for d in dims:
                        if d < len(lhs[0][1]):
                            k *= lhs[0][1][d]
                costs.flops += 2.0 * relems * k
                if not fused:
                    costs.bytes += _nbytes(rshapes) + _nbytes(
                        self._operand_shapes(ins, table))
                continue
            if op == "convolution":
                # rough: 2 * result * (operand1 elems / output-feature dim)
                names = _OPERAND_NAME.findall(ins.rest)
                ker = table.get(names[1], []) if len(names) > 1 else []
                kelems = _nelems(ker) if ker else 1.0
                costs.flops += 2.0 * relems * max(kelems / max(relems, 1), 1)
                if not fused:
                    costs.bytes += _nbytes(rshapes) + _nbytes(
                        self._operand_shapes(ins, table))
                continue
            if op in ELEMENTWISE:
                costs.flops += relems
            elif op in TRANSCENDENTAL:
                costs.flops += relems
                costs.transcendentals += relems
            elif op == "iota" or op == "rng" or op == "rng-bit-generator":
                pass
            if op in FREE:
                continue
            if not fused and op not in NO_BYTES:
                costs.bytes += _nbytes(rshapes) + _nbytes(
                    self._operand_shapes(ins, table))
        self._memo[key] = costs
        return costs

    def entry_costs(self) -> Costs:
        assert self.entry, "no ENTRY computation found"
        return self.computation_costs(self.entry)


def _result_collective_bytes(rshapes, op: str) -> float:
    sizes = []
    for dt, dims in rshapes:
        n = 1
        for d in dims:
            n *= d
        sizes.append(n * _DTYPE_BYTES.get(dt, 0))
    if not sizes:
        return 0.0
    if op.endswith("-start") and len(sizes) > 1:
        if op.startswith("all-gather"):
            return max(sizes)
        return sum(sizes) / 2.0
    return float(sum(sizes))


def analyze(hlo_text: str) -> dict:
    """Entry point: loop-aware flops/bytes/collective accounting."""
    return HloCostModel(hlo_text).entry_costs().as_dict()


def while_report(hlo_text: str) -> list[dict]:
    """Debug view: every while loop with its trip count and weighted cost."""
    model = HloCostModel(hlo_text)
    out = []
    for cname, instrs in model.computations.items():
        for ins in instrs:
            if ins.op != "while":
                continue
            body = _CALLS.search(ins.rest)
            bname = body.group(1) if body else "?"
            trips = model._trip_count(ins)
            costs = (model.computation_costs(bname)
                     if bname in model.computations else Costs())
            out.append({"in": cname, "body": bname, "trips": trips,
                        "body_flops": costs.flops, "body_bytes": costs.bytes,
                        "total_flops": costs.flops * trips,
                        "total_bytes": costs.bytes * trips})
    return sorted(out, key=lambda d: -d["total_bytes"])
