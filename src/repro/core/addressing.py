"""Hybrid addressing scheme — MemPool §3.2 — as a sharding planner.

Two layers live here:

1. The *faithful* artifact: MemPool's address scrambler (paper Fig. 3), a
   bijective bit permutation that carves per-tile *sequential regions* out of
   a word-interleaved memory map. We implement it exactly (and property-test
   that it is a bijection and that sequential addresses stay within one tile).
   It is used by the Fig.-4/5 benchmarks and documents the technique.

2. The *TPU adaptation*: a Region-policy sharding planner. Every tensor in a
   step is assigned a `Region`:

     SEQUENTIAL  — private data (activations, optimizer shards, KV caches):
                   placed so its owner chip holds it wholly locally; access
                   costs zero collective bytes (the paper's local-tile hit).
     INTERLEAVED — shared data (weights): spread over the whole machine
                   (FSDP x TP); access is an all-gather = the paper's
                   remote-tile request through Top_H.
     REPLICATED  — small read-only constants (the RO-cache analogue).

   The planner lowers logical-axis annotations to GSPMD PartitionSpecs on the
   hierarchical mesh, checking divisibility and axis-conflicts, which is the
   moral equivalent of the paper's "wire crossing and a multiplexer".
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ----------------------------------------------------------------------------
# 1. Paper-faithful address scrambler (Fig. 3)
# ----------------------------------------------------------------------------

BYTE_BITS = 2  # 32-bit words


@dataclasses.dataclass(frozen=True)
class AddressMap:
    """MemPool L1 address layout: [row | tile(t) | bank(b) | byte(2)].

    `seq_rows_bits` (s) rows of every tile's banks form its sequential region;
    the first 2**(t+s+b+2) bytes of the address space are sequential.
    """
    tile_bits: int = 6     # t: 64 tiles
    bank_bits: int = 4     # b: 16 banks/tile
    seq_rows_bits: int = 4  # s: 2**s rows per bank are sequential

    @property
    def seq_region_bytes(self) -> int:
        return 1 << (self.tile_bits + self.seq_rows_bits + self.bank_bits + BYTE_BITS)

    def scramble(self, addr):
        """Logical (hybrid-map) address -> physical address (Fig. 3).

        The physical routing is hardwired: bits [2, 2+b) select the bank,
        bits [2+b, 2+b+t) the tile. In the hybrid map the programmer's
        sequential region is laid out [.. | tile | row_s | bank | byte]:
        each tile owns 2^(s+b+2) contiguous logical bytes. The scrambler
        (a wire crossing + mux) swaps the (tile, row_s) fields so those
        contiguous addresses land in one physical tile while staying
        bank-interleaved within it. Outside the region: identity.
        """
        addr = np.asarray(addr, dtype=np.int64)
        t, b, s = self.tile_bits, self.bank_bits, self.seq_rows_bits
        lo = b + BYTE_BITS            # first bit above [bank|byte]
        in_seq = addr < self.seq_region_bytes

        keep_low = addr & ((1 << lo) - 1)
        row_f = (addr >> lo) & ((1 << s) - 1)        # logical row-in-tile
        tile_f = (addr >> (lo + s)) & ((1 << t) - 1)  # logical tile chunk
        high = addr >> (lo + t + s)
        phys = (high << (lo + t + s)) | (row_f << (lo + t)) | \
            (tile_f << lo) | keep_low
        return np.where(in_seq, phys, addr)

    def descramble(self, addr):
        """Inverse permutation (physical -> logical)."""
        addr = np.asarray(addr, dtype=np.int64)
        t, b, s = self.tile_bits, self.bank_bits, self.seq_rows_bits
        lo = b + BYTE_BITS
        in_seq = addr < self.seq_region_bytes

        keep_low = addr & ((1 << lo) - 1)
        tile_f = (addr >> lo) & ((1 << t) - 1)
        row_f = (addr >> (lo + t)) & ((1 << s) - 1)
        high = addr >> (lo + t + s)
        logical = (high << (lo + t + s)) | (tile_f << (lo + s)) | \
            (row_f << lo) | keep_low
        return np.where(in_seq, logical, addr)

    def tile_of(self, addr) -> np.ndarray:
        """Physical tile servicing a *physical* (post-scramble) address —
        the hardwired interconnect routing field."""
        addr = np.asarray(addr, dtype=np.int64)
        lo = self.bank_bits + BYTE_BITS
        return (addr >> lo) & ((1 << self.tile_bits) - 1)


# ----------------------------------------------------------------------------
# 2. Region-policy sharding planner (the TPU adaptation)
# ----------------------------------------------------------------------------

class Region(enum.Enum):
    SEQUENTIAL = "sequential"    # private -> owner-local, collective-free
    INTERLEAVED = "interleaved"  # shared  -> spread machine-wide (FSDP x TP)
    REPLICATED = "replicated"    # RO consts -> every chip has a copy


@dataclasses.dataclass
class AxisRules:
    """Logical-axis -> mesh-axes mapping, parameterized by region policy.

    `rules` maps a logical axis name to a mesh axis (or tuple of axes, or
    None). Built by `default_rules`; hillclimbs in EXPERIMENTS.md §Perf edit
    these knobs rather than touching model code.
    """
    rules: Mapping[str, Any]

    def spec_for(self, logical_axes: Sequence[str | None],
                 shape: Sequence[int], mesh: Mesh) -> P:
        used: set[str] = set()
        parts = []
        for dim, name in zip(shape, logical_axes):
            axes = self.rules.get(name) if name else None
            axes = _normalize(axes)
            # drop mesh axes already used by an earlier dim, or that don't
            # divide this dim — the planner's "multiplexer" fallback.
            kept = []
            size = 1
            for ax in axes:
                if ax in used or ax not in mesh.axis_names:
                    continue
                nxt = size * mesh.shape[ax]
                if dim % nxt != 0:
                    continue
                kept.append(ax)
                used.add(ax)
                size = nxt
            parts.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)


def _normalize(axes) -> tuple[str, ...]:
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


def default_rules(mesh: Mesh, *, fsdp: bool = True, seq_shard: bool = False,
                  zero1: bool = True, expert_axis: str | None = None,
                  overrides=()) -> AxisRules:
    """The framework's hybrid memory map, as logical-axis rules.

    INTERLEAVED logical axes (weights):
      embed   -> data axis when fsdp (weights spread over the DP "banks")
      ffn/heads/vocab/qkv -> model axis (TP)
    SEQUENTIAL logical axes (private data):
      batch -> (pod, data): each chip owns its slice outright
      seq   -> data only when seq_shard (sequence parallelism for prefill)
      kv_heads -> model (KV cache sharded with its producer)
    """
    has_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if has_pod else ("data",)
    rules = {
        # --- SEQUENTIAL region ---
        "batch": batch_axes,
        "seq": ("data",) if seq_shard else None,
        # decode KV caches: shard the cache sequence over `model` — the
        # cache is the dominant decode footprint (tens of GB/chip if left
        # replicated); attention over the sharded dim costs one tiny
        # all-reduce of (B, H, 1) partials per layer.
        "kv_seq": "model",
        "state": None,
        # --- INTERLEAVED region ---
        "embed": batch_axes if fsdp else None,   # FSDP shard dim
        "vocab": "model",
        "ffn": "model",
        "heads": "model",
        "kv_heads": "model",
        "qkv": "model",
        "expert": expert_axis,                   # None -> TP-within-expert
        "conv": None,
        "layers": None,                          # scanned-stack dim stays whole
        # --- REPLICATED ---
        "norm": None,
        None: None,
    }
    if zero1:
        # optimizer moments follow the param spec (they inherit logical axes),
        # which under fsdp already spreads them over `data` — ZeRO-1 for free.
        pass
    rules.update(dict(overrides))
    return AxisRules(rules=rules)


def sharding_for(logical_axes: Sequence[str | None], shape: Sequence[int],
                 mesh: Mesh, rules: AxisRules) -> NamedSharding:
    return NamedSharding(mesh, rules.spec_for(logical_axes, shape, mesh))


def plan_tree(abstract_tree: Any, logical_tree: Any, mesh: Mesh,
              rules: AxisRules) -> Any:
    """Map a pytree of ShapeDtypeStructs + logical-axis tuples to shardings."""
    def one(abstract, logical):
        return sharding_for(logical, abstract.shape, mesh, rules)
    return jax.tree.map(one, abstract_tree, logical_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def spec_tree(abstract_tree: Any, logical_tree: Any, mesh: Mesh,
              rules: AxisRules) -> Any:
    """Same as plan_tree but returns raw PartitionSpecs (for in_shardings)."""
    def one(abstract, logical):
        return rules.spec_for(logical, abstract.shape, mesh)
    return jax.tree.map(one, abstract_tree, logical_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))
