"""Version-bridging helpers over the JAX API surface this repo targets.

The codebase is written against the current jax mesh/sharding API
(`jax.set_mesh`, `jax.sharding.AxisType`, `jax.shard_map`, the
positional `AbstractMesh(shape, axis_names)` constructor).  Older
installs (0.4.x) expose the same functionality under different names and
signatures; everything that touches those entry points goes through this
module so the rest of the code reads as if only the modern API existed.
"""

from __future__ import annotations

import contextlib
import inspect
from typing import Any, Sequence

import jax

__all__ = ["make_mesh", "abstract_mesh", "shard_map", "set_mesh",
           "pallas_hints", "pallas_compiler_params"]


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, devices: Sequence[Any] | None = None) -> jax.sharding.Mesh:
    """`jax.make_mesh` with Auto axis types where the install supports them."""
    kwargs: dict[str, Any] = {}
    if devices is not None:
        kwargs["devices"] = devices
    params = inspect.signature(jax.make_mesh).parameters
    if "axis_types" in params and hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (
            (jax.sharding.AxisType.Auto,) * len(tuple(axis_names)))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def abstract_mesh(axis_shapes: Sequence[int],
                  axis_names: Sequence[str]) -> jax.sharding.AbstractMesh:
    """AbstractMesh across both constructor generations."""
    shapes, names = tuple(axis_shapes), tuple(axis_names)
    try:
        return jax.sharding.AbstractMesh(shapes, names)
    except TypeError:
        # 0.4.x signature: a tuple of (axis_name, axis_size) pairs.
        return jax.sharding.AbstractMesh(tuple(zip(names, shapes)))


def _resolve_shard_map():
    if hasattr(jax, "shard_map"):
        return jax.shard_map
    from jax.experimental.shard_map import shard_map as sm
    return sm


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None,
              **kwargs):
    """`jax.shard_map`, translating `check_vma` to the legacy `check_rep`."""
    sm = _resolve_shard_map()
    params = inspect.signature(sm).parameters
    if check_vma is not None:
        if "check_vma" in params:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in params:
            kwargs["check_rep"] = check_vma
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


@contextlib.contextmanager
def set_mesh(mesh):
    """`jax.set_mesh` where available; the legacy Mesh context otherwise.

    Call sites pair this with `jax.jit(..., in_shardings=..., out_shardings=...)`
    whose NamedShardings already carry the mesh, so the legacy fallback only
    needs to provide an ambient mesh for with_sharding_constraint-style uses.
    """
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        with setter(mesh):
            yield
    elif isinstance(mesh, jax.sharding.Mesh):
        with mesh:
            yield
    else:                                   # AbstractMesh on a legacy install
        yield


# ----------------------------------------------------------------------------
# Pallas pipelining hints
# ----------------------------------------------------------------------------
#
# The hint surface of pallas_call drifts across releases: `cost_estimate`
# moved from absent to a first-class kwarg, the TPU compiler-params class was
# renamed (TPUCompilerParams -> CompilerParams), and explicit multiple-
# buffering knobs (`num_stages` / `pipeline_depth`) exist only on some
# versions. `pallas_hints` keeps only what the installed version accepts, so
# kernel code states its full intent and older installs silently drop the
# parts they cannot express (they are scheduling hints, never semantics).


def _pallas_tpu_params_cls():
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    return cls if cls is not None else getattr(pltpu, "TPUCompilerParams")


def _pallas_tpu_fields() -> frozenset:
    return frozenset(
        getattr(_pallas_tpu_params_cls(), "__dataclass_fields__", ()))


def _pallas_call_params() -> frozenset:
    from jax.experimental import pallas as pl
    return frozenset(inspect.signature(pl.pallas_call).parameters)


def pallas_hints(*, cost: dict | None = None, num_stages: int | None = None,
                 dimension_semantics: Sequence[str] | None = None,
                 ) -> tuple[dict, dict]:
    """Split pipelining hints into what this install can express.

    Returns ``(pallas_call kwargs, compiler-params kwargs)``. `cost` is a
    dict of `pl.CostEstimate` fields (flops/bytes_accessed/transcendentals);
    `num_stages` the desired multiple-buffering depth (2 = classic double
    buffering). Unsupported hints are dropped — they only steer scheduling.
    """
    from jax.experimental import pallas as pl
    call_kw: dict[str, Any] = {}
    cp_kw: dict[str, Any] = {}
    fields = _pallas_tpu_fields()
    if dimension_semantics is not None and "dimension_semantics" in fields:
        cp_kw["dimension_semantics"] = tuple(dimension_semantics)
    if (cost is not None and hasattr(pl, "CostEstimate")
            and "cost_estimate" in _pallas_call_params()):
        call_kw["cost_estimate"] = pl.CostEstimate(**cost)
    if num_stages is not None:
        for field in ("num_stages", "pipeline_depth", "num_pipeline_stages"):
            if field in fields:
                cp_kw[field] = int(num_stages)
                break
    return call_kw, cp_kw


def pallas_compiler_params(cp_kwargs: dict):
    """TPU compiler-params object across both class generations."""
    return _pallas_tpu_params_cls()(**cp_kwargs)
