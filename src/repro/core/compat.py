"""Version-bridging helpers over the JAX API surface this repo targets.

The codebase is written against the current jax mesh/sharding API
(`jax.set_mesh`, `jax.sharding.AxisType`, `jax.shard_map`, the
positional `AbstractMesh(shape, axis_names)` constructor).  Older
installs (0.4.x) expose the same functionality under different names and
signatures; everything that touches those entry points goes through this
module so the rest of the code reads as if only the modern API existed.
"""

from __future__ import annotations

import contextlib
import inspect
from typing import Any, Sequence

import jax

__all__ = ["make_mesh", "abstract_mesh", "shard_map", "set_mesh"]


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, devices: Sequence[Any] | None = None) -> jax.sharding.Mesh:
    """`jax.make_mesh` with Auto axis types where the install supports them."""
    kwargs: dict[str, Any] = {}
    if devices is not None:
        kwargs["devices"] = devices
    params = inspect.signature(jax.make_mesh).parameters
    if "axis_types" in params and hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (
            (jax.sharding.AxisType.Auto,) * len(tuple(axis_names)))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def abstract_mesh(axis_shapes: Sequence[int],
                  axis_names: Sequence[str]) -> jax.sharding.AbstractMesh:
    """AbstractMesh across both constructor generations."""
    shapes, names = tuple(axis_shapes), tuple(axis_names)
    try:
        return jax.sharding.AbstractMesh(shapes, names)
    except TypeError:
        # 0.4.x signature: a tuple of (axis_name, axis_size) pairs.
        return jax.sharding.AbstractMesh(tuple(zip(names, shapes)))


def _resolve_shard_map():
    if hasattr(jax, "shard_map"):
        return jax.shard_map
    from jax.experimental.shard_map import shard_map as sm
    return sm


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None,
              **kwargs):
    """`jax.shard_map`, translating `check_vma` to the legacy `check_rep`."""
    sm = _resolve_shard_map()
    params = inspect.signature(sm).parameters
    if check_vma is not None:
        if "check_vma" in params:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in params:
            kwargs["check_rep"] = check_vma
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


@contextlib.contextmanager
def set_mesh(mesh):
    """`jax.set_mesh` where available; the legacy Mesh context otherwise.

    Call sites pair this with `jax.jit(..., in_shardings=..., out_shardings=...)`
    whose NamedShardings already carry the mesh, so the legacy fallback only
    needs to provide an ambient mesh for with_sharding_constraint-style uses.
    """
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        with setter(mesh):
            yield
    elif isinstance(mesh, jax.sharding.Mesh):
        with mesh:
            yield
    else:                                   # AbstractMesh on a legacy install
        yield
