"""repro.core — MemPool's contributions as composable JAX modules.

- mesh:         hierarchical machine topology (tile/group/cluster → chip/ICI/pod)
- addressing:   hybrid addressing scheme → Region-policy sharding planner
- interconnect: Top_H topology model + α–β collective cost model
- locality:     HLO collective parser (p_local measurement, roofline terms)
- overlap:      latency-tolerance helpers (scanned layers, sharding hints)
"""

from . import addressing, interconnect, locality, mesh, overlap  # noqa: F401
from .addressing import AddressMap, AxisRules, Region, default_rules  # noqa: F401
from .mesh import Topology, v5e_topology  # noqa: F401
