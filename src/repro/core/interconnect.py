"""Interconnect models — MemPool §3 (Fig. 4/5) and the TPU collective cost model.

Two models:

1. `TopologyModel` — a queueing-flavoured throughput/latency model of the
   paper's three candidate interconnects (Top_1, Top_4, Top_H), driven by
   injected load and p_local. Reproduces the *trends* of paper Fig. 4/5:
   Top_1 saturates near 0.10 req/core/cycle; Top_4/Top_H near 0.37/0.40; and
   raising p_local raises the saturation point. Used by
   benchmarks/bench_fig4_interconnect.py and bench_fig5_hybrid.py.

2. `CollectiveModel` — α–β cost of TPU collectives on the hierarchical mesh
   (ring algorithms on ICI axes, DCN for the pod axis). Used by the sharding
   planner and the §Roofline collective term cross-check.
"""

from __future__ import annotations

import dataclasses
import math

from . import mesh as hw

# ----------------------------------------------------------------------------
# 1. Paper topology model (Fig. 4 / Fig. 5)
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TopoSpec:
    name: str
    remote_ports: int       # outgoing remote request ports per tile
    base_latency: float     # cycles, uncongested remote round-trip
    local_latency: float    # cycles, within-tile access
    group_latency: float    # cycles, within-group (Top_H only)
    p_group: float          # fraction of remote traffic staying in-group
    saturation: float       # req/core/cycle at which the fabric saturates


TOP_1 = TopoSpec("Top_1", remote_ports=1, base_latency=5.0, local_latency=1.0,
                 group_latency=5.0, p_group=0.0, saturation=0.105)
TOP_4 = TopoSpec("Top_4", remote_ports=4, base_latency=5.0, local_latency=1.0,
                 group_latency=5.0, p_group=0.0, saturation=0.37)
TOP_H = TopoSpec("Top_H", remote_ports=4, base_latency=5.0, local_latency=1.0,
                 group_latency=3.0, p_group=0.25, saturation=0.40)


class TopologyModel:
    """M/D/1-flavoured latency & accepted-throughput vs injected load.

    Requests are uniformly distributed over banks (paper §3.3.1): with 64
    tiles, 1/64 of requests are local by chance; `p_local` adds the hybrid
    addressing scheme's sequential-region hits on top (paper §3.3.2).
    """

    def __init__(self, spec: TopoSpec, n_tiles: int = 64):
        self.spec = spec
        self.n_tiles = n_tiles

    def split(self, p_local: float) -> tuple[float, float, float]:
        chance_local = 1.0 / self.n_tiles
        p_loc = p_local + (1 - p_local) * chance_local
        p_rem = 1.0 - p_loc
        p_grp = p_rem * self.spec.p_group
        p_far = p_rem - p_grp
        return p_loc, p_grp, p_far

    def accepted_load(self, injected: float, p_local: float = 0.0) -> float:
        """Accepted throughput (req/core/cycle) given injected load."""
        injected = min(injected, 1.0)     # a core issues <= 1 req/cycle
        p_loc, p_grp, p_far = self.split(p_local)
        remote = injected * (p_grp + p_far)
        # fabric saturates when remote traffic hits the spec's ceiling
        sat = self.spec.saturation / max(1e-9, (1 - 1.0 / self.n_tiles))
        accepted_remote = min(remote, sat * (p_grp + p_far) /
                              max(p_grp + p_far, 1e-9) * 1.0)
        accepted_remote = min(remote, self.spec.saturation)
        scale = accepted_remote / remote if remote > 1e-12 else 1.0
        return injected * p_loc + injected * (p_grp + p_far) * scale

    def avg_latency(self, injected: float, p_local: float = 0.0) -> float:
        """Average round-trip latency (cycles) with M/D/1 congestion blow-up."""
        p_loc, p_grp, p_far = self.split(p_local)
        rho = min(injected * (p_grp + p_far) / self.spec.saturation, 0.999)
        # M/D/1 waiting time: rho / (2 (1 - rho)) service units
        queue = rho / (2.0 * (1.0 - rho)) * self.spec.base_latency
        lat = (p_loc * self.spec.local_latency
               + p_grp * (self.spec.group_latency + queue)
               + p_far * (self.spec.base_latency + queue))
        return lat


# ----------------------------------------------------------------------------
# 2. TPU collective cost model (α–β on the hierarchical mesh)
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CollectiveCost:
    seconds: float
    bytes_on_wire: float


class CollectiveModel:
    def __init__(self, topo: hw.Topology):
        self.topo = topo

    def _axis_bw_lat(self, axis: str) -> tuple[float, float]:
        if axis == "pod":
            return hw.DCN_BW_PER_HOST, 1e-5
        # ICI ring on one mesh axis: 2 links usable (bidirectional ring)
        return 2 * hw.ICI_BW_PER_LINK, 1e-6

    def all_gather(self, shard_bytes: float, axis: str) -> CollectiveCost:
        n = self.topo.axis_size(axis)
        if n <= 1:
            return CollectiveCost(0.0, 0.0)
        bw, lat = self._axis_bw_lat(axis)
        steps = n - 1
        sec = steps * lat + (n - 1) / n * (shard_bytes * n) / bw
        return CollectiveCost(sec, shard_bytes * (n - 1))

    def reduce_scatter(self, full_bytes: float, axis: str) -> CollectiveCost:
        n = self.topo.axis_size(axis)
        if n <= 1:
            return CollectiveCost(0.0, 0.0)
        bw, lat = self._axis_bw_lat(axis)
        steps = n - 1
        sec = steps * lat + (n - 1) / n * full_bytes / bw
        return CollectiveCost(sec, full_bytes * (n - 1) / n)

    def all_reduce(self, full_bytes: float, axis: str) -> CollectiveCost:
        rs = self.reduce_scatter(full_bytes, axis)
        ag = self.all_gather(full_bytes / max(self.topo.axis_size(axis), 1), axis)
        return CollectiveCost(rs.seconds + ag.seconds,
                              rs.bytes_on_wire + ag.bytes_on_wire)

    def all_to_all(self, full_bytes: float, axis: str) -> CollectiveCost:
        n = self.topo.axis_size(axis)
        if n <= 1:
            return CollectiveCost(0.0, 0.0)
        bw, lat = self._axis_bw_lat(axis)
        sec = (n - 1) * lat / n + full_bytes * (n - 1) / n / bw
        return CollectiveCost(sec, full_bytes * (n - 1) / n)

    def collective_term_seconds(self, bytes_by_kind: dict[str, float]) -> float:
        """Roofline collective term: wire bytes / per-chip link bandwidth.

        Matches the task's definition: collective_bytes / (chips x link_bw),
        with bytes already summed per chip from the HLO (locality.py).
        """
        total = sum(bytes_by_kind.values())
        return total / (3 * hw.ICI_BW_PER_LINK)  # ~3 usable links/chip on v5e
