"""Model zoo: the ten assigned architectures as composable JAX modules."""
