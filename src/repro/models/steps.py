"""Step factories: compose blocks into train / prefill / decode programs.

Layers are organized as *super-blocks* (one period of cfg.pattern) and
scanned with `lax.scan` + remat, so the lowered HLO stays compact enough to
compile for 512 chips and the per-iteration weight all-gather is exposed for
latency hiding (the Snitch outstanding-load analogue — see core/overlap.py).

Layer layout: n_super complete periods (scanned, weights stacked on a
leading "layers" axis) followed by `n_layers % period` remainder layers
(unscanned). The cross-entropy is computed in sequence chunks with remat so
(B, S, vocab) logits never materialize.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.cluster import policy as kpolicy
from repro.core import overlap
from repro.models.blocks import BLOCKS
from repro.models.layers import (ParamSpec, abstract_tree, init_tree,
                                 logical_tree, layer_norm, rms_norm)
from repro.optim import AdamConfig, adam_init, adam_update, warmup_cosine

F32 = jnp.float32

AUX_COEF = 1e-2     # MoE load-balance loss weight
Z_COEF = 1e-4       # z-loss weight
LOSS_CHUNK = 512    # sequence chunk for the fused CE


# ----------------------------------------------------------------------------
# Layer plan
# ----------------------------------------------------------------------------

def block_plan(cfg) -> tuple[tuple[str, ...], int, tuple[str, ...]]:
    if cfg.family == "vlm" and cfg.cross_every:
        pattern = ("attn",) * (cfg.cross_every - 1) + ("cross",)
    else:
        pattern = cfg.pattern
    period = len(pattern)
    return pattern, cfg.n_layers // period, pattern[: cfg.n_layers % period]


def _stack(specs, n: int):
    def one(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n, *s.shape), ("layers", *s.logical), s.dtype,
                         s.init, s.scale)
    return jax.tree.map(one, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


# ----------------------------------------------------------------------------
# Parameter specs
# ----------------------------------------------------------------------------

def param_specs(cfg, max_seq: int = 4096) -> dict:
    pattern, n_super, remainder = block_plan(cfg)
    d = cfg.d_model
    specs: dict[str, Any] = {
        "tok_embed": ParamSpec((cfg.vocab, d), ("vocab", None), init="embed",
                               scale=1.0),
        "unembed": ParamSpec((d, cfg.vocab), ("embed", "vocab")),
    }
    if cfg.norm == "rms":
        specs["ln_f"] = ParamSpec((d,), ("norm",), init="zeros")
    else:
        specs["ln_f_s"] = ParamSpec((d,), ("norm",), init="ones")
        specs["ln_f_b"] = ParamSpec((d,), ("norm",), init="zeros")
    specs["blocks"] = {
        f"sub{i}": _stack(BLOCKS[k]["specs"](cfg), n_super)
        for i, k in enumerate(pattern)}
    if remainder:
        specs["rem"] = {f"rem{i}": BLOCKS[k]["specs"](cfg)
                        for i, k in enumerate(remainder)}
    if cfg.family == "encdec":
        specs["enc"] = {
            "blocks": _stack(BLOCKS["enc_attn"]["specs"](cfg), cfg.n_enc_layers),
            "pos": ParamSpec((cfg.enc_seq, d), (None, None), init="embed",
                             scale=0.02),
            "ln_s": ParamSpec((d,), ("norm",), init="ones"),
            "ln_b": ParamSpec((d,), ("norm",), init="zeros"),
        }
        specs["dec_pos"] = ParamSpec((max_seq, d), (None, None), init="embed",
                                     scale=0.02)
    return specs


def abstract_params(cfg, max_seq: int = 4096):
    specs = param_specs(cfg, max_seq)
    return abstract_tree(specs), logical_tree(specs)


def init_params(cfg, key, max_seq: int = 4096):
    return init_tree(param_specs(cfg, max_seq), key)


# ----------------------------------------------------------------------------
# Decode cache specs
# ----------------------------------------------------------------------------

def cache_specs(cfg, B: int, cache_len: int) -> dict:
    pattern, n_super, remainder = block_plan(cfg)
    specs: dict[str, Any] = {"blocks": {
        f"sub{i}": _stack(BLOCKS[k]["cache"](cfg, B, cache_len), n_super)
        for i, k in enumerate(pattern)}}
    if remainder:
        specs["rem"] = {f"rem{i}": BLOCKS[k]["cache"](cfg, B, cache_len)
                        for i, k in enumerate(remainder)}
    return specs


def abstract_cache(cfg, B: int, cache_len: int):
    specs = cache_specs(cfg, B, cache_len)
    return abstract_tree(specs), logical_tree(specs)


def init_cache(cfg, B: int, cache_len: int):
    return init_tree(cache_specs(cfg, B, cache_len), jax.random.PRNGKey(0))


def _map_cache_axes(cache, fn_for_axis):
    """Apply `fn_for_axis(batch_axis)` leaf-wise over a decode cache.
    Stacked super-block leaves carry a leading `layers` axis, so the batch
    axis is 1 under `blocks` and 0 under `rem` — every per-slot cache
    operation (zero, fill, take, put, NaN scan) shares this layout fact."""
    out = {"blocks": jax.tree.map(fn_for_axis(1), cache["blocks"])}
    if "rem" in cache:
        out["rem"] = jax.tree.map(fn_for_axis(0), cache["rem"])
    return out


def fill_cache_slots(cache, mask, value):
    """Fill the per-slot decode state of masked batch rows with `value`.

    `mask` is (B,) bool. Non-float leaves are left untouched when `value`
    is not finite (NaN fault injection must not corrupt integer state)."""
    import math
    finite = math.isfinite(value)

    def at_axis(axis):
        def one(c):
            if not finite and not jnp.issubdtype(c.dtype, jnp.inexact):
                return c
            shape = [1] * c.ndim
            shape[axis] = mask.shape[0]
            return jnp.where(mask.reshape(shape),
                             jnp.asarray(value, c.dtype), c)
        return one

    return _map_cache_axes(cache, at_axis)


def zero_cache_slots(cache, mask):
    """Zero the per-slot decode state of masked batch rows.

    `mask` is (B,) bool. Needed when a slot is recycled for a new request:
    KV rows beyond the (reset) position are masked out by decode attention
    anyway, but recurrent block states (mLSTM/sLSTM/RG-LRU matrices, conv
    tails) carry the old request's activations and must be cleared.
    """
    return fill_cache_slots(cache, mask, 0.0)


def take_cache_slot(cache, slot):
    """Slice one slot's rows out of every cache leaf (the device half of a
    slot checkpoint — see `engine.make_slot_snapshot`)."""
    def at_axis(axis):
        return lambda c: jax.lax.dynamic_index_in_dim(c, slot, axis=axis,
                                                      keepdims=False)
    return _map_cache_axes(cache, at_axis)


def put_cache_slot(cache, slot, rows):
    """Write `rows` (a `take_cache_slot` result) back into slot `slot` —
    bit-exact, so a preempted request resumes identically."""
    def put(axis, c, r):
        idx = [slice(None)] * c.ndim
        idx[axis] = slot
        return c.at[tuple(idx)].set(r)

    out = {"blocks": jax.tree.map(lambda c, r: put(1, c, r),
                                  cache["blocks"], rows["blocks"])}
    if "rem" in cache:
        out["rem"] = jax.tree.map(lambda c, r: put(0, c, r),
                                  cache["rem"], rows["rem"])
    return out


def nan_cache_slots(cache):
    """(B,) bool: any-NaN per slot across every float cache leaf — the
    corruption sentinel `engine.make_nan_scan` compiles for the session."""
    flags = []

    def at_axis(axis):
        def one(c):
            if jnp.issubdtype(c.dtype, jnp.inexact):
                axes = tuple(i for i in range(c.ndim) if i != axis)
                flags.append(jnp.any(jnp.isnan(c), axis=axes))
            return c
        return one

    _map_cache_axes(cache, at_axis)
    out = flags[0]
    for f in flags[1:]:
        out = out | f
    return out


# ----------------------------------------------------------------------------
# Paged decode cache — the shared-pool layout (runtime/kvpool.py)
# ----------------------------------------------------------------------------
#
# Under a paged session the positional K/V leaves stop being per-slot
# rectangles (B, L, KV, hd) and become ONE shared pool (n_pages, page_size,
# KV, hd) addressed through per-slot page tables (`ctx["pages"]` in the
# decode step). Rolling-window buffers and recurrent block states are not
# pageable — their `pos % window` addressing / dense state is a layout of
# its own — so they stay private (B, ...) leaves; the two kinds coexist in
# one cache pytree and every per-slot op below routes each leaf by a
# structural mask.

def _kind_paged(cfg, kind: str) -> bool:
    """Does this block kind route K/V through the pool? Mirrors the
    `_paged(ctx, window)` gate in blocks.py: positional attention pages,
    windowed attention stays a private rolling buffer."""
    if kind in ("attn", "attn_moe"):
        return not cfg.window
    return kind == "attn_cross"        # self_k/self_v (cross_* is static)


def _pageable_leaf(spec: ParamSpec) -> bool:
    return tuple(spec.logical[:2]) == ("batch", "kv_seq")


def _paged_kind_specs(cfg, kind: str, B: int, cache_len: int,
                      n_pages: int, page_size: int):
    specs = BLOCKS[kind]["cache"](cfg, B, cache_len)
    if not _kind_paged(cfg, kind):
        return specs

    def one(s: ParamSpec) -> ParamSpec:
        if not _pageable_leaf(s):
            return s
        return ParamSpec((n_pages, page_size, *s.shape[2:]),
                         (None, None, *s.logical[2:]), s.dtype, s.init,
                         s.scale)

    return jax.tree.map(one, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def paged_cache_specs(cfg, B: int, cache_len: int, *, n_pages: int,
                      page_size: int) -> dict:
    """`cache_specs` with every pageable K/V leaf replaced by the shared
    pool (n_pages, page_size, KV, hd); stacked super-blocks carry the
    usual leading layers axis, i.e. (n_super, n_pages, ps, KV, hd)."""
    pattern, n_super, remainder = block_plan(cfg)
    kinds = pattern + remainder
    if not any(_kind_paged(cfg, k) and any(
            _pageable_leaf(s) for s in jax.tree.leaves(
                BLOCKS[k]["cache"](cfg, B, cache_len),
                is_leaf=lambda x: isinstance(x, ParamSpec)))
            for k in kinds):
        raise ValueError(
            f"arch {cfg.name!r} has no pageable KV leaves (recurrent or "
            f"fully windowed) — paged serving needs positional attention")
    specs: dict[str, Any] = {"blocks": {
        f"sub{i}": _stack(_paged_kind_specs(cfg, k, B, cache_len,
                                            n_pages, page_size), n_super)
        for i, k in enumerate(pattern)}}
    if remainder:
        specs["rem"] = {f"rem{i}": _paged_kind_specs(cfg, k, B, cache_len,
                                                     n_pages, page_size)
                        for i, k in enumerate(remainder)}
    return specs


def paged_cache_mask(cfg, B: int, cache_len: int) -> dict:
    """Same tree structure as the cache, True on pool leaves — the routing
    fact every paged per-slot op shares."""
    pattern, n_super, remainder = block_plan(cfg)

    def mask_tree(kind: str):
        paged = _kind_paged(cfg, kind)
        return jax.tree.map(lambda s: paged and _pageable_leaf(s),
                            BLOCKS[kind]["cache"](cfg, B, cache_len),
                            is_leaf=lambda x: isinstance(x, ParamSpec))

    mask: dict[str, Any] = {"blocks": {f"sub{i}": mask_tree(k)
                                       for i, k in enumerate(pattern)}}
    if remainder:
        mask["rem"] = {f"rem{i}": mask_tree(k)
                       for i, k in enumerate(remainder)}
    return mask


def abstract_paged_cache(cfg, B: int, cache_len: int, *, n_pages: int,
                         page_size: int):
    specs = paged_cache_specs(cfg, B, cache_len, n_pages=n_pages,
                              page_size=page_size)
    return abstract_tree(specs), logical_tree(specs)


def init_paged_cache(cfg, B: int, cache_len: int, *, n_pages: int,
                     page_size: int):
    return init_tree(paged_cache_specs(cfg, B, cache_len, n_pages=n_pages,
                                       page_size=page_size),
                     jax.random.PRNGKey(0))


def make_paged_cache_ops(cfg, B: int, cache_len: int):
    """The per-slot / per-page device ops of a paged cache, routed by the
    pool mask. Returned as a dict of pure functions (the engine wrappers
    jit them):

    * ``zero_slots(cache, mask)`` — refill zeroing of *private* leaves
      only (recurrent/rolling state must not leak across requests);
      pool pages are deliberately NOT zeroed — stale page data is masked
      out by decode attention, which is the point of paged refill.
    * ``nan_slots(cache, tables)`` — (B,) any-NaN per slot; private
      leaves by batch row, pool leaves via the slot's page table
      (trash-page entries ignored so one poisoned slot cannot flag
      every retired neighbour).
    * ``corrupt_slots(cache, mask, tables)`` — NaN-fill masked slots:
      private float rows directly, pool pages via a scatter of the
      masked slots' table entries.
    * ``copy_pages(cache, src, dst)`` — pool page copy (the COW fork).
    * ``zero_pages(cache, pages)`` — pool page scrub (NaN quarantine).
    * ``read_pages(cache, pages)`` — gather the requested pool pages,
      page axis moved to the front of every returned array, so the host
      can checksum page content (integrity stamp/verify).
    * ``flip_pages(cache, pages)`` — *silent* corruption for the
      ``bit_flip`` fault: perturb the pages' float content by +1
      (finite values — the NaN sentinel scan cannot see it by design;
      only the content checksum catches it).
    """
    mask = paged_cache_mask(cfg, B, cache_len)

    def _map(cache, fn_for):
        out = {"blocks": jax.tree.map(lambda c, m: fn_for(1, m)(c),
                                      cache["blocks"], mask["blocks"])}
        if "rem" in cache:
            out["rem"] = jax.tree.map(lambda c, m: fn_for(0, m)(c),
                                      cache["rem"], mask["rem"])
        return out

    def zero_slots(cache, slot_mask):
        def fn_for(axis, paged):
            if paged:
                return lambda c: c
            def one(c):
                shape = [1] * c.ndim
                shape[axis] = slot_mask.shape[0]
                return jnp.where(slot_mask.reshape(shape),
                                 jnp.zeros((), c.dtype), c)
            return one
        return _map(cache, fn_for)

    def nan_slots(cache, tables):
        flags = []
        live = tables != 0                       # TRASH_PAGE entries

        def fn_for(axis, paged):
            def one(c):
                if not jnp.issubdtype(c.dtype, jnp.inexact):
                    return c
                if paged:
                    # page axis sits where the batch axis would (the
                    # layers axis, if any, still leads)
                    axes = tuple(i for i in range(c.ndim) if i != axis)
                    per_page = jnp.any(jnp.isnan(c), axis=axes)
                    flags.append(jnp.any(per_page[tables] & live, axis=1))
                else:
                    axes = tuple(i for i in range(c.ndim) if i != axis)
                    flags.append(jnp.any(jnp.isnan(c), axis=axes))
                return c
            return one

        _map(cache, fn_for)
        out = flags[0]
        for f in flags[1:]:
            out = out | f
        return out

    def corrupt_slots(cache, slot_mask, tables):
        import math
        n_hit = None

        def fn_for(axis, paged):
            def one(c):
                nonlocal n_hit
                if not jnp.issubdtype(c.dtype, jnp.inexact):
                    return c
                nan = jnp.asarray(float("nan"), c.dtype)
                if paged:
                    n_pages = c.shape[axis]
                    if n_hit is None or n_hit.shape[0] != n_pages:
                        hit0 = jnp.zeros((n_pages,), bool)
                        n_hit = hit0.at[tables].max(
                            slot_mask[:, None] & (tables != 0))
                    shape = [1] * c.ndim
                    shape[axis] = n_pages
                    return jnp.where(n_hit.reshape(shape), nan, c)
                shape = [1] * c.ndim
                shape[axis] = slot_mask.shape[0]
                return jnp.where(slot_mask.reshape(shape), nan, c)
            return one

        del math
        return _map(cache, fn_for)

    def copy_pages(cache, src, dst):
        s = jnp.asarray(src, jnp.int32)
        d = jnp.asarray(dst, jnp.int32)

        def fn_for(axis, paged):
            def one(c):
                if not paged:
                    return c
                if axis == 1:
                    return c.at[:, d].set(c[:, s])
                return c.at[d].set(c[s])
            return one
        return _map(cache, fn_for)

    def zero_pages(cache, pages):
        idx = jnp.asarray(pages, jnp.int32)

        def fn_for(axis, paged):
            def one(c):
                if not paged:
                    return c
                zero = jnp.zeros((), c.dtype)
                if axis == 1:
                    return c.at[:, idx].set(zero)
                return c.at[idx].set(zero)
            return one
        return _map(cache, fn_for)

    def read_pages(cache, pages):
        idx = jnp.asarray(pages, jnp.int32)
        out = []

        def fn_for(axis, paged):
            def one(c):
                if not paged:
                    return c
                if axis == 1:
                    # (layers, pages, ...) -> page-major (n, layers, ...)
                    out.append(jnp.moveaxis(c[:, idx], 1, 0))
                else:
                    out.append(c[idx])
                return c
            return one

        _map(cache, fn_for)
        return tuple(out)

    def flip_pages(cache, pages):
        idx = jnp.asarray(pages, jnp.int32)

        def fn_for(axis, paged):
            def one(c):
                if not paged or not jnp.issubdtype(c.dtype, jnp.inexact):
                    return c
                one_v = jnp.ones((), c.dtype)
                if axis == 1:
                    return c.at[:, idx].add(one_v)
                return c.at[idx].add(one_v)
            return one
        return _map(cache, fn_for)

    return {"zero_slots": zero_slots, "nan_slots": nan_slots,
            "corrupt_slots": corrupt_slots, "copy_pages": copy_pages,
            "zero_pages": zero_pages, "read_pages": read_pages,
            "flip_pages": flip_pages}


# ----------------------------------------------------------------------------
# Forward
# ----------------------------------------------------------------------------

def _final_norm(cfg, params, x):
    if cfg.norm == "rms":
        return rms_norm(x, params["ln_f"])
    return layer_norm(x, params["ln_f_s"], params["ln_f_b"])


def _encode(cfg, params, enc_embeds):
    """Whisper encoder over stub frame embeddings."""
    x = enc_embeds + params["enc"]["pos"].astype(enc_embeds.dtype)
    B, S = x.shape[:2]
    ctx = {"positions": jnp.broadcast_to(jnp.arange(S), (B, S)), "rope": False}

    def body(carry, layer_params):
        x, = carry
        x, _ = BLOCKS["enc_attn"]["apply"](cfg, layer_params, x, ctx)
        return (x,), None

    (x,), _ = overlap.prefetchable_scan(body, (x,), params["enc"]["blocks"],
                                        remat_policy=cfg.remat)
    return layer_norm(x, params["enc"]["ln_s"], params["enc"]["ln_b"])


def forward(cfg, params, tokens, *, cross_embeds=None, layer_wsc=None):
    """Token ids -> final hidden states (B, S, d) and aux loss.

    `layer_wsc`: optional PartitionSpec tree matching one super-block's
    params. When given, the scan body re-constrains the sliced layer weights
    to those specs — used to force true FSDP semantics (all-gather the
    layer's weights over `data` once per layer) where GSPMD would otherwise
    choose partial-sum all-reduces of activation-sized buffers per einsum
    (see EXPERIMENTS.md §Perf H2).
    """
    pattern, n_super, remainder = block_plan(cfg)
    B, S = tokens.shape
    x = jnp.take(params["tok_embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if cfg.family == "encdec":
        cross_embeds = _encode(cfg, params, cross_embeds)
        x = x + params["dec_pos"][:S].astype(x.dtype)
    ctx = {"positions": positions, "rope": cfg.family != "encdec",
           "cross_embeds": cross_embeds, "max_seq": S}

    def super_body(carry, super_params):
        x, aux = carry
        if layer_wsc is not None:
            super_params = jax.tree.map(overlap.with_sharding, super_params,
                                        layer_wsc)
        for i, kind in enumerate(pattern):
            x, a = BLOCKS[kind]["apply"](cfg, super_params[f"sub{i}"], x, ctx)
            aux = aux + a
        return (x, aux), None

    (x, aux), _ = overlap.prefetchable_scan(
        super_body, (x, jnp.zeros((), F32)), params["blocks"],
        remat_policy=cfg.remat)
    for i, kind in enumerate(remainder):
        x, a = BLOCKS[kind]["apply"](cfg, params["rem"][f"rem{i}"], x, ctx)
        aux = aux + a
    return _final_norm(cfg, params, x), aux


# ----------------------------------------------------------------------------
# Loss (chunked over sequence; logits never materialize at (B, S, V))
# ----------------------------------------------------------------------------

def _chunked_ce(cfg, unembed, hidden, labels):
    B, S, d = hidden.shape
    c = min(LOSS_CHUNK, S)
    if S % c:
        c = S
    nc = S // c
    split = lambda a: jnp.moveaxis(a.reshape(B, nc, c, *a.shape[2:]), 1, 0)

    @jax.checkpoint
    def body(carry, blk):
        h, y = blk
        logits = jnp.einsum("bcd,dv->bcv", h, unembed,
                            preferred_element_type=F32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(y, cfg.vocab, dtype=F32)
        ll = jnp.sum(logits * onehot, axis=-1)
        nll = (lse - ll).sum()
        z = Z_COEF * jnp.square(lse).sum()
        return carry + nll + z, None

    total, _ = jax.lax.scan(body, jnp.zeros((), F32),
                            (split(hidden), split(labels)))
    return total / (B * S)


def loss_fn(cfg, params, batch, layer_wsc=None):
    cross = batch.get("enc_embeds", batch.get("img_embeds"))
    hidden, aux = forward(cfg, params, batch["tokens"], cross_embeds=cross,
                          layer_wsc=layer_wsc)
    ce = _chunked_ce(cfg, params["unembed"], hidden, batch["labels"])
    return ce + AUX_COEF * aux, {"ce": ce, "aux": aux}


# ----------------------------------------------------------------------------
# Train step
# ----------------------------------------------------------------------------

def make_train_step(cfg, *, adam: AdamConfig | None = None,
                    schedule_kwargs: dict | None = None, layer_wsc=None,
                    policy=None):
    """`policy` (KernelPolicy | mode string | None) pins the kernel policy
    the step traces under; None inherits the ambient scope *at trace time*
    — the policy is baked into the jit trace, so re-scoping the ambient
    policy around an already-jitted step does not re-route it. Build one
    step per policy (as Cluster.compile does) to compare routes."""
    pol = kpolicy.as_policy(policy) if policy is not None else None
    adam = adam or AdamConfig(moment_dtype=cfg.moment_dtype)
    sched = functools.partial(warmup_cosine, **(schedule_kwargs or {}))
    acc_dtype = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else F32

    def _body(state, batch):
        params = state["params"]
        k = cfg.grad_accum
        grad_fn = jax.value_and_grad(
            lambda p, mb: loss_fn(cfg, p, mb, layer_wsc), has_aux=True)
        if k <= 1:
            (loss, parts), grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape(k, a.shape[0] // k, *a.shape[1:]), batch)

            def step_i(carry, mb):
                gacc, lacc = carry
                (l, _), g = grad_fn(params, mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dtype), gacc, g)
                return (gacc, lacc + l), None

            # p * 0 (not jnp.zeros) so the accumulator inherits each param's
            # sharding — a fresh zeros carry would let GSPMD pick replicated
            # layouts for the whole accumulation loop state.
            gacc0 = jax.tree.map(
                lambda p: (p * 0).astype(acc_dtype), params)
            (gacc, lsum), _ = jax.lax.scan(
                step_i, (gacc0, jnp.zeros((), F32)), micro)
            grads = jax.tree.map(lambda g: g / k, gacc)
            loss = lsum / k
            parts = {}
        lr_scale = sched(state["opt"]["step"] + 1)
        new_params, new_opt, om = adam_update(params, grads, state["opt"],
                                              adam, lr_scale)
        metrics = {"loss": loss, "lr_scale": lr_scale, **om}
        if parts:
            metrics |= parts
        return {"params": new_params, "opt": new_opt}, metrics

    def train_step(state, batch):
        with kpolicy.scoped(pol):
            return _body(state, batch)

    return train_step


def abstract_train_state(cfg, max_seq: int = 4096):
    """(state_sds, state_logical) for dry-run lowering and planning."""
    p_sds, p_log = abstract_params(cfg, max_seq)
    mdt = jnp.dtype(cfg.moment_dtype)
    m_sds = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, mdt), p_sds)
    state_sds = {"params": p_sds,
                 "opt": {"m": m_sds, "v": m_sds,
                         "step": jax.ShapeDtypeStruct((), jnp.int32)}}
    state_log = {"params": p_log,
                 "opt": {"m": p_log, "v": p_log, "step": ()}}
    return state_sds, state_log


def init_train_state(cfg, key, max_seq: int = 4096,
                     adam: AdamConfig | None = None):
    adam = adam or AdamConfig(moment_dtype=cfg.moment_dtype)
    params = init_params(cfg, key, max_seq)
    return {"params": params, "opt": adam_init(params, adam)}


# ----------------------------------------------------------------------------
# Prefill / decode steps
# ----------------------------------------------------------------------------

def make_prefill_step(cfg, *, policy=None):
    pol = kpolicy.as_policy(policy) if policy is not None else None

    def prefill_step(params, batch):
        with kpolicy.scoped(pol):
            cross = batch.get("enc_embeds", batch.get("img_embeds"))
            hidden, _ = forward(cfg, params, batch["tokens"],
                                cross_embeds=cross)
            last = hidden[:, -1]
            logits = jnp.einsum("bd,dv->bv", last, params["unembed"],
                                preferred_element_type=F32)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    return prefill_step


def make_decode_step(cfg, max_seq: int = 1 << 30, *, policy=None):
    """`max_seq` is the workload's logical context length; caches shorter
    than it (windowed archs) operate as rolling buffers. `policy` pins the
    kernel policy the step traces under (None -> ambient).

    `batch["pos"]` is a scalar (all slots at the same position — the batch
    program) or a (B,) vector (per-slot positions — the continuous-batching
    session, where each slot is mid-way through its own request)."""
    pol = kpolicy.as_policy(policy) if policy is not None else None
    pattern, n_super, remainder = block_plan(cfg)

    def _body(params, cache, batch):
        tokens, pos = batch["tokens"], jnp.asarray(batch["pos"])
        B = tokens.shape[0]
        x = jnp.take(params["tok_embed"], tokens, axis=0)       # (B,1,d)
        if cfg.family == "encdec":
            if pos.ndim == 0:
                dp = jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1,
                                                  axis=0)
            else:
                dp = jnp.take(params["dec_pos"], pos, axis=0)[:, None]
            x = x + dp.astype(x.dtype)
        if pos.ndim == 0:
            positions = jnp.broadcast_to(pos[None, None], (B, 1))
        else:
            positions = pos[:, None]
        positions = positions.astype(jnp.int32)
        ctx = {"positions": positions, "rope": cfg.family != "encdec",
               "max_seq": max_seq, "pages": batch.get("pages")}

        def super_body(x, scanned):
            layer_params, layer_cache = scanned
            new_cache = {}
            for i, kind in enumerate(pattern):
                x, c = BLOCKS[kind]["decode"](cfg, layer_params[f"sub{i}"], x,
                                              layer_cache[f"sub{i}"], pos, ctx)
                new_cache[f"sub{i}"] = c
            return x, new_cache

        x, new_blocks = jax.lax.scan(super_body, x,
                                     (params["blocks"], cache["blocks"]))
        new_cache: dict[str, Any] = {"blocks": new_blocks}
        if remainder:
            new_cache["rem"] = {}
            for i, kind in enumerate(remainder):
                x, c = BLOCKS[kind]["decode"](
                    cfg, params["rem"][f"rem{i}"], x,
                    cache["rem"][f"rem{i}"], pos, ctx)
                new_cache["rem"][f"rem{i}"] = c
        x = _final_norm(cfg, params, x)
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"],
                            preferred_element_type=F32)[:, 0]
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return new_cache, token

    def decode_step(params, cache, batch):
        with kpolicy.scoped(pol):
            return _body(params, cache, batch)

    return decode_step


def make_decode_chunk(cfg, chunk: int, max_seq: int = 1 << 30, *,
                      eos_id: int | None = None, policy=None,
                      donate: bool = True):
    """Scan-compiled K-token decode program (the execution-engine entry):
    `make_decode_step` rolled into one `lax.scan` of `chunk` steps with
    on-device EOS masking/early-exit and donated cache/token buffers. See
    `runtime/engine.make_decode_chunk` for the calling convention."""
    from repro.runtime import engine
    step = make_decode_step(cfg, max_seq=max_seq, policy=policy)
    return engine.make_decode_chunk(step, chunk, eos_id=eos_id, donate=donate)


def decode_cache_len(cfg, seq_len: int) -> int:
    """Physical cache length: windowed archs keep a rolling window buffer."""
    if cfg.window and cfg.window < seq_len:
        return cfg.window
    return seq_len
