"""Residual block kinds composing the ten architectures.

Each kind provides:
  <kind>_specs(cfg)                      -> ParamSpec tree
  <kind>_apply(cfg, p, x, ctx)           -> (x, aux)          full-sequence
  <kind>_cache_specs(cfg, B, cache_len)  -> ParamSpec tree    decode state
  <kind>_decode(cfg, p, x, cache, pos, ctx) -> (x, cache)     one token

`ctx` carries positions and cross-attention context (encoder/image embeds).
Aux is the MoE load-balancing loss contribution (0.0 elsewhere).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.cluster.policy import current_policy
from repro.core.overlap import shard_batch

from . import attention as attn_lib
from .layers import (ParamSpec, apply_ffn, attn_specs, ffn_specs,
                     fused_attention_proj, fused_matmul_bias_act,
                     fused_matmul_residual, fused_norm_matmul, out_project,
                     qkv_postprocess, qkv_project, rms_norm, layer_norm)

F32 = jnp.float32


def _norm_specs(cfg, name: str) -> dict:
    if cfg.norm == "rms":
        return {name: ParamSpec((cfg.d_model,), ("norm",), init="zeros")}
    return {name + "_s": ParamSpec((cfg.d_model,), ("norm",), init="ones"),
            name + "_b": ParamSpec((cfg.d_model,), ("norm",), init="zeros")}


def _norm(cfg, p, name: str, x):
    if cfg.norm == "rms":
        return rms_norm(x, p[name])
    return layer_norm(x, p[name + "_s"], p[name + "_b"])


# ============================================================================
# Dense attention + FFN block ("attn"), with window variant ("local_attn")
# ============================================================================

def attn_block_specs(cfg) -> dict:
    s = {}
    s |= _norm_specs(cfg, "ln_attn")
    s["attn"] = attn_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                           qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm)
    if cfg.d_ff:
        s |= _norm_specs(cfg, "ln_ffn")
        s["ffn"] = ffn_specs(cfg.d_model, cfg.d_ff, kind=cfg.ffn_kind)
    return s


def _fused_rms(cfg) -> bool:
    """Is the fused producer–consumer path applicable to this block's norm?

    The route is steered by the active KernelPolicy (mode "fused"), read at
    trace time — model code asks the policy, not the config."""
    return current_policy().fused and cfg.norm == "rms"


def _fused_qkv(cfg, p, x, ctx):
    """qkv with the pre-attention rmsnorm folded into each projection's
    A-tile prologue (norm recomputed per consumer; the normed activations
    never round-trip HBM)."""
    a = p["attn"]
    d = x.shape[-1]

    def proj(w):
        y = fused_norm_matmul(x, p["ln_attn"], w.reshape(d, -1))
        return y.reshape(*x.shape[:-1], w.shape[1], w.shape[2])

    return qkv_postprocess(a, proj(a["wq"]), proj(a["wk"]), proj(a["wv"]),
                           ctx["positions"], qkv_bias=cfg.qkv_bias,
                           qk_norm=cfg.qk_norm, rope=ctx.get("rope", True),
                           theta=cfg.rope_theta)


def _fused_out_residual(p, o, x):
    """x + out_project(o) with the residual added in the matmul epilogue."""
    wo = p["attn"]["wo"]
    flat = o.reshape(*o.shape[:-2], o.shape[-2] * o.shape[-1])
    return fused_matmul_residual(flat, wo.reshape(-1, wo.shape[-1]), x)


def _self_attention(cfg, p, x, ctx, *, window, causal=True):
    if _fused_rms(cfg):
        q, k, v = _fused_qkv(cfg, p, x, ctx)
        if causal and window is None:
            # the whole hot path in one kernel: flash attention with the
            # output projection accumulated across heads in VMEM (backward
            # recomputes via the reference composition — see kernels/ops.py)
            return x + fused_attention_proj(q, k, v, p["attn"]["wo"],
                                            causal=True)
        o = attn_lib.attention(q, k, v, n_kv=cfg.n_kv_heads,
                               causal=causal, window=window,
                               chunk=cfg.attn_chunk,
                               schedule=cfg.attn_schedule)
        return _fused_out_residual(p, o, x)
    q, k, v = qkv_project(p["attn"], _norm(cfg, p, "ln_attn", x),
                          ctx["positions"], n_heads=cfg.n_heads,
                          n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                          qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
                          rope=ctx.get("rope", True), theta=cfg.rope_theta)
    o = attn_lib.attention(q, k, v, n_kv=cfg.n_kv_heads,
                           causal=causal, window=window,
                           chunk=cfg.attn_chunk, schedule=cfg.attn_schedule)
    return x + out_project(p["attn"], o)


def _ffn_residual(cfg, p, x):
    """x + FFN(norm(x)), with the fused kernel routing when enabled:
    swiglu/geglu fold the norm into the gate/up prologues and the residual
    into the down-projection epilogue; gelu MLPs take the bias+activation
    epilogue. Falls back to the jnp composition per-site."""
    if current_policy().fused:
        f = p["ffn"]
        if cfg.norm == "rms" and cfg.ffn_kind in ("swiglu", "geglu"):
            g = fused_norm_matmul(x, p["ln_ffn"], f["w_gate"])
            u = fused_norm_matmul(x, p["ln_ffn"], f["w_up"])
            act = jax.nn.silu if cfg.ffn_kind == "swiglu" else jax.nn.gelu
            h = act(g.astype(F32)).astype(x.dtype) * u
            return fused_matmul_residual(h, f["w_down"], x)
        if cfg.ffn_kind == "gelu":
            h = fused_matmul_bias_act(_norm(cfg, p, "ln_ffn", x),
                                      f["w_in"], f["b_in"], "gelu")
            return x + fused_matmul_bias_act(h, f["w_out"], f["b_out"],
                                             "none")
    return x + apply_ffn(p["ffn"], _norm(cfg, p, "ln_ffn", x),
                         kind=cfg.ffn_kind)


def attn_block_apply(cfg, p, x, ctx, *, window=None):
    window = window if window is not None else cfg.window
    x = _self_attention(cfg, p, x, ctx, window=window,
                        causal=ctx.get("causal", True))
    if cfg.d_ff:
        x = _ffn_residual(cfg, p, x)
    return x, 0.0


def attn_cache_specs(cfg, B: int, cache_len: int) -> dict:
    return {
        "k": ParamSpec((B, cache_len, cfg.n_kv_heads, cfg.hd),
                       ("batch", "kv_seq", "kv_heads", None), init="zeros"),
        "v": ParamSpec((B, cache_len, cfg.n_kv_heads, cfg.hd),
                       ("batch", "kv_seq", "kv_heads", None), init="zeros"),
    }


def _paged(ctx, window) -> bool:
    """Route this block's K/V through the shared page pool? Only when the
    session threads a page table in `ctx` and the cache is positional
    (rolling SWA buffers stay private — their `pos % window` addressing is
    its own paging scheme)."""
    return ctx.get("pages") is not None and not window


def attn_block_decode(cfg, p, x, cache, pos, ctx, *, window=None):
    window = window if window is not None else cfg.window
    paged = _paged(ctx, window)
    rolling = (not paged and bool(window)
               and cache["k"].shape[1] < ctx["max_seq"])
    if _fused_rms(cfg):
        q, k, v = _fused_qkv(cfg, p, x, ctx)
    else:
        q, k, v = qkv_project(p["attn"], _norm(cfg, p, "ln_attn", x),
                              ctx["positions"], n_heads=cfg.n_heads,
                              n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                              qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
                              rope=ctx.get("rope", True),
                              theta=cfg.rope_theta)
    if paged:
        kc, vc = attn_lib.paged_update_cache(cache["k"], cache["v"], k, v,
                                             pos, ctx["pages"])
        o = attn_lib.paged_decode_attention(q, kc, vc, pos + 1, ctx["pages"],
                                            n_kv=cfg.n_kv_heads)
    else:
        kc, vc = attn_lib.update_cache(cache["k"], cache["v"], k, v, pos,
                                       rolling=rolling)
        o = attn_lib.decode_attention(q, kc, vc, pos + 1,
                                      n_kv=cfg.n_kv_heads,
                                      window=window, rolling=rolling)
    if _fused_rms(cfg):
        x = _fused_out_residual(p, o, x)
    else:
        x = x + out_project(p["attn"], o)
    if cfg.d_ff:
        x = _ffn_residual(cfg, p, x)
    return x, {"k": kc, "v": vc}


# ============================================================================
# MoE block ("attn_moe"): attention + top-k expert FFN (sort/scatter dispatch)
# ============================================================================

def moe_specs(cfg) -> dict:
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": ParamSpec((d, E), ("embed", None), dtype=F32),
        "w_gate": ParamSpec((E, d, f), ("expert", "embed", "ffn")),
        "w_up": ParamSpec((E, d, f), ("expert", "embed", "ffn")),
        "w_down": ParamSpec((E, f, d), ("expert", "ffn", "embed")),
    }


def moe_block_specs(cfg) -> dict:
    s = {}
    s |= _norm_specs(cfg, "ln_attn")
    s["attn"] = attn_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                           qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm)
    s |= _norm_specs(cfg, "ln_ffn")
    s["moe"] = moe_specs(cfg)
    return s


def moe_apply(cfg, p, x):
    """Top-k MoE with capacity; dispatch via scatter/gather (no one-hot GEMM,
    so cost_analysis reflects true expert FLOPs).

    Two dispatch modes (cfg.moe_local_dispatch):
      global (baseline) — capacity over the *flattened global* token set.
        The cumsum/scatter then run along a sharded dimension, which GSPMD
        lowers to cross-shard collectives: the MoE equivalent of MemPool's
        all-remote interleaved accesses.
      local — GShard-style groups: the batch dim stays the group dim, so
        routing/cumsum/scatter/gather are shard-local (capacity per
        sequence). This is the hybrid addressing scheme applied to MoE:
        dispatch traffic moves from the interconnect into the local tile.
    """
    if getattr(cfg, "moe_local_dispatch", False):
        return _moe_apply_local(cfg, p, x)
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    C = max(int(K * T * cfg.capacity_factor / E), 1)
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt.astype(F32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    top_p, top_e = jax.lax.top_k(probs, K)                       # (T, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) slot within its expert's capacity
    e_flat = top_e.reshape(-1)                                   # (T*K,)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - onehot)                  # exclusive
    pos = jnp.take_along_axis(pos, e_flat[:, None], axis=1)[:, 0]
    keep = pos < C
    tok_idx = jnp.repeat(jnp.arange(T), K)

    # dispatch table (E, C) of token ids; overflow slots dropped by OOB scatter
    dispatch = jnp.full((E, C), T, jnp.int32)
    dispatch = dispatch.at[e_flat, pos].set(tok_idx, mode="drop")
    xp = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)])      # pad row
    xe = xp[dispatch]                                            # (E, C, d)

    # expert FFN (SwiGLU), batched over experts
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    h = jax.nn.silu(g.astype(F32)).astype(xe.dtype) * u
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])              # (E, C, d)

    # combine: gather each slot's output back, weight, scatter-add per token
    ys = ye[e_flat, jnp.minimum(pos, C - 1)]                     # (T*K, d)
    w_slot = (top_p.reshape(-1) * keep).astype(ys.dtype)
    y = jnp.zeros((T, d), x.dtype).at[tok_idx].add(ys * w_slot[:, None])

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    f_e = jnp.mean(jax.nn.one_hot(top_e, E, dtype=F32).sum(1), axis=0)  # frac routed
    p_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e / K * p_e)
    return y.reshape(B, S, d), aux


def _moe_apply_local(cfg, p, x):
    """Grouped dispatch: everything batched over B (the sharded group dim)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = max(int(K * S * cfg.capacity_factor / E), 1)

    logits = jnp.einsum("bsd,de->bse", x.astype(F32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (B, S, E)
    top_p, top_e = jax.lax.top_k(probs, K)                       # (B, S, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    e_flat = top_e.reshape(B, S * K)                             # (B, S*K)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)          # (B, S*K, E)
    pos = jnp.cumsum(onehot, axis=1) - onehot                    # local cumsum
    pos = jnp.take_along_axis(pos, e_flat[..., None], axis=2)[..., 0]
    keep = pos < C
    tok_idx = jnp.broadcast_to(
        jnp.repeat(jnp.arange(S), K)[None], (B, S * K))

    def dispatch_one(e_b, pos_b, tok_b, x_b):
        table = jnp.full((E, C), S, jnp.int32)
        table = table.at[e_b, pos_b].set(tok_b, mode="drop")
        xp = jnp.concatenate([x_b, jnp.zeros((1, d), x_b.dtype)])
        return table, xp[table]                                  # (E,C),(E,C,d)

    table, xe = jax.vmap(dispatch_one)(e_flat, pos, tok_idx, x)  # batch-local

    g = jnp.einsum("becd,edf->becf", xe, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", xe, p["w_up"])
    h = jax.nn.silu(g.astype(F32)).astype(xe.dtype) * u
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"])            # (B,E,C,d)

    def combine_one(ye_b, e_b, pos_b, w_b, tok_b):
        ys = ye_b[e_b, jnp.minimum(pos_b, C - 1)]                # (S*K, d)
        return jnp.zeros((S, d), ye_b.dtype).at[tok_b].add(
            ys * w_b[:, None])

    w_slot = (top_p.reshape(B, S * K) * keep).astype(ye.dtype)
    y = jax.vmap(combine_one)(ye, e_flat, pos, w_slot, tok_idx)

    f_e = jnp.mean(jax.nn.one_hot(top_e, E, dtype=F32).sum(2), axis=(0, 1))
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f_e / K * p_e)
    return y.astype(x.dtype), aux


def moe_block_apply(cfg, p, x, ctx):
    x = _self_attention(cfg, p, x, ctx, window=cfg.window)
    y, aux = moe_apply(cfg, p["moe"], _norm(cfg, p, "ln_ffn", x))
    return x + y, aux


def moe_block_decode(cfg, p, x, cache, pos, ctx):
    paged = _paged(ctx, cfg.window)
    rolling = (not paged and bool(cfg.window)
               and cache["k"].shape[1] < ctx["max_seq"])
    q, k, v = qkv_project(p["attn"], _norm(cfg, p, "ln_attn", x),
                          ctx["positions"], n_heads=cfg.n_heads,
                          n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                          qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
                          theta=cfg.rope_theta)
    if paged:
        kc, vc = attn_lib.paged_update_cache(cache["k"], cache["v"], k, v,
                                             pos, ctx["pages"])
        o = attn_lib.paged_decode_attention(q, kc, vc, pos + 1, ctx["pages"],
                                            n_kv=cfg.n_kv_heads)
    else:
        kc, vc = attn_lib.update_cache(cache["k"], cache["v"], k, v, pos,
                                       rolling=rolling)
        o = attn_lib.decode_attention(q, kc, vc, pos + 1, n_kv=cfg.n_kv_heads,
                                      window=cfg.window, rolling=rolling)
    x = x + out_project(p["attn"], o)
    y, _ = moe_apply(cfg, p["moe"], _norm(cfg, p, "ln_ffn", x))
    return x + y, {"k": kc, "v": vc}


# ============================================================================
# Cross-attention block ("cross") — llama-3.2-vision image layers
# ============================================================================

def cross_block_specs(cfg) -> dict:
    s = {}
    s |= _norm_specs(cfg, "ln_attn")
    s["attn"] = attn_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                           qk_norm=True)   # llama-3.2 uses q/k norm on cross
    s["gate_attn"] = ParamSpec((1,), ("norm",), dtype=F32, init="zeros")
    s["gate_ffn"] = ParamSpec((1,), ("norm",), dtype=F32, init="zeros")
    s |= _norm_specs(cfg, "ln_ffn")
    s["ffn"] = ffn_specs(cfg.d_model, cfg.d_ff, kind=cfg.ffn_kind)
    return s


def _cross_kv(cfg, p, embeds):
    k = jnp.einsum("bsd,dhk->bshk", embeds, p["attn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", embeds, p["attn"]["wv"])
    k = rms_norm(k, p["attn"]["k_norm"])
    return k, v


def cross_block_apply(cfg, p, x, ctx):
    h = _norm(cfg, p, "ln_attn", x)
    q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"])
    q = rms_norm(q, p["attn"]["q_norm"])
    k, v = _cross_kv(cfg, p, ctx["cross_embeds"])
    o = attn_lib.cross_attention(q, k, v, n_kv=cfg.n_kv_heads,
                                 chunk=cfg.attn_chunk)
    ga = jnp.tanh(p["gate_attn"]).astype(x.dtype)
    gf = jnp.tanh(p["gate_ffn"]).astype(x.dtype)
    x = x + ga * out_project(p["attn"], o)
    y = apply_ffn(p["ffn"], _norm(cfg, p, "ln_ffn", x), kind=cfg.ffn_kind)
    x = x + gf * y
    return x, 0.0


def cross_cache_specs(cfg, B: int, cache_len: int) -> dict:
    n_ctx = cfg.n_img_tokens or cfg.enc_seq
    return {
        "k": ParamSpec((B, n_ctx, cfg.n_kv_heads, cfg.hd),
                       ("batch", None, "kv_heads", None), init="zeros"),
        "v": ParamSpec((B, n_ctx, cfg.n_kv_heads, cfg.hd),
                       ("batch", None, "kv_heads", None), init="zeros"),
    }


def cross_block_decode(cfg, p, x, cache, pos, ctx):
    h = _norm(cfg, p, "ln_attn", x)
    q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"])
    q = rms_norm(q, p["attn"]["q_norm"])
    kc, vc = cache["k"], cache["v"]
    n_ctx = kc.shape[1]
    o = attn_lib.decode_attention(q, kc, vc, n_ctx, n_kv=cfg.n_kv_heads)
    ga = jnp.tanh(p["gate_attn"]).astype(x.dtype)
    gf = jnp.tanh(p["gate_ffn"]).astype(x.dtype)
    x = x + ga * out_project(p["attn"], o)
    y = apply_ffn(p["ffn"], _norm(cfg, p, "ln_ffn", x), kind=cfg.ffn_kind)
    x = x + gf * y
    return x, cache


# ============================================================================
# Whisper decoder block ("attn_cross"): self + cross + MLP
# ============================================================================

def attn_cross_block_specs(cfg) -> dict:
    s = {}
    s |= _norm_specs(cfg, "ln_self")
    s["self"] = attn_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                           qkv_bias=cfg.qkv_bias)
    s |= _norm_specs(cfg, "ln_cross")
    s["cross"] = attn_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                            qkv_bias=cfg.qkv_bias)
    s |= _norm_specs(cfg, "ln_ffn")
    s["ffn"] = ffn_specs(cfg.d_model, cfg.d_ff, kind=cfg.ffn_kind)
    return s


def _ln(cfg, p, stem, x):
    return layer_norm(x, p[stem + "_s"], p[stem + "_b"]) if cfg.norm == "layer" \
        else rms_norm(x, p[stem])


def attn_cross_block_apply(cfg, p, x, ctx):
    # self attention (causal, no rope — whisper uses learned positions)
    q, k, v = qkv_project(p["self"], _ln(cfg, p, "ln_self", x),
                          ctx["positions"], n_heads=cfg.n_heads,
                          n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                          qkv_bias=cfg.qkv_bias, rope=False)
    o = attn_lib.attention(q, k, v, n_kv=cfg.n_kv_heads, causal=True,
                           chunk=cfg.attn_chunk, schedule=cfg.attn_schedule)
    x = x + out_project(p["self"], o)
    # cross attention to encoder output
    h = _ln(cfg, p, "ln_cross", x)
    qc = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["wq"])
    if cfg.qkv_bias:
        qc = qc + p["cross"]["bq"]
    kc = jnp.einsum("bsd,dhk->bshk", ctx["cross_embeds"], p["cross"]["wk"])
    vc = jnp.einsum("bsd,dhk->bshk", ctx["cross_embeds"], p["cross"]["wv"])
    if cfg.qkv_bias:
        kc, vc = kc + p["cross"]["bk"], vc + p["cross"]["bv"]
    o = attn_lib.cross_attention(qc, kc, vc, n_kv=cfg.n_kv_heads,
                                 chunk=cfg.attn_chunk)
    x = x + out_project(p["cross"], o)
    x = x + apply_ffn(p["ffn"], _ln(cfg, p, "ln_ffn", x), kind=cfg.ffn_kind)
    return x, 0.0


def attn_cross_cache_specs(cfg, B: int, cache_len: int) -> dict:
    self_c = attn_cache_specs(cfg, B, cache_len)
    return {"self_k": self_c["k"], "self_v": self_c["v"],
            "cross_k": ParamSpec((B, cfg.enc_seq, cfg.n_kv_heads, cfg.hd),
                                 ("batch", None, "kv_heads", None), init="zeros"),
            "cross_v": ParamSpec((B, cfg.enc_seq, cfg.n_kv_heads, cfg.hd),
                                 ("batch", None, "kv_heads", None), init="zeros")}


def attn_cross_block_decode(cfg, p, x, cache, pos, ctx):
    q, k, v = qkv_project(p["self"], _ln(cfg, p, "ln_self", x),
                          ctx["positions"], n_heads=cfg.n_heads,
                          n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                          qkv_bias=cfg.qkv_bias, rope=False)
    if _paged(ctx, None):
        kc, vc = attn_lib.paged_update_cache(cache["self_k"], cache["self_v"],
                                             k, v, pos, ctx["pages"])
        o = attn_lib.paged_decode_attention(q, kc, vc, pos + 1, ctx["pages"],
                                            n_kv=cfg.n_kv_heads)
    else:
        kc, vc = attn_lib.update_cache(cache["self_k"], cache["self_v"],
                                       k, v, pos)
        o = attn_lib.decode_attention(q, kc, vc, pos + 1, n_kv=cfg.n_kv_heads)
    x = x + out_project(p["self"], o)
    h = _ln(cfg, p, "ln_cross", x)
    qc = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["wq"])
    if cfg.qkv_bias:
        qc = qc + p["cross"]["bq"]
    o = attn_lib.decode_attention(qc, cache["cross_k"], cache["cross_v"],
                                  cfg.enc_seq, n_kv=cfg.n_kv_heads)
    x = x + out_project(p["cross"], o)
    x = x + apply_ffn(p["ffn"], _ln(cfg, p, "ln_ffn", x), kind=cfg.ffn_kind)
    return x, {"self_k": kc, "self_v": vc,
               "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}


# ============================================================================
# Encoder block ("enc_attn") — bidirectional (whisper encoder)
# ============================================================================

def enc_attn_block_specs(cfg) -> dict:
    s = {}
    s |= _norm_specs(cfg, "ln_attn")
    s["attn"] = attn_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                           qkv_bias=cfg.qkv_bias)
    s |= _norm_specs(cfg, "ln_ffn")
    s["ffn"] = ffn_specs(cfg.d_model, cfg.d_ff, kind=cfg.ffn_kind)
    return s


def enc_attn_block_apply(cfg, p, x, ctx):
    q, k, v = qkv_project(p["attn"], _norm(cfg, p, "ln_attn", x),
                          ctx["positions"], n_heads=cfg.n_heads,
                          n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                          qkv_bias=cfg.qkv_bias, rope=False)
    o = attn_lib.attention(q, k, v, n_kv=cfg.n_kv_heads, causal=False,
                           schedule="direct")
    x = x + out_project(p["attn"], o)
    x = _ffn_residual(cfg, p, x)
    return x, 0.0


# ============================================================================
# RG-LRU recurrent block ("rglru") — RecurrentGemma / Griffin
# ============================================================================

def rglru_block_specs(cfg) -> dict:
    d, r = cfg.d_model, cfg.lru_width
    s = {}
    s |= _norm_specs(cfg, "ln_rec")
    s["w_x"] = ParamSpec((d, r), ("embed", "ffn"))
    s["w_gate"] = ParamSpec((d, r), ("embed", "ffn"))
    s["conv_w"] = ParamSpec((cfg.conv_width, r), ("conv", "ffn"), scale=0.5)
    s["w_ra"] = ParamSpec((r, r), ("ffn", None))       # recurrence gate
    s["b_ra"] = ParamSpec((r,), ("ffn",), init="zeros")
    s["w_ix"] = ParamSpec((r, r), ("ffn", None))       # input gate
    s["b_ix"] = ParamSpec((r,), ("ffn",), init="zeros")
    s["lam"] = ParamSpec((r,), ("ffn",), dtype=F32, init="ones", scale=1.0)
    s["w_out"] = ParamSpec((r, d), ("ffn", "embed"))
    s |= _norm_specs(cfg, "ln_ffn")
    s["ffn"] = ffn_specs(cfg.d_model, cfg.d_ff, kind=cfg.ffn_kind)
    return s


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x: (B,S,r); w: (W,r); state: (B,W-1,r)|None."""
    W = w.shape[0]
    if state is None:
        pads = [jnp.pad(x, ((0, 0), (W - 1 - i, 0), (0, 0)))[:, :x.shape[1]]
                for i in range(W)]
    else:
        ext = jnp.concatenate([state, x], axis=1)
        pads = [ext[:, i:i + x.shape[1]] for i in range(W)]
    y = sum(p * w[i] for i, p in enumerate(pads))
    new_state = (jnp.concatenate([state, x], axis=1)[:, -(W - 1):]
                 if state is not None else None)
    return y, new_state


def _rglru_gates(p, u):
    r = jax.nn.sigmoid(jnp.einsum("...r,rs->...s", u, p["w_ra"]).astype(F32)
                       + p["b_ra"])
    i = jax.nn.sigmoid(jnp.einsum("...r,rs->...s", u, p["w_ix"]).astype(F32)
                       + p["b_ix"])
    log_a = -8.0 * jax.nn.softplus(p["lam"]) * r          # log a_t  (< 0)
    return log_a, i


def rglru_block_apply(cfg, p, x, ctx):
    h = _norm(cfg, p, "ln_rec", x)
    gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", h, p["w_gate"]).astype(F32))
    u = jnp.einsum("bsd,dr->bsr", h, p["w_x"])
    u, _ = _causal_conv(u, p["conv_w"])
    log_a, i_gate = _rglru_gates(p, u)                    # (B,S,r) fp32
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) \
        * (i_gate * u.astype(F32))
    # linear recurrence h_t = a_t h_{t-1} + b_t via associative scan over S
    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2
    _, states = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (gate * states).astype(x.dtype)
    x = x + jnp.einsum("bsr,rd->bsd", y, p["w_out"])
    x = _ffn_residual(cfg, p, x)
    return x, 0.0


def rglru_cache_specs(cfg, B: int, cache_len: int) -> dict:
    r = cfg.lru_width
    return {"h": ParamSpec((B, r), ("batch", "ffn"), dtype=F32, init="zeros"),
            "conv": ParamSpec((B, cfg.conv_width - 1, r),
                              ("batch", None, "ffn"), init="zeros")}


def rglru_block_decode(cfg, p, x, cache, pos, ctx):
    h = _norm(cfg, p, "ln_rec", x)                         # (B,1,d)
    gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", h, p["w_gate"]).astype(F32))
    u = jnp.einsum("bsd,dr->bsr", h, p["w_x"])
    u, conv_state = _causal_conv(u, p["conv_w"], cache["conv"])
    log_a, i_gate = _rglru_gates(p, u)
    a = jnp.exp(log_a)[:, 0]
    b = (jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
         * (i_gate * u.astype(F32)))[:, 0]
    h_new = a * cache["h"] + b                             # (B,r)
    y = (gate[:, 0] * h_new).astype(x.dtype)[:, None]
    x = x + jnp.einsum("bsr,rd->bsd", y, p["w_out"])
    x = _ffn_residual(cfg, p, x)
    return x, {"h": h_new, "conv": conv_state}


# ============================================================================
# mLSTM block — xLSTM matrix-memory (chunked parallel form)
# ============================================================================

def mlstm_block_specs(cfg) -> dict:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    di = H * hd
    s = {}
    s |= _norm_specs(cfg, "ln")
    s["w_up"] = ParamSpec((d, 2 * di), ("embed", "ffn"))
    s["conv_w"] = ParamSpec((cfg.conv_width, di), ("conv", "ffn"), scale=0.5)
    s["wq"] = ParamSpec((di, H, hd), ("ffn", "heads", None))
    s["wk"] = ParamSpec((di, H, hd), ("ffn", "heads", None))
    s["wv"] = ParamSpec((di, H, hd), ("ffn", "heads", None))
    s["w_i"] = ParamSpec((di, H), ("ffn", "heads"), dtype=F32)
    s["b_i"] = ParamSpec((H,), ("heads",), dtype=F32, init="zeros")
    s["w_f"] = ParamSpec((di, H), ("ffn", "heads"), dtype=F32)
    s["b_f"] = ParamSpec((H,), ("heads",), dtype=F32, init="ones", scale=1.0)
    s["ogate_ln"] = ParamSpec((H, hd), ("heads", None), init="zeros")
    s["w_down"] = ParamSpec((di, d), ("ffn", "embed"))
    return s


def _mlstm_qkvif(cfg, p, x):
    """Shared projections. x: (B,S,d) -> q,k,v (B,S,H,hd); li,lf (B,S,H) f32."""
    h = _norm(cfg, p, "ln", x)
    up = jnp.einsum("bsd,de->bse", h, p["w_up"])
    gate, main = jnp.split(up, 2, axis=-1)
    main, _ = _causal_conv(main, p["conv_w"])
    main = jax.nn.silu(main.astype(F32)).astype(x.dtype)
    q = jnp.einsum("bse,ehk->bshk", main, p["wq"])
    k = jnp.einsum("bse,ehk->bshk", main, p["wk"])
    v = jnp.einsum("bse,ehk->bshk", main, p["wv"])
    li = jnp.einsum("bse,eh->bsh", main.astype(F32), p["w_i"]) + p["b_i"]
    lf = jax.nn.log_sigmoid(
        jnp.einsum("bse,eh->bsh", main.astype(F32), p["w_f"]) + p["b_f"])
    return gate, q, k, v, li, lf


def _mlstm_chunk(q, k, v, li, lf, C0, n0, m0, scale):
    """One chunk of the stabilized chunkwise mLSTM.

    q,k,v: (B,c,H,hd); li,lf: (B,c,H) log gates; carried state
    C0: (B,H,hd,hd), n0: (B,H,hd), m0: (B,H). Returns (h, C1, n1, m1).
    """
    B, c, H, hd = q.shape
    F = jnp.cumsum(lf, axis=1)                                  # (B,c,H)
    # intra-chunk decay matrix D[t,s] = F_t - F_s + li_s for s<=t
    Ft = F[:, :, None, :]
    Fs = F[:, None, :, :]
    D = Ft - Fs + li[:, None, :, :]                             # (B,t,s,H)
    tri = jnp.tril(jnp.ones((c, c), bool))
    D = jnp.where(tri[None, :, :, None], D, -jnp.inf)
    m_intra = D.max(axis=2)                                     # (B,t,H)
    m_inter = m0[:, None, :] + F                                # (B,t,H)
    m_t = jnp.maximum(m_intra, m_inter)
    m_t = jnp.maximum(m_t, -1e30)                               # keep finite

    qs = q.astype(F32) * scale
    sc = jnp.einsum("bthd,bshd->btsh", qs, k.astype(F32))       # (B,t,s,H)
    # D: (B,t,s,H); m_t: (B,t,H) -> broadcast over s
    w = jnp.exp(D - m_t[:, :, None, :])
    scw = sc * w   # explicit pairwise product: a 3-operand einsum here can
    #                materialize a (B,t,s,H,hd) intermediate (hundreds of GB)
    h_intra = jnp.einsum("btsh,bshd->bthd", scw, v.astype(F32))
    n_intra = jnp.einsum("btsh,bshd->bthd", scw, k.astype(F32))

    dec = jnp.exp(m_inter - m_t)                                # (B,t,H)
    h_inter = jnp.einsum("bthd,bhde->bthe", qs, C0) * dec[..., None]
    n_inter = jnp.einsum("bthd,bhd->bth", qs, n0) * dec

    num = h_intra + h_inter                                     # (B,t,H,hd)
    qn = jnp.einsum("bthd,bthd->bth", qs, n_intra) + n_inter
    den = jnp.maximum(jnp.abs(qn), jnp.exp(-m_t))
    h = num / den[..., None]

    # chunk-end state
    F_tot = F[:, -1, :]                                         # (B,H)
    m_kv = (F_tot[:, None, :] - F + li)                         # (B,s,H)
    m1 = jnp.maximum(m0 + F_tot, m_kv.max(axis=1))
    w_kv = jnp.exp(m_kv - m1[:, None, :])
    C1 = (jnp.exp(m0 + F_tot - m1)[:, :, None, None] * C0
          + jnp.einsum("bsh,bshd,bshe->bhde", w_kv, k.astype(F32), v.astype(F32)))
    n1 = (jnp.exp(m0 + F_tot - m1)[:, :, None] * n0
          + jnp.einsum("bsh,bshd->bhd", w_kv, k.astype(F32)))
    return h, C1, n1, m1


def mlstm_block_apply(cfg, p, x, ctx):
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    gate, q, k, v, li, lf = _mlstm_qkvif(cfg, p, x)
    scale = hd ** -0.5
    c = min(cfg.attn_chunk, S)
    nc = S // c

    def chunk_step(carry, blk):
        C0, n0, m0 = carry
        qb, kb, vb, lib, lfb = blk
        h, C1, n1, m1 = _mlstm_chunk(qb, kb, vb, lib, lfb, C0, n0, m0, scale)
        return (C1, n1, m1), h

    split = lambda a: jnp.moveaxis(
        a.reshape(B, nc, c, *a.shape[2:]), 1, 0)
    C0 = shard_batch(jnp.zeros((B, H, hd, hd), F32))
    n0 = shard_batch(jnp.zeros((B, H, hd), F32))
    m0 = shard_batch(jnp.full((B, H), -1e30, F32))
    _, hs = jax.lax.scan(chunk_step, (C0, n0, m0),
                         (split(q), split(k), split(v), split(li), split(lf)))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, hd)             # fp32
    h = rms_norm(h.astype(x.dtype), p["ogate_ln"])
    h = h.reshape(B, S, H * hd) * jax.nn.silu(gate.astype(F32)).astype(x.dtype)
    return x + jnp.einsum("bse,ed->bsd", h, p["w_down"]), 0.0


def mlstm_cache_specs(cfg, B: int, cache_len: int) -> dict:
    H, hd = cfg.n_heads, cfg.hd
    di = H * hd
    return {"C": ParamSpec((B, H, hd, hd), ("batch", "heads", None, None),
                           dtype=F32, init="zeros"),
            "n": ParamSpec((B, H, hd), ("batch", "heads", None), dtype=F32,
                           init="zeros"),
            "m": ParamSpec((B, H), ("batch", "heads"), dtype=F32, init="zeros"),
            "conv": ParamSpec((B, cfg.conv_width - 1, di),
                              ("batch", None, "ffn"), init="zeros")}


def mlstm_block_decode(cfg, p, x, cache, pos, ctx):
    B = x.shape[0]
    H, hd = cfg.n_heads, cfg.hd
    h0 = _norm(cfg, p, "ln", x)
    up = jnp.einsum("bsd,de->bse", h0, p["w_up"])
    gate, main = jnp.split(up, 2, axis=-1)
    main, conv_state = _causal_conv(main, p["conv_w"], cache["conv"])
    main = jax.nn.silu(main.astype(F32)).astype(x.dtype)
    q = jnp.einsum("bse,ehk->bshk", main, p["wq"])[:, 0]
    k = jnp.einsum("bse,ehk->bshk", main, p["wk"])[:, 0]
    v = jnp.einsum("bse,ehk->bshk", main, p["wv"])[:, 0]
    li = (jnp.einsum("bse,eh->bsh", main.astype(F32), p["w_i"]) + p["b_i"])[:, 0]
    lf = jax.nn.log_sigmoid(
        jnp.einsum("bse,eh->bsh", main.astype(F32), p["w_f"]) + p["b_f"])[:, 0]
    m1 = jnp.maximum(lf + cache["m"], li)
    fd = jnp.exp(lf + cache["m"] - m1)
    idc = jnp.exp(li - m1)
    C1 = fd[..., None, None] * cache["C"] + idc[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", k.astype(F32), v.astype(F32))
    n1 = fd[..., None] * cache["n"] + idc[..., None] * k.astype(F32)
    qs = q.astype(F32) * (hd ** -0.5)
    num = jnp.einsum("bhd,bhde->bhe", qs, C1)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qs, n1)),
                      jnp.exp(-m1))
    h = (num / den[..., None])[:, None]                          # (B,1,H,hd)
    h = rms_norm(h.astype(x.dtype), p["ogate_ln"])
    h = h.reshape(B, 1, H * hd) * jax.nn.silu(gate.astype(F32)).astype(x.dtype)
    x = x + jnp.einsum("bse,ed->bsd", h, p["w_down"])
    return x, {"C": C1, "n": n1, "m": m1, "conv": conv_state}


# ============================================================================
# sLSTM block — xLSTM scalar-memory (sequential scan; not parallelizable)
# ============================================================================

def slstm_block_specs(cfg) -> dict:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    di = H * hd
    s = {}
    s |= _norm_specs(cfg, "ln")
    s["w_in"] = ParamSpec((d, 4 * di), ("embed", "ffn"))       # i,f,z,o
    s["r_h"] = ParamSpec((4, H, hd, hd), (None, "heads", None, None))
    s["b"] = ParamSpec((4 * di,), ("ffn",), init="zeros")
    s["w_out"] = ParamSpec((di, d), ("ffn", "embed"))
    return s


def _slstm_scan(cfg, p, z_in, c0, n0, m0, h0):
    """z_in: (B,S,4*di). Sequential over S. Returns (h_seq, final_state)."""
    B, S, _ = z_in.shape
    H, hd = cfg.n_heads, cfg.hd

    def step(carry, zt):
        c, n, m, h = carry                                  # (B,H,hd) each; m too
        rec = jnp.einsum("bhd,ghde->bghe", h, p["r_h"].astype(F32))
        zt = zt.reshape(B, 4, H, hd).astype(F32) + rec
        i_r, f_r, z_r, o_r = zt[:, 0], zt[:, 1], zt[:, 2], zt[:, 3]
        lf = jax.nn.log_sigmoid(f_r)
        m1 = jnp.maximum(lf + m, i_r)
        fd = jnp.exp(lf + m - m1)
        idc = jnp.exp(i_r - m1)
        c1 = fd * c + idc * jnp.tanh(z_r)
        n1 = fd * n + idc
        h1 = jax.nn.sigmoid(o_r) * c1 / jnp.maximum(n1, 1e-6)
        return (c1, n1, m1, h1), h1

    zs = jnp.moveaxis(z_in, 1, 0)                           # (S,B,4di)
    (c, n, m, h), hs = jax.lax.scan(step, (c0, n0, m0, h0), zs)
    return jnp.moveaxis(hs, 0, 1), (c, n, m, h)             # (B,S,H,hd)


def slstm_block_apply(cfg, p, x, ctx):
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    z_in = jnp.einsum("bsd,de->bse", _norm(cfg, p, "ln", x), p["w_in"]) + p["b"]
    zero = shard_batch(jnp.zeros((B, H, hd), F32))
    hs, _ = _slstm_scan(cfg, p, z_in, zero, zero, zero - 1e30, zero)
    y = hs.reshape(B, S, H * hd).astype(x.dtype)
    return x + jnp.einsum("bse,ed->bsd", y, p["w_out"]), 0.0


def slstm_cache_specs(cfg, B: int, cache_len: int) -> dict:
    H, hd = cfg.n_heads, cfg.hd
    mk = lambda: ParamSpec((B, H, hd), ("batch", "heads", None), dtype=F32,
                           init="zeros")
    return {"c": mk(), "n": mk(), "m": mk(), "h": mk()}


def slstm_block_decode(cfg, p, x, cache, pos, ctx):
    B = x.shape[0]
    z_in = jnp.einsum("bsd,de->bse", _norm(cfg, p, "ln", x), p["w_in"]) + p["b"]
    hs, (c, n, m, h) = _slstm_scan(cfg, p, z_in, cache["c"], cache["n"],
                                   cache["m"], cache["h"])
    y = hs[:, -1:].reshape(B, 1, -1).astype(x.dtype)
    x = x + jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return x, {"c": c, "n": n, "m": m, "h": h}


# ============================================================================
# Kind registry
# ============================================================================

BLOCKS: dict[str, dict[str, Any]] = {
    "attn": dict(specs=attn_block_specs, apply=attn_block_apply,
                 cache=attn_cache_specs, decode=attn_block_decode),
    "local_attn": dict(
        specs=attn_block_specs,
        apply=lambda cfg, p, x, ctx: attn_block_apply(cfg, p, x, ctx,
                                                      window=cfg.window),
        cache=lambda cfg, B, L: attn_cache_specs(
            cfg, B, min(L, cfg.window or L)),
        decode=lambda cfg, p, x, c, pos, ctx: attn_block_decode(
            cfg, p, x, c, pos, ctx, window=cfg.window)),
    "attn_moe": dict(specs=moe_block_specs, apply=moe_block_apply,
                     cache=lambda cfg, B, L: attn_cache_specs(
                         cfg, B, min(L, cfg.window or L)),
                     decode=moe_block_decode),
    "cross": dict(specs=cross_block_specs, apply=cross_block_apply,
                  cache=cross_cache_specs, decode=cross_block_decode),
    "attn_cross": dict(specs=attn_cross_block_specs,
                       apply=attn_cross_block_apply,
                       cache=attn_cross_cache_specs,
                       decode=attn_cross_block_decode),
    "enc_attn": dict(specs=enc_attn_block_specs, apply=enc_attn_block_apply,
                     cache=None, decode=None),
    "rglru": dict(specs=rglru_block_specs, apply=rglru_block_apply,
                  cache=rglru_cache_specs, decode=rglru_block_decode),
    "mlstm": dict(specs=mlstm_block_specs, apply=mlstm_block_apply,
                  cache=mlstm_cache_specs, decode=mlstm_block_decode),
    "slstm": dict(specs=slstm_block_specs, apply=slstm_block_apply,
                  cache=slstm_cache_specs, decode=slstm_block_decode),
}
