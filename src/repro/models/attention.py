"""Attention with bounded memory, static shapes, and a flash-style VJP.

All variants are pure jnp (they must lower for the 512-chip CPU-hosted
dry-run; the Pallas flash kernel in kernels/flash_attention.py is the TPU
hot-spot implementation, validated against these in interpret mode).

Forward schedules (picked by `schedule=` or automatically):

  direct  — materialize (S x S) scores; only for small S (smoke tests).
  masked  — two-level scan over (q-chunk x kv-chunk) blocks with causal
            masking. Memory-bounded, but computes the full upper triangle
            and masks it: ~2x FLOP waste. This is the *baseline*.
  folded  — exact-causal balanced schedule: q-chunk i is folded with
            q-chunk nq-1-i so every fold processes exactly nq+1 kv blocks
            (the ring-attention load-balancing trick). ~0 wasted FLOPs.
            This is the §Perf "beyond-paper" optimization.
  banded  — sliding-window attention: each q chunk scans only the
            window/chunk + 1 kv blocks in its band. Exact for SWA and
            local attention; O(S*w) instead of O(S^2).

Backward: a shared custom_vjp in the FlashAttention style — only
(q, k, v, out, lse) are saved and score blocks are *recomputed* per (i, j)
pair. Without this, jax.lax.scan's backward stacks every block's scores
across iterations: O(S^2) residual memory (observed: 10 GiB buffers per
layer at S=4096), which no remat policy can prevent.

GQA is computed in grouped form (no materialized KV repetition).
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.overlap import shard_batch

NEG = -1e30
F32 = jnp.float32


def _group(q, n_kv: int):
    """(B, S, H, hd) -> (B, S, KV, G, hd)."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, hd)


def _split_chunks(x, chunk: int):
    """(B, S, ...) -> (nc, B, chunk, ...)."""
    b, s = x.shape[:2]
    n = s // chunk
    x = x.reshape(b, n, chunk, *x.shape[2:])
    return jnp.moveaxis(x, 1, 0)


def _block_attn(q, k, v, bias, m, l, acc, scale):
    """One online-softmax block update.

    q: (B, c, KV, G, hd); k/v: (B, s, KV, hd); bias: (c, s) additive;
    m, l: (B, KV, G, c) fp32; acc: (B, KV, G, c, hd) fp32.
    """
    s_blk = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                       preferred_element_type=F32)
    s_blk = s_blk * scale + bias
    m_new = jnp.maximum(m, s_blk.max(axis=-1))
    p = jnp.exp(s_blk - m_new[..., None])
    alpha = jnp.exp(m - m_new)
    l_new = l * alpha + p.sum(axis=-1)
    pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v.dtype), v,
                    preferred_element_type=F32)
    acc_new = acc * alpha[..., None] + pv
    return m_new, l_new, acc_new


def _finish(acc, m, l, dtype):
    """-> out (B, c, H, hd), lse (B, KV, G, c)."""
    out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B, KV, G, c, hd)
    out = jnp.moveaxis(out, 3, 1)                  # (B, c, KV, G, hd)
    b, c = out.shape[:2]
    out = out.reshape(b, c, -1, out.shape[-1]).astype(dtype)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    return out, lse


def _causal_bias(c: int, qi, kj, window: int | None):
    """(c, c) additive bias for q chunk index qi vs kv chunk index kj."""
    qpos = qi * c + jnp.arange(c)[:, None]
    kpos = kj * c + jnp.arange(c)[None, :]
    ok = kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, NEG).astype(F32)


# ----------------------------------------------------------------------------
# direct (small S) — plain autodiff
# ----------------------------------------------------------------------------

def direct_attention(q, k, v, *, n_kv: int, causal: bool = True,
                     window: int | None = None):
    b, s, h, hd = q.shape
    scale = hd ** -0.5
    qg = _group(q, n_kv)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=F32) * scale
    if causal:
        qpos = jnp.arange(s)[:, None]
        kpos = jnp.arange(s)[None, :]
        ok = kpos <= qpos
        if window is not None:
            ok &= kpos > qpos - window
        scores = jnp.where(ok, scores, NEG)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return out.reshape(b, s, h, hd)


# ----------------------------------------------------------------------------
# chunked forward schedules (shared by the custom VJP)
# ----------------------------------------------------------------------------

def _fwd_masked(q, k, v, n_kv, chunk, window):
    b, s, h, hd = q.shape
    scale = hd ** -0.5
    nq = s // chunk
    qg = _split_chunks(_group(q, n_kv), chunk)   # (nq, B, c, KV, G, hd)
    kc = _split_chunks(k, chunk)                 # (nq, B, c, KV, hd)
    vc = _split_chunks(v, chunk)
    g = h // n_kv

    def q_step(_, qi_and_chunk):
        qi, q_blk = qi_and_chunk
        m0 = shard_batch(jnp.full((b, n_kv, g, chunk), NEG, F32))
        l0 = shard_batch(jnp.zeros((b, n_kv, g, chunk), F32))
        a0 = shard_batch(jnp.zeros((b, n_kv, g, chunk, hd), F32))

        def kv_step(carry, kj_and_kv):
            m, l, acc = carry
            kj, k_blk, v_blk = kj_and_kv
            bias = _causal_bias(chunk, qi, kj, window)
            m, l, acc = _block_attn(q_blk, k_blk, v_blk, bias, m, l, acc, scale)
            return (m, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nq), kc, vc))
        return None, _finish(acc, m, l, q.dtype)

    _, (out, lse) = jax.lax.scan(q_step, None, (jnp.arange(nq), qg))
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, h, hd)
    return out, lse                               # lse: (nq, B, KV, G, c)


def _fwd_banded(q, k, v, n_kv, chunk, window):
    b, s, h, hd = q.shape
    scale = hd ** -0.5
    nq = s // chunk
    nband = min(window // chunk + 1, nq)
    qg = _split_chunks(_group(q, n_kv), chunk)
    kc = _split_chunks(k, chunk)
    vc = _split_chunks(v, chunk)
    g = h // n_kv

    def q_step(_, qi_and_chunk):
        qi, q_blk = qi_and_chunk
        m0 = shard_batch(jnp.full((b, n_kv, g, chunk), NEG, F32))
        l0 = shard_batch(jnp.zeros((b, n_kv, g, chunk), F32))
        a0 = shard_batch(jnp.zeros((b, n_kv, g, chunk, hd), F32))

        def band_step(carry, t):
            m, l, acc = carry
            kj = jnp.clip(qi - nband + 1 + t, 0, nq - 1)
            k_blk = jax.lax.dynamic_index_in_dim(kc, kj, 0, keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(vc, kj, 0, keepdims=False)
            bias = _causal_bias(chunk, qi, kj, window)
            dup = qi - nband + 1 + t < 0               # clipped duplicate
            bias = jnp.where(dup, NEG, bias)
            m, l, acc = _block_attn(q_blk, k_blk, v_blk, bias, m, l, acc, scale)
            return (m, l, acc), None

        (m, l, acc), _ = jax.lax.scan(band_step, (m0, l0, a0),
                                      jnp.arange(nband))
        return None, _finish(acc, m, l, q.dtype)

    _, (out, lse) = jax.lax.scan(q_step, None, (jnp.arange(nq), qg))
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, hd), lse


def _fwd_folded(q, k, v, n_kv, chunk):
    """Exact-causal: fold q chunk i with q chunk nq-1-i; each fold scans
    exactly nq+1 kv blocks, none wasted. Requires nq even."""
    b, s, h, hd = q.shape
    scale = hd ** -0.5
    nq = s // chunk
    qg = _split_chunks(_group(q, n_kv), chunk)
    kc = _split_chunks(k, chunk)
    vc = _split_chunks(v, chunk)
    g = h // n_kv
    acc_shape = (b, n_kv, g, chunk)

    def fold_step(_, f):
        lo, hi = f, nq - 1 - f
        q_lo, q_hi = qg[lo], qg[hi]
        state = tuple(jnp.full(acc_shape, NEG, F32) for _ in range(2)) + \
                tuple(jnp.zeros(acc_shape, F32) for _ in range(2)) + \
                tuple(jnp.zeros(acc_shape + (hd,), F32) for _ in range(2))
        state = tuple(shard_batch(x) for x in state)

        def t_step(carry, t):
            m_lo, m_hi, l_lo, l_hi, a_lo, a_hi = carry
            use_lo = t <= lo
            kj = jnp.where(use_lo, t, t - lo - 1)
            k_blk = jax.lax.dynamic_index_in_dim(kc, kj, 0, keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(vc, kj, 0, keepdims=False)
            q_blk = jnp.where(use_lo, q_lo, q_hi)
            qi = jnp.where(use_lo, lo, hi)
            bias = _causal_bias(chunk, qi, kj, None)
            m_in = jnp.where(use_lo, m_lo, m_hi)
            l_in = jnp.where(use_lo, l_lo, l_hi)
            a_in = jnp.where(use_lo, a_lo, a_hi)
            m, l, acc = _block_attn(q_blk, k_blk, v_blk, bias, m_in, l_in,
                                    a_in, scale)
            m_lo = jnp.where(use_lo, m, m_lo)
            l_lo = jnp.where(use_lo, l, l_lo)
            a_lo = jnp.where(use_lo, acc, a_lo)
            m_hi = jnp.where(use_lo, m_hi, m)
            l_hi = jnp.where(use_lo, l_hi, l)
            a_hi = jnp.where(use_lo, a_hi, acc)
            return (m_lo, m_hi, l_lo, l_hi, a_lo, a_hi), None

        (m_lo, m_hi, l_lo, l_hi, a_lo, a_hi), _ = jax.lax.scan(
            t_step, state, jnp.arange(nq + 1))
        return None, (_finish(a_lo, m_lo, l_lo, q.dtype),
                      _finish(a_hi, m_hi, l_hi, q.dtype))

    _, ((out_lo, lse_lo), (out_hi, lse_hi)) = jax.lax.scan(
        fold_step, None, jnp.arange(nq // 2))
    out = jnp.concatenate([out_lo, out_hi[::-1]], axis=0)   # (nq, B, c, H, hd)
    lse = jnp.concatenate([lse_lo, lse_hi[::-1]], axis=0)
    b_ = out.shape[1]
    out = jnp.moveaxis(out, 0, 1).reshape(b_, s, h, hd)
    return out, lse


# ----------------------------------------------------------------------------
# flash-style custom VJP shared by every causal chunked schedule
# ----------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _flash(n_kv: int, chunk: int, window, schedule: str, q, k, v):
    out, _ = _flash_fwd_inner(n_kv, chunk, window, schedule, q, k, v)
    return out


def _flash_fwd_inner(n_kv, chunk, window, schedule, q, k, v):
    if schedule == "folded":
        return _fwd_folded(q, k, v, n_kv, chunk)
    if schedule == "banded":
        return _fwd_banded(q, k, v, n_kv, chunk, window)
    return _fwd_masked(q, k, v, n_kv, chunk, window)


def _flash_fwd(n_kv, chunk, window, schedule, q, k, v):
    out, lse = _flash_fwd_inner(n_kv, chunk, window, schedule, q, k, v)
    return out, (q, k, v, out, lse)


def _flash_bwd(n_kv, chunk, window, schedule, res, dout):
    """FlashAttention-style backward: recompute score blocks per (i, j).

    Saves only linear-in-S residuals. Accumulates dk/dv into full-length
    fp32 buffers via in-place slice updates; dq is emitted per q chunk.
    """
    q, k, v, out, lse = res
    b, s, h, hd = q.shape
    scale = hd ** -0.5
    nq = s // chunk
    g = h // n_kv
    qg = _split_chunks(_group(q, n_kv), chunk)      # (nq, B, c, KV, G, hd)
    og = _split_chunks(_group(out, n_kv), chunk)
    dog = _split_chunks(_group(dout, n_kv), chunk)
    kc = _split_chunks(k, chunk)                    # (nq, B, c, KV, hd)
    vc = _split_chunks(v, chunk)
    if window is not None and schedule == "banded":
        nband = min(window // chunk + 1, nq)
    else:
        nband = nq

    dk0 = shard_batch(jnp.zeros((b, s, n_kv, hd), F32))
    dv0 = shard_batch(jnp.zeros((b, s, n_kv, hd), F32))

    def q_step(carry, xs):
        dk_full, dv_full = carry
        qi, q_blk, o_blk, do_blk, lse_blk = xs
        # D_i = rowsum(dout * out): (B, c, KV, G) -> (B, KV, G, c)
        D = jnp.einsum("bqkgd,bqkgd->bkgq", do_blk.astype(F32),
                       o_blk.astype(F32))
        dq0 = shard_batch(jnp.zeros((b, chunk, n_kv, g, hd), F32))

        def kv_step(inner, t):
            dq_acc, dk_full, dv_full = inner
            kj = jnp.clip(qi - nband + 1 + t, 0, nq - 1) if nband < nq else t
            k_blk = jax.lax.dynamic_index_in_dim(kc, kj, 0, keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(vc, kj, 0, keepdims=False)
            bias = _causal_bias(chunk, qi, kj, window)
            if nband < nq:
                dup = qi - nband + 1 + t < 0
                bias = jnp.where(dup, NEG, bias)
            s_blk = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, k_blk,
                               preferred_element_type=F32) * scale + bias
            p = jnp.exp(s_blk - lse_blk[..., None])          # (B,KV,G,c,s)
            dv_c = jnp.einsum("bkgqs,bqkgd->bskd", p,
                              do_blk.astype(F32))
            dp = jnp.einsum("bqkgd,bskd->bkgqs", do_blk, v_blk,
                            preferred_element_type=F32)
            ds = p * (dp - D[..., None]) * scale             # (B,KV,G,c,s)
            dq_acc = dq_acc + jnp.einsum("bkgqs,bskd->bqkgd",
                                         ds.astype(k.dtype), k_blk,
                                         preferred_element_type=F32)
            dk_c = jnp.einsum("bkgqs,bqkgd->bskd", ds,
                              q_blk.astype(F32))
            start = kj * chunk
            upd = lambda full, c_: jax.lax.dynamic_update_slice_in_dim(
                full, jax.lax.dynamic_slice_in_dim(full, start, chunk, 1)
                + c_, start, 1)
            return (dq_acc, upd(dk_full, dk_c), upd(dv_full, dv_c)), None

        (dq_acc, dk_full, dv_full), _ = jax.lax.scan(
            kv_step, (dq0, dk_full, dv_full), jnp.arange(nband))
        return (dk_full, dv_full), dq_acc

    (dk_full, dv_full), dq_chunks = jax.lax.scan(
        q_step, (dk0, dv0), (jnp.arange(nq), qg, og, dog, lse))
    dq = jnp.moveaxis(dq_chunks, 0, 1).reshape(b, s, h, hd).astype(q.dtype)
    return dq, dk_full.astype(k.dtype), dv_full.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


# ----------------------------------------------------------------------------
# public entry points
# ----------------------------------------------------------------------------

Schedule = Literal["auto", "direct", "masked", "folded", "banded", "pallas"]


def pallas_flash_attention(q, k, v, *, causal: bool = True):
    """Route model-layout attention through the Pallas flash kernel.

    q: (B, S, H, hd); k/v: (B, S, KV, hd) — transposed to the kernel's
    (B, H, S, hd) layout and back. Forward-only (no custom VJP): the serve
    path's schedule; training uses the jnp flash VJP or, under the "fused"
    kernel policy, the fused kernels' reference-composition backward.
    """
    from repro.kernels import ops
    o = ops.flash_attention(jnp.transpose(q, (0, 2, 1, 3)),
                            jnp.transpose(k, (0, 2, 1, 3)),
                            jnp.transpose(v, (0, 2, 1, 3)), causal=causal)
    return jnp.transpose(o, (0, 2, 1, 3))


def attention(q, k, v, *, n_kv: int, causal: bool = True,
              window: int | None = None, chunk: int = 1024,
              schedule: Schedule = "auto"):
    """Training/prefill attention. q: (B,S,H,hd); k/v: (B,S,KV,hd)."""
    s = q.shape[1]
    if schedule == "pallas" and causal and window is None:
        return pallas_flash_attention(q, k, v, causal=True)
    if schedule == "pallas":          # kernel has no SWA/bidirectional path
        schedule = "auto"
    if schedule == "auto":
        if s <= 2 * chunk or s % chunk or not causal:
            schedule = "direct"
        elif window is not None and window < s:
            schedule = "banded"
        else:
            schedule = "masked"
    if schedule == "folded" and ((s // chunk) % 2 or (window and window < s)):
        schedule = "masked"
    if schedule == "direct" or not causal:
        return direct_attention(q, k, v, n_kv=n_kv, causal=causal,
                                window=window)
    return _flash(n_kv, chunk, window, schedule, q, k, v)


def cross_attention(q, k, v, *, n_kv: int, chunk: int = 1024):
    """Non-causal attention of long q against a short kv context (cross-attn).

    Scans q in chunks so the (S_q x S_kv) scores never materialize at full
    S_q. kv (encoder output / image embeds) is small enough to keep whole.
    """
    b, s, h, hd = q.shape
    if s <= 2 * chunk or s % chunk:
        return direct_attention(q, k, v, n_kv=n_kv, causal=False)
    scale = hd ** -0.5
    qg = _split_chunks(_group(q, n_kv), chunk)   # (nq, B, c, KV, G, hd)

    def q_step(_, q_blk):
        scores = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, k,
                            preferred_element_type=F32) * scale
        p = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
        return None, out.reshape(b, chunk, h, hd)

    _, out = jax.lax.scan(q_step, None, qg)
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, hd)


def decode_attention(q, k_cache, v_cache, pos, *, n_kv: int,
                     window: int | None = None, rolling: bool = False):
    """Single-token decode. q: (B,1,H,hd); caches: (B, S_c, KV, hd);
    pos: scalar or (B,) current position (number of tokens already cached).

    With `rolling=True` the cache is a circular buffer of size S_c (used for
    SWA at long context) and every live slot is attendable.
    """
    b, sc, kv, hd = k_cache.shape
    h = q.shape[2]
    scale = hd ** -0.5
    qg = _group(q, n_kv)[:, 0]                       # (B, KV, G, hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                        preferred_element_type=F32) * scale
    idx = jnp.arange(sc)
    pos_b = jnp.asarray(pos)
    if pos_b.ndim == 0:
        pos_b = jnp.full((b,), pos_b)
    if rolling:
        n_live = jnp.minimum(pos_b, sc)
        ok = idx[None, :] < n_live[:, None]
    else:
        ok = idx[None, :] < pos_b[:, None]
        if window is not None:
            ok &= idx[None, :] >= (pos_b[:, None] - window)
    scores = jnp.where(ok[:, None, None, :], scores, NEG)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, hd)


def update_cache(k_cache, v_cache, k_new, v_new, pos, *, rolling: bool = False):
    """Insert (B, 1, KV, hd) new keys/values at position `pos`.

    `pos` is a scalar (every slot writes the same row — the batch-program
    path) or a (B,) vector (each slot writes its own row — the continuous-
    batching session path, where slots sit at independent decode positions).
    """
    sc = k_cache.shape[1]
    slot = jnp.asarray(pos) % sc if rolling else jnp.asarray(pos)
    if slot.ndim == 0:
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, slot,
                                                      axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, slot,
                                                      axis=1)
        return k_cache, v_cache
    upd = jax.vmap(
        lambda c, n, s: jax.lax.dynamic_update_slice_in_dim(c, n, s, axis=0))
    return upd(k_cache, k_new, slot), upd(v_cache, v_new, slot)


# ----------------------------------------------------------------------------
# Paged KV — the shared-pool routing of the two hooks above
# (runtime/kvpool.py owns the host-side allocator; these are the device ops)
# ----------------------------------------------------------------------------


def paged_update_cache(k_pool, v_pool, k_new, v_new, pos, pages):
    """Scatter (B, 1, KV, hd) new keys/values through per-slot page tables.

    Pools are (n_pages, page_size, KV, hd) — the whole session shares them;
    `pages` is the (B, pages_per_slot) int32 table and `pos` the scalar or
    (B,) decode position. Slot b's token lands at
    `pool[pages[b, pos_b // page_size], pos_b % page_size]` — the paged
    analogue of `update_cache`'s per-slot dynamic-update-slice. Retired
    slots' tables point at the reserved trash page 0, so their frozen-pos
    writes can never corrupt pages reallocated to live requests.
    """
    ps = k_pool.shape[1]
    b = k_new.shape[0]
    pos_b = jnp.asarray(pos)
    if pos_b.ndim == 0:
        pos_b = jnp.full((b,), pos_b)
    page_idx = jnp.take_along_axis(pages, (pos_b // ps)[:, None],
                                   axis=1)[:, 0]            # (B,)
    off = pos_b % ps
    k_pool = k_pool.at[page_idx, off].set(k_new[:, 0])
    v_pool = v_pool.at[page_idx, off].set(v_new[:, 0])
    return k_pool, v_pool


def paged_gather(pool, pages):
    """Gather each slot's pages into a contiguous (B, npp * ps, KV, hd)
    cache view. Positions past a slot's written length read stale pool
    data (or the trash page) — harmless, because `decode_attention`'s
    `idx < pos` mask gives them exactly-zero softmax weight."""
    b, npp = pages.shape
    _, ps, kv, hd = pool.shape
    return pool[pages].reshape(b, npp * ps, kv, hd)


def paged_decode_attention(q, k_pool, v_pool, pos, pages, *, n_kv: int):
    """`decode_attention` against the shared pool: gather-through-table,
    then the standard masked path — bit-identical to the private-cache
    result for any slot whose pages hold the same K/V rows."""
    kg = paged_gather(k_pool, pages)
    vg = paged_gather(v_pool, pages)
    return decode_attention(q, kg, vg, pos, n_kv=n_kv)


def copy_page(pool, src, dst):
    """Device page copy (COW fork): pool[dst] = pool[src]."""
    return pool.at[dst].set(pool[src])


def zero_pages(pool, pages):
    """Scrub the listed pages (NaN-corruption recovery: masked attention
    zeroes stale *weights*, but 0 * NaN is still NaN, so pages freed from
    a corrupted slot must be cleaned before reuse)."""
    return pool.at[jnp.asarray(pages)].set(jnp.zeros((), pool.dtype))
