"""Parameter specs and core layers shared by the model zoo.

Every parameter is declared as a ParamSpec carrying its *logical axes* —
the handles the hybrid-addressing planner (core/addressing.py) uses to place
it in the SEQUENTIAL or INTERLEAVED region. One spec tree serves both the
dry-run (abstract ShapeDtypeStructs) and real initialization.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Logical = tuple  # tuple[str | None, ...]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    logical: Logical
    dtype: Any = jnp.bfloat16
    init: str = "normal"      # normal | zeros | ones | embed
    scale: float | None = None  # None -> 1/sqrt(fan_in)

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def materialize(self, key) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        scale = self.scale if self.scale is not None else 1.0 / np.sqrt(fan_in)
        if self.init == "embed":
            scale = 1.0
        x = jax.random.normal(key, self.shape, jnp.float32) * scale
        return x.astype(self.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def abstract_tree(specs):
    return jax.tree.map(lambda s: s.abstract(), specs, is_leaf=is_spec)


def logical_tree(specs):
    return jax.tree.map(lambda s: s.logical, specs, is_leaf=is_spec)


def init_tree(specs, key):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [s.materialize(k) for s, k in zip(leaves, keys)])


# ----------------------------------------------------------------------------
# Normalization / activations
# ----------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def geglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    h = jnp.einsum("...d,df->...f", x, w_in) + b_in
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, w_out) + b_out


# ----------------------------------------------------------------------------
# Rotary position embeddings
# ----------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 1e4):
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)          # (head_dim/2,)


def apply_rope(x, positions, theta: float = 1e4):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., seq, hd/2)
    angles = angles[..., None, :]                                # broadcast heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# Shared spec builders
# ----------------------------------------------------------------------------

def attn_specs(d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
               *, qkv_bias: bool = False, qk_norm: bool = False,
               dtype=jnp.bfloat16) -> dict:
    s = {
        "wq": ParamSpec((d_model, n_heads, head_dim), ("embed", "heads", None), dtype),
        "wk": ParamSpec((d_model, n_kv_heads, head_dim), ("embed", "kv_heads", None), dtype),
        "wv": ParamSpec((d_model, n_kv_heads, head_dim), ("embed", "kv_heads", None), dtype),
        "wo": ParamSpec((n_heads, head_dim, d_model), ("heads", None, "embed"), dtype),
    }
    if qkv_bias:
        s |= {
            "bq": ParamSpec((n_heads, head_dim), ("heads", None), dtype, init="zeros"),
            "bk": ParamSpec((n_kv_heads, head_dim), ("kv_heads", None), dtype, init="zeros"),
            "bv": ParamSpec((n_kv_heads, head_dim), ("kv_heads", None), dtype, init="zeros"),
        }
    if qk_norm:
        s |= {
            "q_norm": ParamSpec((head_dim,), ("norm",), dtype, init="zeros"),
            "k_norm": ParamSpec((head_dim,), ("norm",), dtype, init="zeros"),
        }
    return s


def ffn_specs(d_model: int, d_ff: int, *, kind: str = "swiglu",
              dtype=jnp.bfloat16) -> dict:
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": ParamSpec((d_model, d_ff), ("embed", "ffn"), dtype),
            "w_up": ParamSpec((d_model, d_ff), ("embed", "ffn"), dtype),
            "w_down": ParamSpec((d_ff, d_model), ("ffn", "embed"), dtype),
        }
    if kind == "gelu":  # whisper-style MLP with biases
        return {
            "w_in": ParamSpec((d_model, d_ff), ("embed", "ffn"), dtype),
            "b_in": ParamSpec((d_ff,), ("ffn",), dtype, init="zeros"),
            "w_out": ParamSpec((d_ff, d_model), ("ffn", "embed"), dtype),
            "b_out": ParamSpec((d_model,), ("embed",), dtype, init="zeros"),
        }
    raise ValueError(kind)


def apply_ffn(params: dict, x, *, kind: str = "swiglu"):
    if kind == "swiglu":
        return swiglu(x, params["w_gate"], params["w_up"], params["w_down"])
    if kind == "geglu":
        return geglu(x, params["w_gate"], params["w_up"], params["w_down"])
    if kind == "gelu":
        return gelu_mlp(x, params["w_in"], params["b_in"], params["w_out"],
                        params["b_out"])
    raise ValueError(kind)


def qkv_postprocess(params: dict, q, k, v, positions, *, qkv_bias=False,
                    qk_norm=False, rope=True, theta=1e4):
    """Bias / qk-norm / rope tail shared by the plain and fused qkv paths."""
    if qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if rope:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def qkv_project(params: dict, x, positions, *, n_heads, n_kv_heads, head_dim,
                qkv_bias=False, qk_norm=False, rope=True, theta=1e4):
    """x: (B, S, d) -> q (B,S,H,hd), k/v (B,S,KV,hd) with rope applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    return qkv_postprocess(params, q, k, v, positions, qkv_bias=qkv_bias,
                           qk_norm=qk_norm, rope=rope, theta=theta)


def out_project(params: dict, attn_out):
    """attn_out: (B, S, H, hd) -> (B, S, d)."""
    return jnp.einsum("bshk,hkd->bsd", attn_out, params["wo"])


# ----------------------------------------------------------------------------
# Fused kernel routing (KernelPolicy mode "fused"): producer–consumer kernels
# ----------------------------------------------------------------------------
#
# These helpers flatten the leading dims and dispatch to the fused wrappers
# in kernels/ops.py, which carry custom VJPs (Pallas forward, reference-
# composition backward) so the same route serves train and serve paths.

def fused_norm_matmul(x, scale, w):
    """rmsnorm(x, scale) @ w with the norm in the A-tile prologue.

    x: (..., d); scale: (d,); w: (d, f) -> (..., f). The normalized
    activations never round-trip HBM.
    """
    from repro.kernels import ops
    d = x.shape[-1]
    y = ops.rmsnorm_matmul(x.reshape(-1, d), scale, w)
    return y.reshape(*x.shape[:-1], w.shape[1])


def fused_matmul_residual(h, w, res):
    """h @ w + res with the residual added in the output epilogue.

    h: (..., f); w: (f, d); res: (..., d) -> (..., d).
    """
    from repro.kernels import ops
    f = h.shape[-1]
    y = ops.matmul_residual_add(h.reshape(-1, f), w,
                                res.reshape(-1, w.shape[1]))
    return y.reshape(res.shape)


def fused_matmul_bias_act(h, w, bias, act: str):
    """act(h @ w + bias) applied in the output epilogue. h: (..., f)."""
    from repro.kernels import ops
    f = h.shape[-1]
    y = ops.matmul_bias_act(h.reshape(-1, f), w, bias, act=act)
    return y.reshape(*h.shape[:-1], w.shape[1])


def fused_attention_proj(q, k, v, wo, *, causal: bool = True):
    """Flash attention + output projection in one kernel.

    q: (B, S, H, hd), k/v: (B, S, KV, hd) (model layout), wo: (H, hd, d)
    -> (B, S, d); the (B, H, S, hd) attention output never exists in HBM.
    """
    from repro.kernels import ops
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    return ops.flash_attention_proj(qt, kt, vt, wo, causal=causal)
