"""Request-level serving: ServeSession + the slot-scheduled session cell.

Acceptance coverage: a ServeSession fed one batch up-front is bit-identical
to the fixed-batch `ServeProgram(chunk=K)` path (tokens, EOS behaviour,
`emitted_per_slot`); staggered requests decoding at independent per-slot
positions match what each request gets in isolation; finished slots are
recycled in place (allocation-free steady state, on-device `age`/`active`
masks); streaming delivers incremental tokens; cancel frees the slot;
submit applies bounded-queue backpressure.
"""

import gc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import Cluster, ServeProgram, ServeSessionProgram
from repro.runtime import engine
from repro.runtime.scheduler import QueueFull, RequestFailed
from repro.runtime.serve_loop import ServeLoop, ServeSession


# ----------------------------------------------------------------------------
# Scripted harness: a decode step aware of per-slot positions
# ----------------------------------------------------------------------------


SCRIPT = np.array([[7, 1, 2], [3, 7, 4], [5, 6, 8], [9, 9, 9],
                   [2, 3, 4], [5, 6, 7]], np.int32)


def scripted_step(script: np.ndarray):
    """Emits script[pos[i], i] per slot — `pos` scalar or (B,) vector."""
    table = jnp.asarray(script, jnp.int32)

    def decode_step(params, cache, batch):
        pos = jnp.asarray(batch["pos"])
        idx = jnp.clip(pos, 0, table.shape[0] - 1)
        if pos.ndim == 0:
            return cache, jnp.take(table, idx, axis=0)[:, None]
        rows = jnp.take(table, idx, axis=0)              # (B, B)
        return cache, jnp.diagonal(rows)[:, None]

    return decode_step


def make_session(script=SCRIPT, *, chunk=2, eos_id=7, max_prompt=4,
                 max_queue=None, admission="fifo"):
    B = script.shape[1]
    chunk_fn = engine.make_session_chunk(scripted_step(script), chunk,
                                         eos_id=eos_id)
    refill_fn = engine.make_session_refill()
    state = engine.init_session_state({"kv": jnp.zeros((B, 4), jnp.float32)},
                                      B, max_prompt)
    return ServeSession(chunk_fn, refill_fn, None, state, n_slots=B,
                        chunk=chunk, max_prompt=max_prompt, eos_id=eos_id,
                        max_queue=max_queue, admission=admission)


# ----------------------------------------------------------------------------
# Parity with the fixed-batch loop (scripted)
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("chunk", [1, 2, 3, 16])
def test_session_matches_serve_loop_bit_for_bit(chunk):
    B = SCRIPT.shape[1]
    loop = ServeLoop(scripted_step(SCRIPT), None,
                     {"kv": jnp.zeros((B, 4), jnp.float32)},
                     batch_size=B, eos_id=7, chunk=1)
    ref = loop.generate(np.zeros((B, 1), np.int32), max_new=4)
    ref_st = loop.stats()

    sess = make_session(chunk=chunk)
    handles = [sess.submit([0], 4) for _ in range(B)]
    sess.drain()
    # per-request tokens are the unpadded rows of the legacy output
    for i, h in enumerate(handles):
        n = ref_st["emitted_per_slot"][i]
        np.testing.assert_array_equal(h.tokens, ref[i, 1:1 + n])
    assert [h.tokens.size for h in handles] == ref_st["emitted_per_slot"]
    assert sum(h.hit_eos for h in handles) == ref_st["finished_slots"]
    # host syncs once per chunk, not per token
    assert sess.clock.report()["host_syncs"] <= -(-4 // chunk) + 1


def test_session_slot_recycling_and_age():
    sess = make_session(chunk=2)
    first = [sess.submit([0], 4) for _ in range(3)]
    sess.drain()
    # all three slots saw one admission
    np.testing.assert_array_equal(np.asarray(sess.state["age"]), [1, 1, 1])
    late = sess.submit([1, 2], 3)             # prefill 1 then 2, emit 3
    sess.drain()
    assert late.done and not late.hit_eos
    # exactly one slot was recycled (age bumped), in place
    assert sorted(np.asarray(sess.state["age"]).tolist()) == [1, 1, 2]
    assert late.tokens.size == 3
    assert all(h.done for h in first)


def test_session_steady_state_allocates_nothing():
    sess = make_session(chunk=2, eos_id=None)
    sess.submit([0], 4)
    sess.drain()                              # compile + first cycle
    gc.collect()
    baseline = len(jax.live_arrays())
    for _ in range(3):                        # recycle the pool repeatedly
        sess.submit([0], 4)
        sess.drain()
        gc.collect()
        assert len(jax.live_arrays()) == baseline


# ----------------------------------------------------------------------------
# Streaming, cancel, backpressure, validation
# ----------------------------------------------------------------------------


def test_stream_yields_incremental_tokens_in_order():
    sess = make_session(chunk=2, eos_id=None)
    h = sess.submit([0], 4)
    seen = []
    dones = 0
    for handle, toks, done in sess.stream():
        assert handle is h
        seen.extend(toks.tolist())
        dones += done
    assert dones == 1
    np.testing.assert_array_equal(seen, h.result())
    assert h.tokens.size == 4


def test_poll_is_noop_when_idle():
    sess = make_session()
    assert sess.poll() == []
    assert sess.clock.report()["host_syncs"] == 0


def test_cancel_running_frees_slot_for_queued_work():
    script = np.full((8, 1), 3, np.int32)     # B=1: queue forms behind slot 0
    sess = make_session(script, chunk=2, eos_id=None)
    a = sess.submit([0], 8)
    b = sess.submit([0], 2)
    sess.poll()                               # a admitted + 2 tokens
    assert a.tokens.size == 2 and b.state == "queued"
    assert sess.cancel(a)
    sess.drain()
    assert a.cancelled and a.tokens.size == 2     # truncated, kept
    assert b.done and b.tokens.size == 2          # got the freed slot
    with pytest.raises(RequestFailed) as exc:     # typed failure, partial
        a.result()                                # tokens attached
    assert exc.value.reason == "cancelled"
    assert exc.value.partial_tokens.size == 2


def test_cancel_queued_never_runs():
    script = np.full((8, 1), 3, np.int32)
    sess = make_session(script, chunk=2, eos_id=None)
    a = sess.submit([0], 4)
    b = sess.submit([0], 4)
    sess.cancel(b)
    sess.drain()
    assert b.cancelled and b.tokens.size == 0
    assert a.done and a.tokens.size == 4


def test_submit_backpressure_and_validation():
    sess = make_session(max_queue=2)
    sess.submit([0], 1)
    sess.submit([0], 1)
    with pytest.raises(QueueFull):
        sess.submit([0], 1)
    with pytest.raises(ValueError):
        sess.submit([1] * 99, 1)              # prompt > max_prompt
    sess2 = make_session()
    sess2.max_seq = 4
    with pytest.raises(ValueError):
        sess2.submit([1, 2], 4)               # P + max_new > max_seq


def test_longest_prefix_admission_orders_by_prompt():
    script = np.full((8, 1), 3, np.int32)
    sess = make_session(script, chunk=2, eos_id=None,
                        admission="longest_prefix")
    a = sess.submit([1], 2)
    b = sess.submit([1, 2, 3], 2)
    sess.drain()
    assert list(sess.scheduler.admitted_order) == [b.id, a.id]


def test_session_stats_shape():
    sess = make_session(chunk=2, eos_id=None)
    hs = [sess.submit([0], 3) for _ in range(4)]
    st = sess.drain()
    assert st["requests_done"] == 4
    assert st["emitted_total"] == sum(h.tokens.size for h in hs) == 12
    assert 0.0 < st["occupancy_pct"] <= 100.0
    assert st["ttft_ms"]["p50"] >= 0.0
    assert st["latency_ms"]["p99"] >= st["latency_ms"]["p50"] >= 0.0
    assert st["stall"]["host_syncs"] == len(sess.chunk_latencies)


# ----------------------------------------------------------------------------
# Model path (slow): one-shot parity + staggered isolation
# ----------------------------------------------------------------------------


@pytest.mark.slow
def test_one_shot_session_bit_identical_to_serve_program():
    cluster = Cluster("xlstm-125m-smoke")
    ref = cluster.compile(ServeProgram(batch=2, max_seq=16, max_new=8,
                                       chunk=4))
    params = ref.init_params()
    r_ref = ref.run(params=params)
    r_sess = cluster.compile(ServeSessionProgram(
        slots=2, max_seq=16, max_new=8, chunk=4)).run(params=params)
    np.testing.assert_array_equal(r_ref["tokens"], r_sess["tokens"])
    assert (r_ref["stats"]["emitted_per_slot"]
            == r_sess["stats"]["emitted_per_slot"])

    # EOS variant: masking, early stop, finished_slots all line up
    eos = int(r_ref["tokens"][0, 4])
    re = cluster.compile(ServeProgram(batch=2, max_seq=16, max_new=8,
                                      chunk=4, eos_id=eos)).run(params=params)
    rs = cluster.compile(ServeSessionProgram(
        slots=2, max_seq=16, max_new=8, chunk=4,
        eos_id=eos)).run(params=params)
    np.testing.assert_array_equal(re["tokens"], rs["tokens"])
    assert re["stats"]["emitted_per_slot"] == rs["stats"]["emitted_per_slot"]
    assert re["stats"]["finished_slots"] == rs["stats"]["finished_slots"]

    # prompt ingest parity (continuous-batching-style prefill per slot)
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(0), (2, 3), 0,
                                           cluster.arch.vocab))
    rp = ref.run(params=params, prompt=prompt)
    rps = cluster.compile(ServeSessionProgram(
        slots=2, max_seq=16, max_new=8, chunk=4)).run(params=params,
                                                      prompt=prompt)
    np.testing.assert_array_equal(rp["tokens"], rps["tokens"])


@pytest.mark.slow
def test_staggered_requests_match_isolated_decode():
    """Slots at independent positions (the continuous-batching invariant):
    a request admitted into a recycled slot mid-session decodes the same
    tokens it would get alone in a fresh pool."""
    cluster = Cluster("qwen3-14b-smoke")      # attention arch: per-slot KV pos
    prog = cluster.compile(ServeSessionProgram(slots=2, max_seq=32,
                                               max_prompt=8, chunk=4))
    params = prog.init_params()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cluster.arch.vocab, size=n).astype(np.int32)
               for n in (3, 5, 2, 4)]
    lens = [6, 9, 5, 7]

    isolated = []
    for p, n in zip(prompts, lens):
        s = prog.open(params=params)
        h = s.submit(p, n)
        s.drain()
        isolated.append(h.tokens.tolist())

    sess = prog.open(params=params)
    hs = [sess.submit(p, n) for p, n in zip(prompts, lens)]
    st = sess.drain()
    assert [h.tokens.tolist() for h in hs] == isolated
    assert st["requests_done"] == 4
    # four requests through two slots: both slots recycled at least once
    assert np.asarray(sess.state["age"]).sum() == 4


@pytest.mark.slow
def test_api_serve_routes_through_session():
    from repro import api
    out = api.serve("xlstm-125m", batch=2, max_seq=16, max_new=4)
    assert out["tokens"].shape == (2, 5)
    st = out["stats"]
    assert st["decode_steps"] == 3            # legacy per-token warmup drop
    assert st["emitted_per_slot"] == [4, 4]
    assert "session" in st and st["session"]["requests_done"] == 2
