"""High-level API (the OpenMP layer): plan / train / serve one-call shims
over the Cluster façade."""

import jax
import numpy as np
import pytest

from repro import api
from repro.core import compat


def test_plan_regions():
    mesh = compat.abstract_mesh((2, 2), ("data", "model"))
    p = api.plan("qwen3-14b", mesh)
    ffn = next(v for k, v in p.items() if k.endswith("w_gate"))
    assert ffn["region"] == "INTERLEAVED"
    norm = next(v for k, v in p.items() if "ln_f" in k)
    assert norm["region"] == "REPLICATED"
    assert len(p) > 10


@pytest.mark.slow
def test_train_and_serve_one_call(tmp_path):
    report = api.train("xlstm-125m", num_steps=4, batch=2, seq=16,
                       checkpoint_dir=str(tmp_path))
    assert report["final_step"] == 4
    out = api.serve("xlstm-125m", report["params"], batch=2, max_seq=16,
                    max_new=4)
    assert out["tokens"].shape == (2, 5)
    # 4 generated tokens -> 3 post-warmup latency samples
    assert out["stats"]["decode_steps"] == 3


@pytest.mark.slow
def test_train_steps_alias_deprecated(tmp_path):
    """The old steps_ keyword still works (one release) but warns."""
    with pytest.deprecated_call():
        report = api.train("xlstm-125m", steps_=2, batch=2, seq=16,
                           checkpoint_dir=str(tmp_path))
    assert report["final_step"] == 2
