"""Data pipeline: splitter/distributor semantics + double-buffered feed."""

import time

import jax
import numpy as np
import pytest

from repro.core import compat
from repro.data import DoubleBufferedFeed, Distributor, Splitter, SyntheticLMStream
from repro.data.pipeline import BatchSpec


def test_stream_deterministic_and_stateless():
    spec = BatchSpec(global_batch=4, seq_len=16, vocab=1000)
    s1 = SyntheticLMStream(spec, seed=7)
    s2 = SyntheticLMStream(spec, seed=7)
    b1 = s1.batch(42)
    b2 = s2.batch(42)                      # fresh object, same (seed, step)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = s1.batch(43)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_labels_are_shifted_tokens():
    spec = BatchSpec(global_batch=2, seq_len=8, vocab=100)
    b = SyntheticLMStream(spec).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_splitter_slices_cover_batch():
    mesh = compat.make_mesh((1,), ("data",))
    sp = Splitter(mesh, ("pod", "data"))
    slices = sp.slices(8)
    assert slices[0] == (0, 8)
    covered = sorted(x for lo, hi in slices for x in range(lo, hi))
    assert covered == list(range(8))


def test_slice_independence():
    """Each row is generated independently: slice == slice of the whole
    (the distributor can hand any shard to any host)."""
    spec = BatchSpec(global_batch=8, seq_len=8, vocab=100)
    st = SyntheticLMStream(spec, seed=1)
    full = st.batch(5)
    part = st.batch(5, lo=2, hi=5)
    np.testing.assert_array_equal(full["tokens"][2:5], part["tokens"])


def test_distributor_materializes_sharded():
    spec = BatchSpec(global_batch=4, seq_len=8, vocab=50)
    stream = SyntheticLMStream(spec)
    mesh = compat.make_mesh((1,), ("data",))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))
    dist = Distributor(mesh, Splitter(mesh, ("data",)))
    batch = dist.materialize(stream, 0, sh)
    assert batch["tokens"].shape == (4, 8)
    assert batch["tokens"].sharding == sh


def test_double_buffered_feed_overlaps():
    made = []

    def make(step):
        time.sleep(0.02)
        made.append(step)
        return {"step": step}

    feed = DoubleBufferedFeed(make, depth=2)
    t0 = time.perf_counter()
    for i in range(5):
        step, batch = next(feed)
        assert batch["step"] == step == i
        time.sleep(0.02)                  # "compute"
    elapsed = time.perf_counter() - t0
    feed.close()
    # serial would be >= 10 * 0.02; overlap should beat it comfortably
    assert elapsed < 0.18, elapsed
    assert len(feed.transfer_seconds) >= 5


def test_double_buffered_feed_propagates_producer_error():
    def make(step):
        if step == 2:
            raise ValueError("bad batch")
        return {"step": step}

    feed = DoubleBufferedFeed(make, depth=2)
    # batches queued before the failure still arrive, in order
    assert next(feed)[0] == 0
    assert next(feed)[0] == 1
    with pytest.raises(RuntimeError, match="producer failed") as ei:
        next(feed)
    assert isinstance(ei.value.__cause__, ValueError)
    # the error is sticky: later next() calls re-raise instead of blocking
    with pytest.raises(RuntimeError, match="producer failed"):
        next(feed)
    feed.close()


def test_double_buffered_feed_error_before_first_batch():
    def make(step):
        raise OSError("disk gone")

    feed = DoubleBufferedFeed(make, depth=2)
    with pytest.raises(RuntimeError, match="producer failed"):
        next(feed)
    feed.close()


def test_double_buffered_feed_close_idempotent():
    feed = DoubleBufferedFeed(lambda step: {"step": step}, depth=2)
    next(feed)
    feed.close()
    feed.close()                            # second close is a no-op
    assert not feed._thread.is_alive()


def test_double_buffered_feed_stall_report():
    def make(step):
        time.sleep(0.005)
        return {"step": step}

    feed = DoubleBufferedFeed(make, depth=2)
    for _ in range(4):
        next(feed)
        time.sleep(0.01)                    # compute longer than transfer
    report = feed.stall_report()
    feed.close()
    assert len(feed.consumer_wait_seconds) >= 4
    assert report["produce_s"] > 0
    # steady state: transfers hide under compute
    assert report["overlap_pct"] > 50.0
    assert report["hidden_s"] <= report["produce_s"]
