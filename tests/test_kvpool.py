"""Shared paged KV pool (runtime/kvpool.py) — invariants + serving paths.

The pool is the software shared-L1: one global array of KV pages, slots
hold page tables, prefixes are shared copy-on-write. The properties the
tentpole rests on, checked here:

* allocator soundness — a page is never handed out twice, refcounts
  never go negative, and after every slot releases (and the prefix
  cache is cleared) all pages are free again: no leaks;
* COW prefix reuse is *bit-exact* — a paged session with shared (and
  exactly-identical) prompts emits the same tokens as the private-cache
  session, while skipping prefill for the shared pages;
* exhaustion is a typed, recoverable condition — `PoolExhausted` sheds
  to the queue (scripted via the `page_alloc_fail` fault or genuinely
  via a tiny pool) and only fails terminally when the request can never
  fit, with reason "pool_exhausted";
* the fault-recovery contract survives the layout swap — NaN corruption
  and wedge recovery still reproduce the fault-free tokens bit for bit;
* equal memory buys strictly more concurrency — a pool with half the
  private layout's KV capacity still serves the full slot complement.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from hypothesis_fallback import given, settings, strategies as st

from repro.runtime.kvpool import (PagePool, PagedKV, PoolExhausted,
                                  PrefixCache, TRASH_PAGE)

ARCH = "qwen3-14b-smoke"


# ----------------------------------------------------------------------------
# PagePool allocator invariants
# ----------------------------------------------------------------------------


def test_pool_basics():
    pool = PagePool(8, 4)
    assert pool.free_pages == 7                 # page 0 reserved
    pages = pool.alloc(3)
    assert len(set(pages)) == 3 and TRASH_PAGE not in pages
    assert pool.used_pages == 3
    freed = pool.release(pages)
    assert sorted(freed) == sorted(pages)
    assert pool.free_pages == 7


def test_pool_alloc_is_all_or_nothing():
    pool = PagePool(4, 4)
    pool.alloc(2)
    with pytest.raises(PoolExhausted) as ei:
        pool.alloc(2)
    assert ei.value.needed == 2 and ei.value.free == 1
    assert pool.free_pages == 1                 # nothing was taken
    assert pool.alloc_failures == 1


def test_shared_page_survives_first_release():
    pool = PagePool(4, 4)
    (p,) = pool.alloc(1)
    pool.ref([p])
    assert pool.release([p]) == []              # still referenced
    assert pool.release([p]) == [p]             # now free
    assert pool.refcount[p] == 0


@settings(deadline=None, max_examples=40)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n_pages=st.integers(min_value=2, max_value=24),
       n_ops=st.integers(min_value=1, max_value=120))
def test_pool_never_double_allocates_or_leaks(seed, n_pages, n_ops):
    """Random alloc/ref/release interleavings: every live allocation set
    is disjoint, refcounts stay >= 0, and draining everything frees
    every page."""
    rng = np.random.default_rng(seed)
    pool = PagePool(n_pages, 4)
    live: list[list[int]] = []          # allocation units (owned refs)
    for _ in range(n_ops):
        op = rng.integers(0, 3)
        if op == 0:
            n = int(rng.integers(0, max(pool.free_pages, 1) + 1))
            try:
                pages = pool.alloc(n)
            except PoolExhausted:
                continue
            held = {p for unit in live for p in unit}
            assert not (set(pages) & held), "page double-allocated"
            live.append(pages)
        elif op == 1 and live:
            unit = live[int(rng.integers(0, len(live)))]
            if unit:
                pool.ref(unit)
                live.append(list(unit))
        elif op == 2 and live:
            unit = live.pop(int(rng.integers(0, len(live))))
            pool.release(unit)
        assert (pool.refcount >= 0).all()
        assert pool.refcount[TRASH_PAGE] == 1
        assert pool.free_pages + pool.used_pages == n_pages - 1
    for unit in live:
        pool.release(unit)
    assert pool.free_pages == n_pages - 1, "pages leaked"
    assert (pool.refcount[1:] == 0).all()


def test_dirty_tracking_scrubs_only_free_pages():
    pool = PagePool(8, 4)
    a = pool.alloc(2)
    b = pool.alloc(1)
    pool.mark_dirty(a + b)
    pool.release(a)
    assert sorted(pool.take_dirty_free()) == sorted(a)   # b still live
    assert pool.take_dirty_free() == []                  # marks cleared
    pool.release(b)
    assert pool.take_dirty_free() == b


# ----------------------------------------------------------------------------
# PrefixCache
# ----------------------------------------------------------------------------


def test_prefix_match_is_bit_exact_not_just_hash():
    pool = PagePool(8, 4)
    cache = PrefixCache(pool)
    toks = np.arange(8, dtype=np.int32)
    pages = pool.alloc(2)
    assert cache.insert(toks, pages) == 2
    assert cache.match(toks) == pages
    other = toks.copy()
    other[5] ^= 1
    assert cache.match(other) == pages[:1]      # second page differs
    assert cache.match(other[:3]) == []         # below one full page


def test_prefix_eviction_frees_pages():
    pool = PagePool(6, 4)
    cache = PrefixCache(pool)
    toks = np.arange(8, dtype=np.int32)
    pages = pool.alloc(2)
    cache.insert(toks, pages)
    pool.release(pages)                         # owner gone, cache holds
    assert pool.free_pages == 3
    freed = cache.evict(2)
    assert sorted(freed) == sorted(pages)
    assert pool.free_pages == 5
    assert cache.match(toks) == []


# ----------------------------------------------------------------------------
# PagedKV admission
# ----------------------------------------------------------------------------


@settings(deadline=None, max_examples=25)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n_reqs=st.integers(min_value=1, max_value=12))
def test_paged_kv_admit_release_never_leaks(seed, n_reqs):
    rng = np.random.default_rng(seed)
    kv = PagedKV(n_pages=33, page_size=4, n_slots=4, pages_per_slot=8)
    live: list[int] = []
    for _ in range(n_reqs):
        if live and (len(live) == 4 or rng.integers(0, 2)):
            slot = live.pop(int(rng.integers(0, len(live))))
            if rng.integers(0, 2):
                kv.publish(slot)
            kv.release(slot)
            continue
        slot = next(s for s in range(4) if s not in live)
        prompt = rng.integers(1, 40, size=int(rng.integers(1, 16)))
        try:
            alloc = kv.admit(slot, prompt.astype(np.int32),
                             int(rng.integers(1, 8)))
        except PoolExhausted:
            continue
        table = alloc.table
        assert table.shape == (8,)
        n_live = len(kv.slot_pages(slot))
        assert (table[n_live:] == TRASH_PAGE).all()
        assert (table[:n_live] != TRASH_PAGE).all()
        live.append(slot)
    for slot in live:
        kv.release(slot)
    if kv.prefix is not None:
        kv.prefix.clear()
    assert kv.pool.free_pages == 32, "pages leaked"
    assert (kv.pool.refcount[1:] == 0).all()


def test_admit_shares_published_prefix_and_skips_prefill():
    kv = PagedKV(n_pages=33, page_size=4, n_slots=4, pages_per_slot=8)
    prompt = np.arange(1, 12, dtype=np.int32)       # 11 toks = 2 full pages
    a0 = kv.admit(0, prompt, 4)
    assert a0.shared_pages == 0 and a0.prefill_skip == 0
    kv.publish(0)
    kv.release(0)
    a1 = kv.admit(1, prompt, 4)
    assert a1.shared_pages == 2
    assert a1.prefill_skip == 8                     # 2 pages * 4 tokens
    assert a1.cow_copies == []                      # skip < prompt size
    assert kv.slot_pages(1)[:2] == kv.slot_pages(1)[:2]
    kv.release(1)


def test_exact_full_coverage_prompt_cow_forks_last_page():
    kv = PagedKV(n_pages=33, page_size=4, n_slots=4, pages_per_slot=8)
    prompt = np.arange(1, 9, dtype=np.int32)        # exactly 2 pages
    kv.admit(0, prompt, 4)
    kv.publish(0)
    first_pages = kv.slot_pages(0)
    kv.release(0)
    a1 = kv.admit(1, prompt, 4)
    assert a1.shared_pages == 2
    assert a1.prefill_skip == 7                     # last token re-fed
    assert len(a1.cow_copies) == 1
    src, dst = a1.cow_copies[0]
    assert src == first_pages[1]                    # forked shared page
    assert kv.slot_pages(1)[1] == dst != src
    assert kv.pool.refcount[src] > 0                # src alive until copy
    kv.release(1)


def test_admit_allocates_nothing_on_failure():
    kv = PagedKV(n_pages=5, page_size=4, n_slots=2, pages_per_slot=8,
                 prefix_cache=False)
    kv.admit(0, np.arange(8, dtype=np.int32), 4)    # 3 pages of 4
    free_before = kv.pool.free_pages
    with pytest.raises(PoolExhausted):
        kv.admit(1, np.arange(8, dtype=np.int32), 4)
    assert kv.pool.free_pages == free_before
    assert kv.slot_pages(1) == []


def test_admit_evicts_prefix_cache_under_pressure():
    kv = PagedKV(n_pages=7, page_size=4, n_slots=2, pages_per_slot=8)
    kv.admit(0, np.arange(8, dtype=np.int32), 4)    # 3 pages
    kv.publish(0)
    kv.release(0)                                   # 2 pages cached
    # a disjoint prompt needs more than the raw free pages — eviction of
    # the cached prefix must make room
    alloc = kv.admit(1, 50 + np.arange(12, dtype=np.int32), 8)   # 5 pages
    assert alloc.shared_pages == 0
    assert len(kv.slot_pages(1)) == 5
    kv.release(1)


def test_reset_forgets_everything():
    kv = PagedKV(n_pages=33, page_size=4, n_slots=4, pages_per_slot=8)
    kv.admit(0, np.arange(1, 12, dtype=np.int32), 4)
    kv.publish(0)
    kv.reset()
    assert kv.pool.free_pages == 32
    assert kv.slot_pages(0) == []
    assert kv.match_len(np.arange(1, 12, dtype=np.int32)) == 0


# ----------------------------------------------------------------------------
# End-to-end serving: paged vs private, faults, capacity
# ----------------------------------------------------------------------------


@pytest.fixture(scope="module")
def cluster():
    from repro.cluster.session import Cluster
    return Cluster(ARCH)


@pytest.fixture(scope="module")
def programs(cluster):
    from repro.cluster.session import ServeSessionProgram
    common = dict(slots=4, max_seq=48, max_prompt=16, max_new=6, chunk=4)
    private = cluster.compile(ServeSessionProgram(preempt=False, **common))
    paged = cluster.compile(ServeSessionProgram(paged=True, page_size=4,
                                                **common))
    return private, paged, private.init_params()


def _run(prog, params, prompts, faults=None, max_new=6):
    sess = prog.open(params=params, faults=faults)
    handles = [sess.submit(p, max_new) for p in prompts]
    sess.drain()
    return [h.result() for h in handles], sess.stats()


def test_paged_bit_identical_on_prefix_free_workload(programs):
    private, paged, params = programs
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 50, size=int(rng.integers(2, 16)))
               .astype(np.int32) for _ in range(6)]
    toks_p, _ = _run(private, params, prompts)
    toks_g, st = _run(paged, params, prompts)
    for a, b in zip(toks_p, toks_g):
        np.testing.assert_array_equal(a, b)
    assert st["kv"]["alloc_failures"] == 0


def test_shared_prefix_skips_prefill_bit_identically(programs):
    private, paged, params = programs
    rng = np.random.default_rng(1)
    pre = rng.integers(1, 50, size=12).astype(np.int32)   # 3 full pages
    prompts = [np.concatenate([pre,
                               rng.integers(1, 50, size=3).astype(np.int32)])
               for _ in range(8)]
    toks_p, _ = _run(private, params, prompts)
    toks_g, st = _run(paged, params, prompts)
    for a, b in zip(toks_p, toks_g):
        np.testing.assert_array_equal(a, b)
    kv = st["kv"]
    assert kv["prefix_hits"] > 0
    assert kv["pages_shared"] > 0
    assert kv["prefill_skipped_tokens"] >= kv["prefix_hits"] * 12


def test_identical_prompts_cow_fork_bit_identically(programs):
    private, paged, params = programs
    rng = np.random.default_rng(2)
    pre = rng.integers(1, 50, size=12).astype(np.int32)   # exact page cover
    prompts = [pre.copy() for _ in range(6)]
    toks_p, _ = _run(private, params, prompts)
    toks_g, st = _run(paged, params, prompts)
    for a, b in zip(toks_p, toks_g):
        np.testing.assert_array_equal(a, b)
    assert st["kv"]["cow_forks"] > 0


def test_page_alloc_fault_sheds_and_requeues(programs):
    from repro.runtime.faults import FaultPlan
    _, paged, params = programs
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, 50, size=12).astype(np.int32)
               for _ in range(6)]
    toks_ref, _ = _run(paged, params, prompts)
    plan = FaultPlan().page_alloc_fail(at_chunk=0)
    toks_f, st = _run(paged, params, prompts, faults=plan)
    for a, b in zip(toks_ref, toks_f):
        np.testing.assert_array_equal(a, b)
    assert st["kv"]["pool_exhausted"] == 4      # the whole first wave shed
    assert plan.summary()["by_kind"]["page_alloc_fail"] == 1


def test_genuine_exhaustion_backs_off_and_completes(cluster, programs):
    from repro.cluster.session import ServeSessionProgram
    _, _, params = programs
    # 10 usable pages, 5 per request: two slots' worth — the other two
    # admissions must shed, requeue, and run as pages free up
    prog = cluster.compile(ServeSessionProgram(
        slots=4, max_seq=48, max_prompt=16, max_new=6, chunk=4,
        paged=True, page_size=4, n_pages=11, prefix_cache=False))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, 50, size=12).astype(np.int32)
               for _ in range(6)]
    sess = prog.open(params=params)
    handles = [sess.submit(p, 6) for p in prompts]
    sess.drain()
    assert all(h.ok for h in handles)
    assert sess.stats()["kv"]["pool_exhausted"] > 0


def test_never_fitting_request_fails_typed(cluster, programs):
    from repro.cluster.session import ServeSessionProgram
    _, _, params = programs
    prog = cluster.compile(ServeSessionProgram(
        slots=2, max_seq=48, max_prompt=16, max_new=20, chunk=4,
        paged=True, page_size=4, n_pages=3, prefix_cache=False))
    sess = prog.open(params=params)
    h = sess.submit(np.arange(1, 13, dtype=np.int32), 20)
    sess.drain()
    assert h.failed
    assert h.fail_reason == "pool_exhausted"


def test_nan_corruption_recovers_bit_identically_under_paged(programs):
    from repro.runtime.faults import FaultPlan
    _, paged, params = programs
    rng = np.random.default_rng(4)
    prompts = [rng.integers(1, 50, size=12).astype(np.int32)
               for _ in range(6)]
    toks_ref, _ = _run(paged, params, prompts)
    plan = FaultPlan().corrupt_nan(at_chunk=1, slot=0)
    toks_f, _ = _run(paged, params, prompts, faults=plan)
    for a, b in zip(toks_ref, toks_f):
        np.testing.assert_array_equal(a, b)
    assert plan.summary()["by_kind"]["corrupt_nan"] == 1


def test_wedge_recovery_resets_pool_under_paged(programs):
    from repro.runtime.faults import FaultPlan, SessionWedged
    _, paged, params = programs
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, 50, size=12).astype(np.int32)
               for _ in range(6)]
    toks_ref, _ = _run(paged, params, prompts)
    plan = FaultPlan().wedge(at_chunk=1)
    sess = paged.open(params=params, faults=plan)
    handles = [sess.submit(p, 6) for p in prompts]
    with pytest.raises(SessionWedged):
        sess.drain(timeout_s=0.5)
    sess.recover_wedged()
    # recovery rebuilt the device pool: the kv book must match (empty)
    assert sess.stats()["kv"]["used_pages"] == 0
    sess.drain()
    for a, h in zip(toks_ref, handles):
        np.testing.assert_array_equal(a, h.result())


def test_half_memory_pool_serves_full_slot_complement(cluster, programs):
    """Equal memory buys strictly more concurrency: a pool with HALF the
    private layout's page capacity still runs all 4 slots at once when
    requests are shorter than max_seq (the private layout reserves
    max_seq rows per slot no matter what)."""
    from repro.cluster.session import ServeSessionProgram
    _, _, params = programs
    pps = -((48 + 1) // -4)                      # private capacity/slot
    half = 4 * pps // 2 + 1
    prog = cluster.compile(ServeSessionProgram(
        slots=4, max_seq=48, max_prompt=16, max_new=6, chunk=4,
        paged=True, page_size=4, n_pages=half, prefix_cache=False))
    rng = np.random.default_rng(6)
    prompts = [rng.integers(1, 50, size=10).astype(np.int32)
               for _ in range(4)]
    sess = prog.open(params=params)
    handles = [sess.submit(p, 6) for p in prompts]
    sess.drain()
    assert all(h.ok for h in handles)
    # all four ran concurrently: nothing was shed back to the queue
    assert sess.stats()["kv"]["pool_exhausted"] == 0


def test_paged_rejects_preempt_and_recurrent_archs(cluster):
    from repro.cluster.session import Cluster, ServeSessionProgram
    from repro.models import steps
    from repro.configs import get as get_arch
    # recurrent-only arch has no pageable leaves
    cfg = get_arch("xlstm-125m-smoke")
    with pytest.raises(ValueError):
        steps.paged_cache_specs(cfg, 2, 16, n_pages=9, page_size=4)
    # preempt + kv is contradictory at the session layer
    from repro.runtime.serve_loop import ServeSession
    prog = cluster.compile(ServeSessionProgram(
        slots=2, max_seq=32, max_prompt=8, chunk=4, paged=True,
        page_size=4, preempt=True))
    sess = prog.open()          # program forces preempt off: must not raise
    assert sess.stats()["kv"]["used_pages"] == 0
