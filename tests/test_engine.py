"""Device-resident execution engine (runtime/engine.py).

Acceptance coverage: the scan-compiled K-step decode is bit-identical to
the per-token host loop (tokens, EOS masking/early-stop, emitted_per_slot)
while cutting host syncs from O(T) to O(T/K); steady-state decode chunks
allocate no new device buffers (donation); the chunked train path matches
the per-step loop and samples straggler/logging at chunk granularity; the
StallClock ledger and the Pallas pipelining-hint compat layer behave.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import Cluster, ServeProgram, TrainProgram
from repro.core import compat
from repro.models import steps
from repro.runtime.engine import (DecodeEngine, StallClock, make_decode_chunk,
                                  make_train_chunk, stack_batches)
from repro.runtime.serve_loop import ServeLoop


# ----------------------------------------------------------------------------
# Scripted-decode parity: scan path == per-token loop, bit for bit
# ----------------------------------------------------------------------------


def scripted_step(script: np.ndarray):
    """Traceable decode_step emitting script[pos] (a (B,) row) per position."""
    table = jnp.asarray(script, jnp.int32)

    def decode_step(params, cache, batch):
        tok = jnp.take(table, batch["pos"], axis=0)[:, None]
        return cache, tok

    return decode_step


def fresh_cache(B: int):
    return {"kv": jnp.zeros((B, 4), jnp.float32)}


SCRIPT = np.array([[7, 1, 2], [3, 7, 4], [5, 6, 8], [9, 9, 9]], np.int32)


def run_loop(chunk: int, *, eos_id=7, max_new=4, script=SCRIPT):
    B = script.shape[1]
    loop = ServeLoop(scripted_step(script), None, fresh_cache(B),
                     batch_size=B, eos_id=eos_id, chunk=chunk)
    out = loop.generate(np.zeros((B, 1), np.int32), max_new=max_new)
    return out, loop.stats()


@pytest.mark.parametrize("chunk", [2, 3, 4, 16])
def test_scan_decode_matches_per_token_loop(chunk):
    ref_out, ref_st = run_loop(1)
    out, st = run_loop(chunk)
    np.testing.assert_array_equal(out, ref_out)
    assert st["emitted_per_slot"] == ref_st["emitted_per_slot"]
    assert st["finished_slots"] == ref_st["finished_slots"]
    # O(T) -> O(T/K) host syncs
    assert st["stall"]["host_syncs"] <= -(-4 // chunk)
    assert ref_st["stall"]["host_syncs"] == 4


def test_scan_decode_eos_early_stop_and_masking():
    out, st = run_loop(2)
    # slot 0 finishes at step 1, slot 1 at step 2; slot 2 never does
    np.testing.assert_array_equal(out[0], [0, 7, 7, 7, 7])
    np.testing.assert_array_equal(out[1], [0, 1, 7, 7, 7])
    np.testing.assert_array_equal(out[2], [0, 2, 4, 8, 9])
    assert st["emitted_per_slot"] == [1, 2, 4]

    all_eos = np.full((4, 2), 7, np.int32)
    ref_out, ref_st = run_loop(1, script=all_eos, max_new=10)
    out, st = run_loop(4, script=all_eos, max_new=10)
    np.testing.assert_array_equal(out, ref_out)
    assert out.shape == (2, 2)                  # stopped after one step
    assert st["emitted_per_slot"] == ref_st["emitted_per_slot"] == [1, 1]
    assert st["stall"]["host_syncs"] == 1       # one chunk was enough


def test_scan_decode_no_eos_and_partial_chunk():
    ref_out, _ = run_loop(1, eos_id=None, max_new=3)
    out, st = run_loop(4, eos_id=None, max_new=3)      # K > max_new
    np.testing.assert_array_equal(out, ref_out)
    assert out.shape == (3, 4)
    assert st["emitted_per_slot"] == [3, 3, 3]
    assert st["stall"]["host_syncs"] == 1


def test_decode_chunk_rejects_bad_k():
    with pytest.raises(ValueError):
        DecodeEngine(scripted_step(SCRIPT), 0)


def test_tail_chunk_compiles_short_scan_variant():
    """max_new % chunk != 0: the final chunk runs a short scan (exactly the
    remaining steps) instead of K iterations with every slot masked off."""
    script = np.tile(np.arange(24, dtype=np.int32)[:, None], (1, 2))
    eng = DecodeEngine(scripted_step(script), 16, eos_id=None)
    out, _, _, emitted = eng.generate(None, fresh_cache(2),
                                      np.zeros((2, 1), np.int32), max_new=20)
    assert out.shape == (2, 21)
    assert sorted(eng._chunk_fns) == [4, 16]        # steady + tail variant
    assert [n for _, n in eng.chunk_latencies] == [16, 4]
    # parity with the per-token loop
    ref, _ = run_loop(1, eos_id=None, max_new=20, script=script)
    np.testing.assert_array_equal(out, ref)
    # the tail variant is cached: a second generate re-uses both programs
    eng.generate(None, fresh_cache(2), np.zeros((2, 1), np.int32),
                 max_new=20)
    assert sorted(eng._chunk_fns) == [4, 16]


def test_tail_chunk_shorter_than_one_chunk():
    out, st = run_loop(16, eos_id=None, max_new=3)  # K > max_new: one short scan
    ref, _ = run_loop(1, eos_id=None, max_new=3)
    np.testing.assert_array_equal(out, ref)
    assert st["stall"]["host_syncs"] == 1


# ----------------------------------------------------------------------------
# Donation: steady-state decode chunks allocate nothing new
# ----------------------------------------------------------------------------


def test_decode_chunk_donates_buffers():
    import gc

    step = scripted_step(np.zeros((64, 2), np.int32))
    chunk_fn = make_decode_chunk(step, 8)
    cache = fresh_cache(2)
    leaf = cache["kv"]
    state = (cache, jnp.zeros((2, 1), jnp.int32), jnp.zeros((2,), bool),
             jnp.zeros((2,), jnp.int32))

    def one_chunk(state, i):
        out = chunk_fn(None, *state, jnp.asarray(8 * i, jnp.int32),
                       jnp.asarray(8, jnp.int32))
        state = out[:4]
        del out
        jax.block_until_ready(state)
        gc.collect()
        return state

    state = one_chunk(state, 0)             # warmup (compile)
    # the donated input buffers are consumed
    assert leaf.is_deleted()
    state = one_chunk(state, 1)             # first steady-state chunk
    baseline = len(jax.live_arrays())
    for i in range(2, 5):
        state = one_chunk(state, i)
        # steady state: no growth in live device allocations across chunks
        assert len(jax.live_arrays()) == baseline


@pytest.mark.slow
def test_model_decode_parity_and_donation():
    """Real model: K=1 loop vs scan engine — tokens and EOS bit-identical."""
    cluster = Cluster("xlstm-125m-smoke")
    p1 = cluster.compile(ServeProgram(batch=2, max_seq=16, max_new=8,
                                      chunk=1))
    params = p1.init_params()
    r1 = p1.run(params=params)
    r4 = cluster.compile(ServeProgram(batch=2, max_seq=16, max_new=8,
                                      chunk=4)).run(params=params)
    np.testing.assert_array_equal(r1["tokens"], r4["tokens"])
    assert r1["stats"]["stall"]["host_syncs"] == 8
    assert r4["stats"]["stall"]["host_syncs"] == 2

    # EOS parity with a token the model really emits
    eos = int(r1["tokens"][0, 4])
    re1 = cluster.compile(ServeProgram(batch=2, max_seq=16, max_new=8,
                                       chunk=1, eos_id=eos)).run(params=params)
    re4 = cluster.compile(ServeProgram(batch=2, max_seq=16, max_new=8,
                                       chunk=4, eos_id=eos)).run(params=params)
    np.testing.assert_array_equal(re1["tokens"], re4["tokens"])
    assert (re1["stats"]["emitted_per_slot"]
            == re4["stats"]["emitted_per_slot"])
    assert re1["stats"]["finished_slots"] == re4["stats"]["finished_slots"]


# ----------------------------------------------------------------------------
# Chunked training: scan-of-steps matches the per-step loop
# ----------------------------------------------------------------------------


def _toy_step(state, batch):
    w = state["w"] + batch["x"].sum()
    return {"w": w}, {"loss": w * 0.5}


def test_train_chunk_matches_per_step():
    batches = [{"x": jnp.full((2,), float(i))} for i in range(4)]
    state = {"w": jnp.zeros(())}
    for b in batches:
        state, metrics = _toy_step(state, b)
    chunk = make_train_chunk(_toy_step, donate=False)
    cstate, cmetrics = chunk({"w": jnp.zeros(())}, stack_batches(batches))
    np.testing.assert_allclose(np.asarray(cstate["w"]), np.asarray(state["w"]))
    assert cmetrics["loss"].shape == (4,)
    np.testing.assert_allclose(float(cmetrics["loss"][-1]),
                               float(metrics["loss"]))


@pytest.mark.slow
def test_train_program_steps_per_sync(tmp_path):
    cluster = Cluster("xlstm-125m-smoke")
    r1 = cluster.compile(TrainProgram(
        num_steps=6, batch=2, seq=16, log_every=3,
        checkpoint_dir=str(tmp_path / "a"))).run()
    r3 = cluster.compile(TrainProgram(
        num_steps=6, batch=2, seq=16, log_every=3, steps_per_sync=3,
        checkpoint_dir=str(tmp_path / "b"))).run()
    assert r3["final_step"] == r1["final_step"] == 6
    assert r3["steps_per_sync"] == 3
    # host syncs collapse to one per chunk
    assert r1["stall"]["host_syncs"] == 6
    assert r3["stall"]["host_syncs"] == 2
    # logger samples at chunk granularity, same sampled losses
    assert [m["step"] for m in r3["metrics"]] == [3, 6]
    np.testing.assert_allclose([m["loss"] for m in r3["metrics"]],
                               [m["loss"] for m in r1["metrics"]],
                               rtol=1e-5)
    assert all(m["steps_in_chunk"] == 3 for m in r3["metrics"])


# ----------------------------------------------------------------------------
# Stall accounting
# ----------------------------------------------------------------------------


def test_stall_clock_ledger():
    clock = StallClock()
    clock.dispatch()
    clock.sync(jnp.zeros(()))
    time.sleep(0.02)                        # host-side gap (the stall)
    clock.dispatch()
    clock.sync(jnp.zeros(()))
    rep = clock.report()
    assert rep["host_syncs"] == 2
    assert rep["dispatch_gap_s"] >= 0.02
    assert 0.0 < rep["stall_pct"] <= 100.0
    assert rep["wall_s"] >= rep["dispatch_gap_s"]


def test_serve_stats_report_stall_and_chunk():
    _, st = run_loop(4)
    assert st["chunk"] == 4
    for key in ("host_syncs", "dispatch_gap_s", "device_wait_s", "stall_pct"):
        assert key in st["stall"]


# ----------------------------------------------------------------------------
# Pallas pipelining hints (compat-guarded)
# ----------------------------------------------------------------------------


def test_pallas_hints_filter_to_installed_surface():
    call_kw, cp_kw = compat.pallas_hints(
        cost={"flops": 100, "bytes_accessed": 10, "transcendentals": 0},
        num_stages=3, dimension_semantics=("parallel", "arbitrary"))
    # only knobs this install's pallas accepts survive
    assert set(call_kw) <= compat._pallas_call_params()
    assert set(cp_kw) <= compat._pallas_tpu_fields()
    compat.pallas_compiler_params(cp_kw)    # must construct cleanly
    if "cost_estimate" in compat._pallas_call_params():
        assert "cost_estimate" in call_kw
    none_call, none_cp = compat.pallas_hints()
    assert none_call == {} and none_cp == {}


def test_pipeline_stages_heuristic():
    from repro.kernels import axpy
    from repro.kernels import pipeline as pp

    # axpy streams ~3 bytes/flop — memory-bound, wants a deeper window
    p = axpy.build_pipeline(1024, 256, jnp.float32, block_rows=128)
    assert p.pipeline_stages() == 3
    # ...but not when a third slot set would bust the VMEM budget
    p = axpy.build_pipeline(8192, 1024, jnp.float32, block_rows=4096)
    assert p.pipeline_stages() == 2

    def synthetic(cost):
        tile = pp.TileSpec((128, 128), lambda i: (0, 0))
        return pp.KernelPipeline(
            "synthetic", lambda *refs: None, grid=(pp.GridAxis("i", 1),),
            in_tiles=[tile], out_tiles=tile,
            out_shape=jax.ShapeDtypeStruct((128, 128), jnp.float32),
            cost=cost)

    # compute-bound: classic double buffering already hides the transfers
    compute = pp.Traffic(flops=1e12, hbm_bytes=1e6, ideal_bytes=1e6,
                         grid_steps=1, vmem_bytes=0)
    assert synthetic(compute).pipeline_stages() == 2
    memory = pp.Traffic(flops=1e6, hbm_bytes=1e12, ideal_bytes=1e12,
                        grid_steps=1, vmem_bytes=0)
    assert synthetic(memory).pipeline_stages() == 3
    assert synthetic(None).pipeline_stages() is None
