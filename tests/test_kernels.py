"""Per-kernel allclose sweeps against the ref.py oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:              # bare env without the [test] extra
    from hypothesis_fallback import given, settings, strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (256, 256, 256, 128, 128, 128),
    (512, 384, 256, 128, 128, 128),
    (128, 512, 640, 128, 128, 256),
])
def test_matmul_sweep(dtype, m, k, n, bm, bn, bk):
    a = rand(jax.random.PRNGKey(0), (m, k), dtype)
    b = rand(jax.random.PRNGKey(1), (k, n), dtype)
    got = ops.matmul(a, b, bm=bm, bn=bn, bk=bk)
    want = ref.matmul(a, b)
    np.testing.assert_allclose(got.astype(np.float32),
                               want.astype(np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(512, 128), (1024, 256), (2048, 512)])
def test_axpy_sweep(dtype, shape):
    x = rand(jax.random.PRNGKey(2), shape, dtype)
    y = rand(jax.random.PRNGKey(3), shape, dtype)
    np.testing.assert_allclose(
        ops.axpy(1.7, x, y).astype(np.float32),
        ref.axpy(1.7, x, y).astype(np.float32), **TOL[dtype])


@pytest.mark.parametrize("shape", [(512, 128), (1024, 384)])
def test_dotp_sweep(shape):
    x = rand(jax.random.PRNGKey(4), shape, jnp.float32)
    y = rand(jax.random.PRNGKey(5), shape, jnp.float32)
    np.testing.assert_allclose(ops.dotp(x, y), ref.dotp(x, y),
                               rtol=1e-3)


@pytest.mark.parametrize("hw", [(256, 128), (512, 256), (1024, 128)])
def test_conv2d_sweep(hw):
    img = rand(jax.random.PRNGKey(6), hw, jnp.float32)
    w = rand(jax.random.PRNGKey(7), (3, 3), jnp.float32)
    np.testing.assert_allclose(ops.conv2d_3x3(img, w), ref.conv2d_3x3(img, w),
                               rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("n", [512, 1024, 2048])
def test_dct8x8_sweep(n):
    blocks = rand(jax.random.PRNGKey(8), (n, 8, 8), jnp.float32)
    np.testing.assert_allclose(ops.dct8x8(blocks), ref.dct8x8(blocks),
                               rtol=1e-3, atol=1e-4)


def test_dct_energy_preservation():
    """2-D DCT is orthonormal: per-block energy is preserved."""
    blocks = rand(jax.random.PRNGKey(9), (256, 8, 8), jnp.float32)
    out = np.asarray(ops.dct8x8(blocks), np.float64)
    inp = np.asarray(blocks, np.float64)
    np.testing.assert_allclose((out ** 2).sum(axis=(1, 2)),
                               (inp ** 2).sum(axis=(1, 2)), rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(256, 512), (512, 768)])
def test_rmsnorm_sweep(dtype, shape):
    x = rand(jax.random.PRNGKey(10), shape, dtype)
    s = rand(jax.random.PRNGKey(11), shape[-1:], jnp.float32) * 0.1
    np.testing.assert_allclose(
        ops.rmsnorm(x, s.astype(dtype)).astype(np.float32),
        ref.rmsnorm(x, s.astype(dtype)).astype(np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,s,hd,bq,bk", [
    (2, 4, 4, 256, 64, 64, 64),       # MHA
    (2, 4, 2, 256, 64, 128, 64),      # GQA group 2
    (1, 8, 1, 512, 128, 128, 128),    # MQA
])
def test_flash_attention_sweep(dtype, b, h, kv, s, hd, bq, bk):
    q = rand(jax.random.PRNGKey(12), (b, h, s, hd), dtype)
    k = rand(jax.random.PRNGKey(13), (b, kv, s, hd), dtype)
    v = rand(jax.random.PRNGKey(14), (b, kv, s, hd), dtype)
    got = ops.flash_attention(q, k, v, bq=bq, bk=bk)
    kr = jnp.repeat(k, h // kv, axis=1)
    vr = jnp.repeat(v, h // kv, axis=1)
    want = ref.flash_attention(q, kr, vr)
    tol = dict(rtol=2e-3, atol=2e-3) if dtype == jnp.float32 \
        else dict(rtol=6e-2, atol=6e-2)
    np.testing.assert_allclose(got.astype(np.float32),
                               want.astype(np.float32), **tol)


def test_flash_attention_non_causal():
    q = rand(jax.random.PRNGKey(15), (1, 2, 128, 64), jnp.float32)
    k = rand(jax.random.PRNGKey(16), (1, 2, 128, 64), jnp.float32)
    v = rand(jax.random.PRNGKey(17), (1, 2, 128, 64), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False, bq=64, bk=64)
    want = ref.flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(mb=st.integers(1, 4), kb=st.integers(1, 4), nb=st.integers(1, 4),
       seed=st.integers(0, 2 ** 16))
def test_matmul_property(mb, kb, nb, seed):
    """Property: kernel == oracle for arbitrary block-aligned shapes."""
    m, k, n = 128 * mb, 128 * kb, 128 * nb
    a = rand(jax.random.PRNGKey(seed), (m, k), jnp.float32)
    b = rand(jax.random.PRNGKey(seed + 1), (k, n), jnp.float32)
    np.testing.assert_allclose(ops.matmul(a, b, bm=128, bn=128, bk=128),
                               ref.matmul(a, b), rtol=2e-4, atol=2e-4)
