import os
import sys
from pathlib import Path

# make `repro` importable without installation (PYTHONPATH=src also works)
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# make sibling test helpers (hypothesis_fallback) importable regardless of
# pytest import mode
TESTS = Path(__file__).resolve().parent
if str(TESTS) not in sys.path:
    sys.path.insert(0, str(TESTS))

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only launch/dryrun.py forces 512 (and the
# dry-run CI test spawns a subprocess with REPRO_DRYRUN_DEVICES=8).

# Tests run under the deterministic "modeled" tune mode: a timed race on
# every autotune-on-miss would make the suite slow and wall-clock-dependent.
# Tests that target the timed path opt in explicitly (mode="timed", usually
# with an injected timer — see test_tunedb.py). setdefault, so an outer
# REPRO_TUNE_MODE still wins.
os.environ.setdefault("REPRO_TUNE_MODE", "modeled")
