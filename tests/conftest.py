import os
import sys
from pathlib import Path

# make `repro` importable without installation (PYTHONPATH=src also works)
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only launch/dryrun.py forces 512 (and the
# dry-run CI test spawns a subprocess with REPRO_DRYRUN_DEVICES=8).
