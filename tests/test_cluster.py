"""The Cluster/Session façade and the KernelPolicy dispatch layer.

Covers the policy satellites (scoped override nesting, per-op overrides,
interpret-mode equivalence with the REPRO_INTERPRET env path, policy-
respected dispatch in tuned_call), the Cluster programs + compile cache,
the api.* shims (identical report keys and matching loss/tokens vs the
Cluster path on a smoke config), and ServeLoop's EOS handling.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import (Cluster, KernelPolicy, ServeProgram, TrainProgram,
                           current_policy, default_policy, use_policy)
from repro.configs import registry
from repro.kernels import ops, ref
from repro.runtime.serve_loop import ServeLoop


def rand(seed, shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


# ----------------------------------------------------------------------------
# KernelPolicy: scoping, overrides, interpret equivalence, tuned_call
# ----------------------------------------------------------------------------


def test_policy_scope_nesting():
    assert current_policy().mode == "tuned"          # env default
    with use_policy("fused") as outer:
        assert current_policy() is outer
        assert current_policy().fused
        with use_policy(KernelPolicy(mode="reference")) as inner:
            assert current_policy() is inner
            assert current_policy().mode == "reference"
            assert not current_policy().fused
        assert current_policy() is outer             # inner scope popped
    assert current_policy().mode == "tuned"


def test_policy_validation():
    with pytest.raises(ValueError):
        KernelPolicy(mode="warp-speed")
    with pytest.raises(ValueError):
        KernelPolicy(overrides={"matmul": "warp-speed"})
    with pytest.raises(TypeError):
        KernelPolicy(overrides={"matmul": 42})


def test_policy_per_op_override_routes_to_reference():
    a, b = rand(0, (16, 24)), rand(1, (24, 16))
    pol = KernelPolicy(mode="tuned", overrides={"matmul": "reference"})
    assert pol.mode_for("matmul") == "reference"
    assert pol.mode_for("axpy") == "tuned"
    with use_policy(pol):
        got = ops.matmul(a, b)
        other = ops.axpy(2.0, a, a)
    assert pol.stats["ref_calls"] == 1               # matmul short-circuited
    assert pol.stats["pallas_calls"] == 1            # axpy ran the kernel
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.matmul(a, b)),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(other),
                               np.asarray(ref.axpy(2.0, a, a)),
                               rtol=1e-6, atol=1e-6)


def test_interpret_mode_matches_env_path():
    """KernelPolicy(mode='interpret') == the legacy REPRO_INTERPRET env."""
    a, b = rand(2, (16, 16)), rand(3, (16, 16))
    with use_policy("interpret") as pol:
        assert pol.interpret_for("matmul")
        got_policy = ops.matmul(a, b)
    old = os.environ.get("REPRO_INTERPRET")
    try:
        os.environ["REPRO_INTERPRET"] = "1"
        assert default_policy().mode == "interpret"  # env -> default policy
        got_env = ops.matmul(a, b)                   # no scope: env default
    finally:
        if old is None:
            os.environ.pop("REPRO_INTERPRET", None)
        else:
            os.environ["REPRO_INTERPRET"] = old
    assert default_policy().mode == "tuned"
    np.testing.assert_array_equal(np.asarray(got_policy), np.asarray(got_env))


def test_tuned_call_respects_policy():
    registry.KERNEL_TUNES.clear()
    a, b = rand(4, (48, 32)), rand(5, (32, 40))
    want = np.asarray(ref.matmul(a, b))

    # (1) reference override short-circuits tuned_call entirely
    with use_policy(KernelPolicy(overrides={"matmul": "reference"})) as pol:
        got = ops.tuned_call("matmul", a, b)
    assert pol.stats == {"ref_calls": 1}
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6, atol=1e-6)

    # (2) pinned blocks skip the registry (block_overrides counted)
    pinned = KernelPolicy(overrides={"matmul": {"bm": 16, "bn": 8, "bk": 32}})
    with use_policy(pinned):
        got = ops.tuned_call("matmul", a, b)
    assert pinned.stats["block_overrides"] == 1
    assert "tune_hits" not in pinned.stats
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)

    # (3) default: autotune-on-miss then registry hit, both counted
    with use_policy("tuned") as pol:
        ops.tuned_call("matmul", a, b)
        ops.tuned_call("matmul", a, b)
    assert pol.stats["tune_misses"] == 1
    assert pol.stats["tune_hits"] == 1
    key = pp_shape_key({"m": 48, "k": 32, "n": 40})
    assert registry.get_kernel_tune("matmul", key) is not None


def pp_shape_key(shapes):
    from repro.kernels import pipeline as pp
    return pp.shape_key(shapes)


# ----------------------------------------------------------------------------
# Cluster: plan, policy scope, compile cache
# ----------------------------------------------------------------------------


def test_cluster_plan_matches_api_plan():
    from repro import api
    from repro.core import compat
    mesh = compat.abstract_mesh((2, 2), ("data", "model"))
    assert Cluster("qwen3-14b", mesh).plan() == api.plan("qwen3-14b", mesh)


def test_cluster_policy_scope_sets_cluster_default():
    cluster = Cluster()                              # kernel-only cluster
    assert cluster.kernel_policy.mode == "tuned"
    with cluster.policy("fused") as pol:
        assert cluster.kernel_policy is pol
        assert current_policy() is pol
    assert cluster.kernel_policy.mode == "tuned"
    with cluster.policy(mode="tuned", overrides={"matmul": "reference"}) as p:
        assert p.mode_for("matmul") == "reference"
    with pytest.raises(ValueError):
        cluster.plan()                               # no arch attached


def test_cluster_compile_cache_memoizes_programs():
    cluster = Cluster("xlstm-125m-smoke")
    spec = ServeProgram(batch=2, max_seq=16, max_new=2)
    p1 = cluster.compile(spec)
    p2 = cluster.compile(ServeProgram(batch=2, max_seq=16, max_new=2))
    assert p1 is p2
    assert cluster.compile_cache.hits == 1
    # a different spec, and a different policy scope, compile fresh
    p3 = cluster.compile(ServeProgram(batch=4, max_seq=16, max_new=2))
    assert p3 is not p1
    with cluster.policy("fused"):
        p4 = cluster.compile(ServeProgram(batch=2, max_seq=16, max_new=2))
    assert p4 is not p1
    assert p4.policy.fused


def test_cluster_rejects_unknown_program():
    with pytest.raises(TypeError):
        Cluster("xlstm-125m-smoke").compile({"not": "a program"})


# ----------------------------------------------------------------------------
# Shim equivalence: api.train/serve == the Cluster path (acceptance)
# ----------------------------------------------------------------------------


@pytest.mark.slow
def test_api_shims_match_cluster_programs(tmp_path):
    from repro import api
    r_api = api.train("xlstm-125m", num_steps=3, batch=2, seq=16,
                      checkpoint_dir=str(tmp_path / "api"))
    cluster = Cluster("xlstm-125m-smoke")
    r_clu = cluster.compile(TrainProgram(
        num_steps=3, batch=2, seq=16,
        checkpoint_dir=str(tmp_path / "clu"))).run()
    assert sorted(r_api.keys()) == sorted(r_clu.keys())
    losses = lambda r: [m["loss"] for m in r["metrics"]]
    np.testing.assert_allclose(losses(r_api), losses(r_clu), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(r_api["params"]),
                    jax.tree.leaves(r_clu["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))

    s_api = api.serve("xlstm-125m", r_api["params"], batch=2, max_seq=16,
                      max_new=4)
    s_clu = cluster.compile(ServeProgram(batch=2, max_seq=16, max_new=4)) \
        .run(params=r_clu["params"])
    assert sorted(s_api.keys()) == sorted(s_clu.keys())
    np.testing.assert_array_equal(s_api["tokens"], s_clu["tokens"])


@pytest.mark.slow
def test_train_program_report_and_plan(tmp_path):
    cluster = Cluster("xlstm-125m-smoke")
    prog = cluster.compile(TrainProgram(num_steps=2, batch=2, seq=16,
                                        checkpoint_dir=str(tmp_path)))
    assert prog.plan() == cluster.plan()
    rep = prog.report()
    assert rep["kind"] == "train" and rep["arch"] == "xlstm-125m-smoke"
    assert "result" not in rep                        # not run yet
    prog.run()
    rep = prog.report()
    assert rep["result"]["final_step"] == 2
    assert "params" not in rep["result"]              # arrays stripped


# ----------------------------------------------------------------------------
# ServeLoop EOS handling (satellite)
# ----------------------------------------------------------------------------


def _scripted_decode(script):
    """decode_step emitting script[pos] (a (B,) row) at each position."""
    def decode_step(params, cache, batch):
        pos = int(batch["pos"])
        return cache, jnp.asarray(script[pos])[:, None].astype(jnp.int32)
    return decode_step


def test_serve_loop_eos_masks_and_stops():
    # slot 0 hits EOS (=7) at step 1, slot 1 at step 2; B=3 never does
    script = {0: np.array([7, 1, 2]), 1: np.array([3, 7, 4]),
              2: np.array([5, 6, 8]), 3: np.array([9, 9, 9])}
    loop = ServeLoop(_scripted_decode(script), None, None, batch_size=3,
                     eos_id=7)
    out = loop.generate(np.zeros((3, 1), np.int32), max_new=4)
    # slot 0: eos at step 0, masked afterward
    np.testing.assert_array_equal(out[0], [0, 7, 7, 7, 7])
    np.testing.assert_array_equal(out[1], [0, 1, 7, 7, 7])
    np.testing.assert_array_equal(out[2], [0, 2, 4, 8, 9])
    st = loop.stats()
    assert st["emitted_per_slot"] == [1, 2, 4]
    assert st["finished_slots"] == 2


def test_serve_loop_eos_early_stop():
    script = {0: np.array([7, 7]), 1: np.array([1, 1]), 2: np.array([1, 1])}
    loop = ServeLoop(_scripted_decode(script), None, None, batch_size=2,
                     eos_id=7)
    out = loop.generate(np.zeros((2, 1), np.int32), max_new=10)
    assert out.shape == (2, 2)                       # stopped after step 1
    assert len(loop.latencies) == 1
    assert loop.stats()["emitted_per_slot"] == [1, 1]
    assert loop.stats()["finished_slots"] == 2


def test_serve_loop_no_eos_unchanged():
    script = {i: np.array([7, 7]) for i in range(4)}
    loop = ServeLoop(_scripted_decode(script), None, None, batch_size=2)
    out = loop.generate(np.zeros((2, 1), np.int32), max_new=4)
    assert out.shape == (2, 5)                       # eos disabled: full run
    assert loop.stats()["emitted_per_slot"] == [4, 4]
    assert "finished_slots" not in loop.stats()
