"""Timed autotuning + TuneDB: the race, the disk cache, and the perf gate.

The race itself is tested with *scripted* timers (`timer(fn, blocks)`
injection) so outcomes are deterministic — who wins is the script's
choice, not the wall clock's. What's under test is the selection logic:
the measured winner is kept, the default lane can win, a lane that throws
cannot, and the tuned <= default invariant holds by construction.

DB tests cover the persistence contract: round-trip, warm-start without
re-racing (the second-benchmark-run-is-race-free property), corrupt and
stale-schema files degrading to cold autotune, and frozen mode never
touching disk. Cluster tests pin the exact counter traffic.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

# benchmarks/ is a namespace package off the repo root
ROOT = Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))

from repro.cluster import Cluster, KernelPolicy, use_policy  # noqa: E402
from repro.configs import registry  # noqa: E402
from repro.kernels import ops, pipeline as pp, tunedb  # noqa: E402

SHAPES = {"m": 512, "n": 512, "k": 512}
KEY = pp.shape_key(SHAPES, 4)
BACKEND = jax.default_backend()


@pytest.fixture(autouse=True)
def _clean_tunes():
    registry.KERNEL_TUNES.clear()
    tunedb.set_active_db(None)
    yield
    registry.KERNEL_TUNES.clear()
    tunedb.reset_active_db()


def scripted_timer(script: dict, default: float = 1.0):
    """timer(fn, blocks) that never runs fn — returns scripted seconds."""
    def timer(fn, blocks):
        return script.get(tuple(sorted(blocks.items())), default)
    return timer


def modeled_pick(kernel: str = "matmul", shapes: dict = SHAPES) -> dict:
    return dict(pp.autotune(kernel, shapes, mode="modeled",
                            register_record=False).blocks)


# ----------------------------------------------------------------------------
# the race
# ----------------------------------------------------------------------------

def test_race_picks_fastest_candidate():
    """The scripted-fastest lane (here: the modeled-best candidate) wins,
    and the record carries real measured_us/default_us from the race."""
    best = modeled_pick()
    default = pp.KERNELS["matmul"].default_blocks(SHAPES)
    assert best != default          # 512^3: model prefers bigger tiles
    script = {tuple(sorted(best.items())): 0.5,
              tuple(sorted(default.items())): 2.0}
    r = pp.autotune("matmul", SHAPES, mode="timed",
                    timer=scripted_timer(script))
    assert r.source == "timed" and r.raced >= 2
    assert r.blocks == best
    assert r.measured_us == pytest.approx(0.5e6)
    assert r.default_us == pytest.approx(2.0e6)
    assert r.measured_speedup == pytest.approx(4.0)
    rec = registry.get_kernel_tune("matmul", KEY)
    assert rec.timed and rec.source == "timed"
    assert rec.measured_speedup == pytest.approx(4.0)


def test_race_default_lane_can_win():
    """When the default times fastest, the tuner keeps it — tuned is never
    slower than default because default is itself a race lane."""
    default = pp.KERNELS["matmul"].default_blocks(SHAPES)
    script = {tuple(sorted(default.items())): 0.1}
    r = pp.autotune("matmul", SHAPES, mode="timed",
                    timer=scripted_timer(script, default=1.0))
    assert r.blocks == dict(default)
    assert r.measured_us == r.default_us == pytest.approx(0.1e6)
    assert r.measured_speedup == pytest.approx(1.0)
    assert r.measured_us <= r.default_us


def test_race_erroring_lane_cannot_win():
    """A lane whose timer throws is scored inf; the survivors race on."""
    default = pp.KERNELS["matmul"].default_blocks(SHAPES)
    default_key = tuple(sorted(default.items()))

    def timer(fn, blocks):
        if tuple(sorted(blocks.items())) != default_key:
            raise RuntimeError("candidate refused to compile")
        return 0.3
    r = pp.autotune("matmul", SHAPES, mode="timed", timer=timer)
    assert r.source == "timed"
    assert r.blocks == dict(default)


def test_race_all_lanes_failing_falls_back_to_modeled():
    def timer(fn, blocks):
        raise RuntimeError("no lane runs")
    r = pp.autotune("matmul", SHAPES, mode="timed", timer=timer)
    assert r.source == "modeled" and not r.timed and r.raced == 0
    assert r.blocks == modeled_pick()


def test_modeled_mode_never_races():
    def timer(fn, blocks):              # must never be consulted
        raise AssertionError("modeled mode raced")
    r = pp.autotune("matmul", SHAPES, mode="modeled", timer=timer)
    assert r.source == "modeled" and r.raced == 0 and r.measured_us == 0.0


def test_timed_race_on_device_tuned_not_slower(monkeypatch):
    """One real (unscripted) race: the acceptance invariant, measured."""
    monkeypatch.setenv("REPRO_TUNE_REPS", "1")
    r = pp.autotune("matmul", {"m": 256, "n": 256, "k": 256}, mode="timed")
    assert r.source == "timed" and r.raced >= 1
    assert r.measured_us <= r.default_us * (1 + 1e-9)
    assert r.measured_speedup >= 1.0


# ----------------------------------------------------------------------------
# the composition lane: fused-vs-unfused routing
# ----------------------------------------------------------------------------

COMP_KEY = tuple(sorted(pp.COMPOSITION_LANE.items()))


def test_composition_lane_wins_routes_unfused():
    """When a fused kernel's unfused composition times fastest, the race
    demotes the fusion: route flips to "unfused", measured_us is the
    composition's time, and the best *kernel* blocking is still recorded."""
    best = modeled_pick("rmsnorm_matmul")
    script = {COMP_KEY: 0.1}
    r = pp.autotune("rmsnorm_matmul", SHAPES, mode="timed",
                    timer=scripted_timer(script, default=1.0))
    assert r.source == "timed" and r.route == "unfused"
    assert r.measured_us == pytest.approx(0.1e6)
    assert r.blocks == best
    rec = registry.get_kernel_tune("rmsnorm_matmul", pp.shape_key(SHAPES, 4))
    assert rec.route == "unfused" and rec.timed


def test_composition_lane_losing_keeps_fused_route():
    best = modeled_pick("rmsnorm_matmul")
    script = {COMP_KEY: 5.0, tuple(sorted(best.items())): 0.5}
    r = pp.autotune("rmsnorm_matmul", SHAPES, mode="timed",
                    timer=scripted_timer(script, default=1.0))
    assert r.route == "fused"
    assert r.blocks == best
    assert r.measured_us == pytest.approx(0.5e6)
    rec = registry.get_kernel_tune("rmsnorm_matmul", pp.shape_key(SHAPES, 4))
    assert rec.route == "fused"


def test_composition_lane_erroring_keeps_fused_route():
    """A composition that won't run can't win — scored inf like any lane."""
    def timer(fn, blocks):
        if tuple(sorted(blocks.items())) == COMP_KEY:
            raise RuntimeError("composition refused to compile")
        return 1.0
    r = pp.autotune("rmsnorm_matmul", SHAPES, mode="timed", timer=timer)
    assert r.source == "timed" and r.route == "fused"


def test_unfused_kernel_has_no_composition_lane():
    """matmul carries no composition; the sentinel never reaches the timer."""
    def timer(fn, blocks):
        assert "route" not in blocks
        return 1.0
    r = pp.autotune("matmul", SHAPES, mode="timed", timer=timer)
    assert r.source == "timed" and r.route == "fused"


def test_route_survives_db_round_trip(tmp_path):
    pp.autotune("rmsnorm_matmul", SHAPES, mode="timed",
                timer=scripted_timer({COMP_KEY: 0.1}, default=1.0))
    rec = registry.get_kernel_tune("rmsnorm_matmul", pp.shape_key(SHAPES, 4))
    assert rec.route == "unfused"
    path = tmp_path / "tunes.json"
    tunedb.TuneDB(path).record(rec, backend=BACKEND, mode="tuned")
    got = tunedb.TuneDB(path).get(BACKEND, "tuned", "rmsnorm_matmul",
                                  pp.shape_key(SHAPES, 4))
    assert got == rec and got.route == "unfused"


def test_policy_dispatches_composition_on_unfused_route():
    """tuned_call honors a demoted fusion: the unfused composition runs
    (unfused_routes counter) and the numerics still match the reference."""
    m = k = n = 256
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    scale = jnp.ones((k,), jnp.float32) * 0.1
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    shapes = ops.kernel_shapes("rmsnorm_matmul", x, scale, w)
    pp.autotune("rmsnorm_matmul", shapes, mode="timed",
                timer=scripted_timer({COMP_KEY: 0.1}, default=1.0))
    pol = KernelPolicy(mode="tuned")
    with use_policy(pol):
        out = ops.tuned_call("rmsnorm_matmul", x, scale, w)
    assert pol.stats.get("unfused_routes") == 1
    assert pol.stats.get("tune_hits") == 1
    ref = ops.OPS["rmsnorm_matmul"].reference(x, scale, w)
    assert jnp.allclose(out, ref, atol=2e-2, rtol=2e-2)


# ----------------------------------------------------------------------------
# TuneDB persistence
# ----------------------------------------------------------------------------

def _timed_record() -> registry.KernelTuneRecord:
    script = {tuple(sorted(modeled_pick().items())): 0.5}
    pp.autotune("matmul", SHAPES, mode="timed",
                timer=scripted_timer(script, default=2.0))
    return registry.get_kernel_tune("matmul", KEY)


def test_db_round_trip(tmp_path):
    rec = _timed_record()
    path = tmp_path / "tunes.json"
    db = tunedb.TuneDB(path)
    db.record(rec, backend=BACKEND, mode="tuned")
    assert path.exists() and db.stores == 1

    db2 = tunedb.TuneDB(path)
    assert len(db2) == 1 and db2.loads == 1 and db2.load_errors == 0
    got = db2.get(BACKEND, "tuned", "matmul", KEY)
    assert got == rec               # full field-for-field round trip
    assert got.measured_speedup == pytest.approx(rec.measured_speedup)
    # other (backend, mode) keys don't alias
    assert db2.get(BACKEND, "fused", "matmul", KEY) is None
    assert db2.get("tpu" if BACKEND != "tpu" else "cpu",
                   "tuned", "matmul", KEY) is None


def test_db_warm_start_no_rerace(tmp_path):
    rec = _timed_record()
    path = tmp_path / "tunes.json"
    tunedb.TuneDB(path).record(rec, backend=BACKEND, mode="tuned")

    # fresh process simulation: empty registry, warm DB
    registry.KERNEL_TUNES.clear()
    db = tunedb.TuneDB(path)
    assert db.warm_start(backend=BACKEND, mode="tuned") == 1
    warm = registry.get_kernel_tune("matmul", KEY)
    assert warm.source == "db" and warm.timed
    assert dict(warm.blocks) == dict(rec.blocks)

    def timer(fn, blocks):
        raise AssertionError("warm-started record re-raced")
    with tunedb.use_db(db):
        got = pp.tuned_record("matmul", SHAPES, timer=timer, mode="timed")
    assert got is warm              # registry hit, no autotune at all

    # in-memory records take precedence over a second warm-start
    assert db.warm_start(backend=BACKEND, mode="tuned") == 0


def test_corrupt_db_falls_back_cold(tmp_path):
    path = tmp_path / "tunes.json"
    path.write_text("{not json")
    db = tunedb.TuneDB(path)
    assert len(db) == 0 and db.load_errors == 1
    assert db.warm_start(backend=BACKEND, mode="tuned") == 0
    # cold autotune still works and can repair the file
    rec = _timed_record()
    db.record(rec, backend=BACKEND, mode="tuned")
    assert len(tunedb.TuneDB(path)) == 1


def test_stale_schema_db_ignored(tmp_path):
    path = tmp_path / "tunes.json"
    path.write_text(json.dumps({"version": 999, "records": [{"bogus": 1}]}))
    db = tunedb.TuneDB(path)
    assert len(db) == 0 and db.load_errors == 1
    # a save rewrites the current schema
    db.save()
    assert json.loads(path.read_text())["version"] == tunedb.SCHEMA_VERSION


def test_frozen_db_never_writes(tmp_path):
    rec = _timed_record()
    path = tmp_path / "tunes.json"
    db = tunedb.TuneDB(path, frozen=True)
    db.record(rec, backend=BACKEND, mode="tuned")
    db.save()
    assert not path.exists()
    assert db.stores == 0 and db.write_skips == 2


def test_frozen_mode_autotune_no_race_no_write(tmp_path):
    path = tmp_path / "tunes.json"
    db = tunedb.TuneDB(path)

    def timer(fn, blocks):
        raise AssertionError("frozen mode raced")
    with tunedb.use_db(db):
        r = pp.autotune("matmul", SHAPES, mode="frozen", timer=timer)
    assert r.source == "modeled" and r.raced == 0
    assert len(db) == 0 and not path.exists()


def test_autotune_writes_through_active_db(tmp_path):
    path = tmp_path / "tunes.json"
    db = tunedb.TuneDB(path)
    script = {tuple(sorted(modeled_pick().items())): 0.5}
    with tunedb.use_db(db):
        pp.autotune("matmul", SHAPES, mode="timed",
                    timer=scripted_timer(script, default=2.0))
    assert len(db) == 1 and path.exists()
    got = db.get(BACKEND, "tuned", "matmul", KEY)
    assert got is not None and got.source == "timed"


def test_modeled_pick_not_written_to_db(tmp_path):
    """Only timed picks persist — a modeled pick must not poison later
    warm-starts with an unmeasured blocking."""
    db = tunedb.TuneDB(tmp_path / "tunes.json")
    with tunedb.use_db(db):
        pp.autotune("matmul", SHAPES, mode="modeled")
    assert len(db) == 0


def test_tune_mode_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_TUNE_MODE", raising=False)
    assert tunedb.tune_mode() == "timed"
    monkeypatch.setenv("REPRO_TUNE_MODE", "frozen")
    assert tunedb.tune_mode() == "frozen"
    assert tunedb.tune_mode("modeled") == "modeled"   # explicit arg wins
    # policy.tuning outranks the env
    with use_policy(KernelPolicy(mode="tuned", tuning="timed")):
        assert tunedb.tune_mode() == "timed"
    with pytest.raises(ValueError):
        tunedb.tune_mode("warp")


# ----------------------------------------------------------------------------
# Cluster integration: counters + warm-start
# ----------------------------------------------------------------------------

def test_cluster_counters_and_warm_start(tmp_path):
    path = tmp_path / "tunes.json"
    a = jnp.ones((256, 256), jnp.float32)
    b = jnp.ones((256, 256), jnp.float32)

    c1 = Cluster(policy=KernelPolicy(mode="tuned", tuning="timed"),
                 tune_db=str(path))
    assert c1.tune_db_warm == 0
    with use_policy(c1._policy):
        ops.tuned_call("matmul", a, b)      # miss -> race
        ops.tuned_call("matmul", a, b)      # registry hit
    st = c1._policy.stats
    assert st["tune_misses"] == 1 and st["tune_races"] == 1
    assert st["tune_hits"] == 1
    assert len(c1.tune_db) == 1

    # "second process": registry cold, same DB -> warm start, zero races
    registry.KERNEL_TUNES.clear()
    tunedb.set_active_db(None)
    c2 = Cluster(policy=KernelPolicy(mode="tuned", tuning="timed"),
                 tune_db=str(path))
    assert c2.tune_db_warm == 1
    with use_policy(c2._policy):
        ops.tuned_call("matmul", a, b)
    st2 = c2._policy.stats
    assert st2.get("tune_hits") == 1
    assert "tune_misses" not in st2 and "tune_races" not in st2


def test_program_report_carries_tunedb(tmp_path):
    from repro.cluster import BenchProgram
    path = tmp_path / "tunes.json"
    cluster = Cluster(policy="tuned", tune_db=str(path))
    program = cluster.compile(BenchProgram(sections=("table1",), smoke=True))
    rep = program.report()
    assert rep["tunedb"]["path"] == str(path)
    assert rep["tunedb"]["warm_started"] == 0
    assert rep["policy"]["tuning"] == "auto"


def test_cluster_without_db_has_no_tunedb_report():
    from repro.cluster import BenchProgram
    cluster = Cluster(policy="tuned")
    assert cluster.tune_db is None
    rep = cluster.compile(
        BenchProgram(sections=("table1",), smoke=True)).report()
    assert "tunedb" not in rep


# ----------------------------------------------------------------------------
# the second benchmark run is race-free (the bench's own racing path)
# ----------------------------------------------------------------------------

def test_second_bench_run_zero_races(tmp_path):
    """bench_table1_kernels.tuned_rows twice against one DB: run 1 races
    every kernel, run 2 (cold registry, warm DB) races none — the property
    the CI tune-DB cache exists for."""
    from benchmarks import bench_table1_kernels as b1

    path = tmp_path / "tunes.json"
    db = tunedb.TuneDB(path)
    pol1 = KernelPolicy(mode="tuned", tuning="timed")
    with tunedb.use_db(db), use_policy(pol1):
        rows1 = b1.tuned_rows(smoke=True)
    assert pol1.stats["tune_races"] == len(rows1)
    assert pol1.stats["tune_misses"] == len(rows1)
    for r in rows1:
        assert r["source"] == "timed"
        assert r["us_tuned"] <= r["us_default"] * (1 + 1e-9), r
        assert r["measured_speedup"] >= 1.0

    # fresh process: cold registry, warm DB
    registry.KERNEL_TUNES.clear()
    db2 = tunedb.TuneDB(path)
    assert db2.warm_start(backend=BACKEND, mode="tuned") == len(rows1)
    pol2 = KernelPolicy(mode="tuned", tuning="timed")
    with tunedb.use_db(db2), use_policy(pol2):
        rows2 = b1.tuned_rows(smoke=True)
    assert "tune_races" not in pol2.stats and "tune_misses" not in pol2.stats
    assert pol2.stats["tune_hits"] == len(rows2)
    assert [r["blocks"] for r in rows2] == [r["blocks"] for r in rows1]
    assert db2.stores == 0          # nothing new to write


# ----------------------------------------------------------------------------
# the perf gate
# ----------------------------------------------------------------------------

def _gate_record(tuned_us: float, default_us: float) -> dict:
    return {
        "rows": [
            {"name": "table1_tuned/matmul", "us_per_call": tuned_us,
             "derived": f"default_us={default_us:.1f};blocks=bm=512;"
                        f"measured_speedup=1.50;source=timed;p_local=0.9"},
            {"name": "table1_fused/rmsnorm_matmul", "us_per_call": 100.0,
             "derived": "unfused_us=150.0;bytes_reduction=2.5"},
        ],
        "decode": [
            {"name": "decode/K1", "us_per_call": 1000.0,
             "derived": "tokens_per_s=1500.0;stall_pct=0.2;host_syncs=32"},
            {"name": "decode/K16", "us_per_call": 500.0,
             "derived": "tokens_per_s=3800.0;stall_pct=0.5;host_syncs=2"},
        ],
        "serve_continuous": [
            {"name": "serve/continuous", "us_per_call": 180.0,
             "derived": "tokens_per_s=5400.0;occupancy_pct=79.0;p99_ms=90"},
            {"name": "serve/static", "us_per_call": 340.0,
             "derived": "tokens_per_s=2900.0;occupancy_pct=45.0;p99_ms=180"},
        ],
    }


def _run_gate(tmp_path, record, baseline=None, require="tuned", tol=0.15):
    from benchmarks import check_gate
    bench = tmp_path / "bench.json"
    bench.write_text(json.dumps(record))
    argv = ["--bench", str(bench), "--require", require, "--tol", str(tol)]
    if baseline is not None:
        base = tmp_path / "baseline.json"
        base.write_text(json.dumps(baseline))
        argv += ["--baseline", str(base)]
    return check_gate.main(argv)


def test_gate_passes_when_tuned_not_slower(tmp_path):
    assert _run_gate(tmp_path, _gate_record(90.0, 100.0),
                     require="tuned,fused,decode,serve") == 0


def test_gate_fails_when_tuned_slower(tmp_path):
    assert _run_gate(tmp_path, _gate_record(130.0, 100.0)) == 1


def test_gate_tolerance_absorbs_timer_noise(tmp_path):
    assert _run_gate(tmp_path, _gate_record(110.0, 100.0), tol=0.15) == 0
    assert _run_gate(tmp_path, _gate_record(110.0, 100.0), tol=0.05) == 1


def test_gate_fails_on_missing_sections(tmp_path):
    record = _gate_record(90.0, 100.0)
    del record["serve_continuous"]
    assert _run_gate(tmp_path, record,
                     require="tuned,fused,decode,serve") == 1


def test_gate_baseline_regressions(tmp_path):
    good = _gate_record(90.0, 100.0)
    # stall regression beyond tolerance fails
    worse = json.loads(json.dumps(good))
    worse["decode"][1]["derived"] = \
        "tokens_per_s=3800.0;stall_pct=9.5;host_syncs=2"
    assert _run_gate(tmp_path, worse, baseline=good) == 1
    # occupancy collapse fails
    worse2 = json.loads(json.dumps(good))
    worse2["serve_continuous"][0]["derived"] = \
        "tokens_per_s=5400.0;occupancy_pct=40.0;p99_ms=90"
    assert _run_gate(tmp_path, worse2, baseline=good) == 1
    # within tolerance passes
    assert _run_gate(tmp_path, good, baseline=good) == 0


def test_gate_paged_requirement(tmp_path):
    record = _gate_record(90.0, 100.0)
    # rows absent -> fail
    assert _run_gate(tmp_path, record, require="paged") == 1
    record["serve_continuous"] += [
        {"name": "serve/paged_kv", "us_per_call": 170.0,
         "derived": "tokens_per_s=5900.0;private_tokens_per_s=4300.0;"
                    "capacity_x=1.75;pages_shared=24;cow_forks=2;"
                    "pool_exhausted=0"},
        {"name": "serve/prefix_reuse", "us_per_call": 1400.0,
         "derived": "cold_ttft_p50_ms=4.3;warm_ttft_p50_ms=1.4;"
                    "ttft_speedup_x=3.1;prefill_skipped=96;prefix_hits=8"},
    ]
    assert _run_gate(tmp_path, record, require="paged") == 0
    # prefix reuse never fired -> fail (no skip, no speedup)
    bad = json.loads(json.dumps(record))
    bad["serve_continuous"][-1]["derived"] = (
        "cold_ttft_p50_ms=4.3;warm_ttft_p50_ms=4.2;ttft_speedup_x=1.0;"
        "prefill_skipped=0;prefix_hits=0")
    assert _run_gate(tmp_path, bad, require="paged") == 1
    # no capacity win at equal memory -> fail
    bad2 = json.loads(json.dumps(record))
    bad2["serve_continuous"][-2]["derived"] = (
        "tokens_per_s=5900.0;capacity_x=1.00")
    assert _run_gate(tmp_path, bad2, require="paged") == 1
