"""End-to-end system behaviour: train -> checkpoint -> resume -> serve,
composed exactly as examples/ and the launcher wire it together."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import compat
from repro.data import Distributor, Splitter, SyntheticLMStream
from repro.data.pipeline import BatchSpec
from repro.models import steps
from repro.runtime import ServeLoop, TrainLoop, TrainLoopConfig


@pytest.mark.slow
def test_train_then_serve_roundtrip(tmp_path):
    """Train a smoke model a few steps, checkpoint, reload, decode."""
    cfg = get("qwen3-14b-smoke")
    S = 16
    key = jax.random.PRNGKey(0)
    state = steps.init_train_state(cfg, key, max_seq=S)
    ts = jax.jit(steps.make_train_step(cfg))

    spec = BatchSpec(global_batch=2, seq_len=S, vocab=cfg.vocab)
    stream = SyntheticLMStream(spec, seed=3)
    mesh = compat.make_mesh((1,), ("data",))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    dist = Distributor(mesh, Splitter(mesh, ("data",)))

    def batches():
        step = 0
        while True:
            yield dist.materialize(stream, step, sh)
            step += 1

    loop = TrainLoop(TrainLoopConfig(total_steps=4, checkpoint_every=2,
                                     checkpoint_dir=str(tmp_path)),
                     ts, state, batches())
    report = loop.run(start_step=0)
    assert report["final_step"] == 4
    assert all(np.isfinite(m["loss"]) for m in report["metrics"])

    # restore params and serve a batch of 2 greedily
    restored = loop.ckpt.restore(4, state)
    params = restored["params"]
    cache = steps.init_cache(cfg, 2, S)
    dec = jax.jit(steps.make_decode_step(cfg, max_seq=S))
    serve = ServeLoop(dec, params, cache, batch_size=2)
    out = serve.generate(np.zeros((2, 1), np.int32), max_new=5)
    assert out.shape == (2, 6)
    assert (out >= 0).all() and (out < cfg.vocab).all()
    stats = serve.stats()
    # decode_steps counts the warmup-dropped samples the percentiles use
    # (5 generated tokens, first step dropped as compile warmup)
    assert stats["decode_steps"] == 4
    assert stats["tokens_per_s_per_slot"] > 0


@pytest.mark.slow
def test_decode_consistent_with_prefill():
    """Greedy next-token from decode-with-cache must match prefill argmax
    when the cache was filled by decoding the same prompt."""
    cfg = get("xlstm-125m-smoke")
    S = 8
    key = jax.random.PRNGKey(1)
    params = steps.init_params(cfg, key, max_seq=S)
    prompt = jax.random.randint(key, (2, S), 0, cfg.vocab)

    pf = jax.jit(steps.make_prefill_step(cfg))
    want_next = np.asarray(pf(params, {"tokens": prompt}))

    cache = steps.init_cache(cfg, 2, S)
    dec = jax.jit(steps.make_decode_step(cfg, max_seq=S))
    tok = None
    for t in range(S):
        cache, tok = dec(params, cache,
                         {"tokens": prompt[:, t:t + 1],
                          "pos": jnp.asarray(t, jnp.int32)})
    np.testing.assert_array_equal(np.asarray(tok)[:, 0], want_next)


def test_region_plan_places_weights_and_state():
    """The hybrid addressing plan: weights INTERLEAVED (data x model),
    optimizer/activations SEQUENTIAL (batch axes), norms replicated."""
    from repro.core import addressing
    mesh = compat.abstract_mesh((2, 2), ("data", "model"))
    rules = addressing.default_rules(mesh)
    # an FFN weight: embed x ffn -> (data, model)
    spec = rules.spec_for(("embed", "ffn"), (64, 64), mesh)
    assert spec == jax.sharding.PartitionSpec("data", "model")
    # a norm scale: replicated
    assert rules.spec_for(("norm",), (64,), mesh) == jax.sharding.PartitionSpec()
    # a batch tensor: sequential region (owner-local)
    assert rules.spec_for(("batch", "seq"), (8, 64), mesh) == \
        jax.sharding.PartitionSpec("data")
