"""Per-architecture smoke tests: reduced same-family config, one forward /
train step and one decode step on CPU; asserts shapes + finite values."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get
from repro.models import steps

B, S = 2, 32
KEY = jax.random.PRNGKey(0)


def make_batch(cfg):
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            KEY, (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
    return batch


# heaviest smoke configs (deep scan patterns / vision cross-attn); their
# prefill/decode smokes run only in the slow lane — the fast lane keeps one
# representative of every other family
_HEAVY = {"llama-3.2-vision-90b", "recurrentgemma-9b", "xlstm-125m"}
_SMOKE_PARAMS = [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY else a
                 for a in sorted(ARCHS)]


@pytest.mark.slow
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_smoke(arch):
    cfg = get(arch + "-smoke")
    state = steps.init_train_state(cfg, KEY, max_seq=S)
    batch = make_batch(cfg)
    ts = jax.jit(steps.make_train_step(cfg))
    new_state, metrics = ts(state, batch)
    assert np.isfinite(float(metrics["loss"])), metrics
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    before = jax.tree.leaves(state["params"])[1]
    after = jax.tree.leaves(new_state["params"])[1]
    assert not np.allclose(np.asarray(before, np.float32),
                           np.asarray(after, np.float32))


@pytest.mark.parametrize("arch", _SMOKE_PARAMS)
def test_decode_step_smoke(arch):
    cfg = get(arch + "-smoke")
    params = steps.init_params(cfg, KEY, max_seq=S)
    cl = steps.decode_cache_len(cfg, S)
    cache = steps.init_cache(cfg, B, cl)
    dec = jax.jit(steps.make_decode_step(cfg, max_seq=S))
    batch = {"tokens": jnp.zeros((B, 1), jnp.int32),
             "pos": jnp.asarray(3, jnp.int32)}
    new_cache, tok = dec(params, cache, batch)
    assert tok.shape == (B, 1)
    assert tok.dtype == jnp.int32
    assert (np.asarray(tok) >= 0).all() and (np.asarray(tok) < cfg.vocab).all()
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", _SMOKE_PARAMS)
def test_prefill_step_smoke(arch):
    cfg = get(arch + "-smoke")
    params = steps.init_params(cfg, KEY, max_seq=S)
    batch = make_batch(cfg)
    batch.pop("labels")
    pf = jax.jit(steps.make_prefill_step(cfg))
    tok = pf(params, batch)
    assert tok.shape == (B,)


@pytest.mark.slow
def test_train_loss_decreases():
    """A few steps on a fixed batch must reduce the loss (learning works)."""
    cfg = get("qwen3-14b-smoke")
    state = steps.init_train_state(cfg, KEY, max_seq=S)
    batch = make_batch(cfg)
    ts = jax.jit(steps.make_train_step(cfg))
    losses = []
    for _ in range(8):
        state, metrics = ts(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses


def test_full_config_param_counts():
    """The exact assignment configs must hit their advertised scale."""
    expect = {"qwen1.5-32b": (30e9, 36e9), "yi-34b": (32e9, 37e9),
              "deepseek-67b": (63e9, 70e9), "qwen3-14b": (13e9, 16e9),
              "grok-1-314b": (300e9, 330e9), "mixtral-8x7b": (44e9, 50e9),
              "whisper-small": (0.1e9, 0.3e9), "xlstm-125m": (0.1e9, 0.2e9),
              "recurrentgemma-9b": (8e9, 11e9),
              "llama-3.2-vision-90b": (80e9, 95e9)}
    for arch, (lo, hi) in expect.items():
        n = ARCHS[arch].n_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


@pytest.mark.slow
def test_moe_local_dispatch_matches_global():
    """With ample capacity (no drops), grouped-local dispatch must equal
    the global-flat dispatch bit-for-bit in routing semantics."""
    import dataclasses
    from repro.models.blocks import moe_apply, moe_specs
    from repro.models.layers import init_tree
    cfg = get("mixtral-8x7b-smoke")
    cfg_g = dataclasses.replace(cfg, capacity_factor=8.0)
    cfg_l = dataclasses.replace(cfg, capacity_factor=8.0,
                                moe_local_dispatch=True)
    p = init_tree(moe_specs(cfg), KEY)
    x = jax.random.normal(KEY, (3, 16, cfg.d_model), jnp.bfloat16)
    yg, ag = moe_apply(cfg_g, p, x)
    yl, al = moe_apply(cfg_l, p, x)
    np.testing.assert_allclose(np.asarray(yg, np.float32),
                               np.asarray(yl, np.float32),
                               rtol=2e-2, atol=2e-2)
    assert float(ag) == pytest.approx(float(al), rel=1e-5)


def test_moe_capacity_drop_and_combine():
    """MoE combine weights: sum over used experts <= 1, dropped -> partial."""
    from repro.models.blocks import moe_apply
    cfg = get("mixtral-8x7b-smoke")
    from repro.models.blocks import moe_specs
    from repro.models.layers import init_tree
    p = init_tree(moe_specs(cfg), KEY)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.bfloat16)
    y, aux = moe_apply(cfg, p, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert float(aux) > 0.0


@pytest.mark.slow
def test_mlstm_chunked_matches_decode_loop():
    """Chunkwise mLSTM (train path) == step-by-step recurrence (decode)."""
    from repro.models import blocks
    cfg = get("xlstm-125m-smoke")
    p = blocks.BLOCKS["mlstm"]["specs"](cfg)
    from repro.models.layers import init_tree
    params = init_tree(p, KEY)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.bfloat16)
    ctx = {"positions": jnp.broadcast_to(jnp.arange(16), (2, 16))}
    full, _ = blocks.mlstm_block_apply(cfg, params, x, ctx)
    cache = init_tree(blocks.mlstm_cache_specs(cfg, 2, 16), KEY)
    cache = jax.tree.map(jnp.zeros_like, cache)
    outs = []
    for t in range(16):
        o, cache = blocks.mlstm_block_decode(
            cfg, params, x[:, t:t + 1], cache, t, ctx)
        outs.append(o)
    stepwise = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(stepwise, np.float32),
                               rtol=0.15, atol=0.15)


@pytest.mark.slow
def test_rglru_scan_matches_decode_loop():
    from repro.models import blocks
    cfg = get("recurrentgemma-9b-smoke")
    params_specs = blocks.BLOCKS["rglru"]["specs"](cfg)
    from repro.models.layers import init_tree
    params = init_tree(params_specs, KEY)
    x = jax.random.normal(KEY, (2, 12, cfg.d_model), jnp.bfloat16)
    ctx = {"positions": jnp.broadcast_to(jnp.arange(12), (2, 12))}
    full, _ = blocks.rglru_block_apply(cfg, params, x, ctx)
    cache = init_tree(blocks.rglru_cache_specs(cfg, 2, 12), KEY)
    cache = jax.tree.map(jnp.zeros_like, cache)
    outs = []
    for t in range(12):
        o, cache = blocks.rglru_block_decode(
            cfg, params, x[:, t:t + 1], cache, t, ctx)
        outs.append(o)
    stepwise = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(stepwise, np.float32),
                               rtol=0.15, atol=0.15)
