"""Minimal stand-in for the hypothesis API used by this suite.

Installed via `pip install -e .[test]`, hypothesis drives the property
tests with real shrinking search. When it is absent (bare runtime env),
these shims keep the suite collectable and still exercise each property
on a handful of deterministic samples drawn from the declared ranges —
strictly weaker than hypothesis, but never silently skipped.

Only the pieces this suite uses are implemented: `given` with keyword
`st.integers(lo, hi)` strategies, and a no-op `settings`.
"""

from __future__ import annotations


import random


class _IntegersStrategy:
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi

    def sample(self, rng: random.Random) -> int:
        # always include the endpoints, then uniform draws
        return rng.choice((self.lo, self.hi, rng.randint(self.lo, self.hi)))


class strategies:                               # mirrors `hypothesis.strategies`
    @staticmethod
    def integers(min_value: int, max_value: int) -> _IntegersStrategy:
        return _IntegersStrategy(min_value, max_value)


def settings(*_args, **_kwargs):
    return lambda fn: fn


def given(**strategy_kwargs):
    n_examples = 8

    def deco(fn):
        # zero-arg wrapper: the strategy parameters must NOT survive in the
        # signature, or pytest would resolve them as fixtures
        def wrapper():
            rng = random.Random(0xA5)
            for _ in range(n_examples):
                drawn = {name: s.sample(rng)
                         for name, s in strategy_kwargs.items()}
                fn(**drawn)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
