"""The unified tile-pipeline layer: correctness through KernelPipeline,
autotuner validity (divisibility + VMEM budget), cost-model sanity, and
registry round-trip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.kernels import ops, ref, pipeline as pp

KEY = jax.random.PRNGKey(3)


def rand(seed, shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


# one smallish shape dict per kernel — the autotune sweep cases
SHAPES = {
    "axpy": {"m": 768, "n": 128},
    "dotp": {"m": 768, "n": 128},
    "matmul": {"m": 512, "n": 256, "k": 384},
    "conv2d": {"h": 96, "w": 256},
    "dct8x8": {"n": 1536},
    "rmsnorm": {"m": 384, "d": 256},
    "flash_attention": {"b": 1, "h": 4, "kv": 2, "s": 256, "hd": 64},
}

# which traffic dims each block size must divide
DIVIDES = {
    "axpy": {"block_rows": "m"},
    "dotp": {"block_rows": "m"},
    "matmul": {"bm": "m", "bn": "n", "bk": "k"},
    "conv2d": {"block_rows": "h"},
    "dct8x8": {"block_n": "n"},
    "rmsnorm": {"block_rows": "m"},
    "flash_attention": {"bq": "s", "bk": "s"},
}


def make_operands(name, shapes):
    if name == "axpy":
        return (1.7, rand(0, (shapes["m"], shapes["n"])),
                rand(1, (shapes["m"], shapes["n"])))
    if name == "dotp":
        return (rand(2, (shapes["m"], shapes["n"])),
                rand(3, (shapes["m"], shapes["n"])))
    if name == "matmul":
        return (rand(4, (shapes["m"], shapes["k"])),
                rand(5, (shapes["k"], shapes["n"])))
    if name == "conv2d":
        return (rand(6, (shapes["h"], shapes["w"])), rand(7, (3, 3)))
    if name == "dct8x8":
        return (rand(8, (shapes["n"], 8, 8)),)
    if name == "rmsnorm":
        return (rand(9, (shapes["m"], shapes["d"])),
                rand(10, (shapes["d"],)) * 0.1)
    if name == "flash_attention":
        b, h, kv, s, hd = (shapes[k] for k in ("b", "h", "kv", "s", "hd"))
        return (rand(11, (b, h, s, hd)), rand(12, (b, kv, s, hd)),
                rand(13, (b, kv, s, hd)))
    raise KeyError(name)


def reference(name, operands):
    if name == "axpy":
        return ref.axpy(*operands)
    if name == "dotp":
        return ref.dotp(*operands)
    if name == "matmul":
        return ref.matmul(*operands)
    if name == "conv2d":
        return ref.conv2d_3x3(*operands)
    if name == "dct8x8":
        return ref.dct8x8(*operands)
    if name == "rmsnorm":
        return ref.rmsnorm(*operands)
    if name == "flash_attention":
        q, k, v = operands
        g = q.shape[1] // k.shape[1]
        return ref.flash_attention(q, jnp.repeat(k, g, axis=1),
                                   jnp.repeat(v, g, axis=1))
    raise KeyError(name)


ALL_KERNELS = sorted(SHAPES)

# the fused producer–consumer kernels (kernels/fused.py); numerics covered
# in tests/test_fused.py, registry membership checked here
FUSED_KERNELS = ["flash_attention_proj", "matmul_bias_act",
                 "matmul_residual_add", "rmsnorm_matmul"]


def test_all_kernels_registered():
    assert sorted(pp.KERNELS) == sorted(ALL_KERNELS + FUSED_KERNELS)
    assert sorted(ops.OPS) == sorted(ALL_KERNELS + FUSED_KERNELS)


@pytest.mark.parametrize("name", ALL_KERNELS)
def test_kernel_matches_reference_through_pipeline(name):
    """Every kernel routed through KernelPipeline == its jnp oracle."""
    operands = make_operands(name, SHAPES[name])
    got = ops.tuned_call(name, *operands)
    want = reference(name, operands)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("name", ALL_KERNELS)
def test_autotune_blocks_divide_and_fit(name):
    shapes = SHAPES[name]
    result = pp.autotune(name, shapes)
    for block_name, dim_name in DIVIDES[name].items():
        block = result.blocks[block_name]
        dim = shapes[dim_name]
        assert dim % block == 0, (name, block_name, block, dim)
        assert 1 <= block <= dim
    t = pp.KERNELS[name].traffic(shapes, result.blocks, 4)
    assert t.vmem_bytes <= pp.VMEM_BUDGET_BYTES
    assert result.cost.total_s <= result.default_cost.total_s * (1 + 1e-9)


@pytest.mark.parametrize("name", ALL_KERNELS)
def test_tune_space_is_all_divisors(name):
    """Every candidate the tuner may pick respects divisibility."""
    shapes = SHAPES[name]
    n_cands = 0
    for blocks in pp.KERNELS[name].tune_space(shapes):
        n_cands += 1
        for block_name, dim_name in DIVIDES[name].items():
            assert shapes[dim_name] % blocks[block_name] == 0, (name, blocks)
    assert n_cands >= 1


def test_autotune_registers_record():
    registry.KERNEL_TUNES.clear()
    r = pp.autotune("matmul", SHAPES["matmul"])
    rec = registry.get_kernel_tune("matmul", pp.shape_key(SHAPES["matmul"]))
    assert rec is not None
    assert dict(rec.blocks) == r.blocks
    assert rec.modeled_seconds == pytest.approx(r.cost.total_s)
    assert registry.kernel_tunes() == [rec]
    # tuned_blocks is registry-cached: same answer without re-tuning
    assert pp.tuned_blocks("matmul", SHAPES["matmul"]) == r.blocks


def test_tune_records_keyed_by_dtype():
    """Blocks tuned under bf16 VMEM footprints must not serve f32 calls."""
    registry.KERNEL_TUNES.clear()
    pp.autotune("matmul", SHAPES["matmul"], dtype_bytes=2)
    assert registry.get_kernel_tune(
        "matmul", pp.shape_key(SHAPES["matmul"], 2)) is not None
    assert registry.get_kernel_tune(
        "matmul", pp.shape_key(SHAPES["matmul"], 4)) is None


def test_default_blocks_are_divisors():
    """The modeled default must be the blocking that actually executes
    (snap_block applied), even when the nominal default doesn't divide."""
    for name, shapes in SHAPES.items():
        d = pp.KERNELS[name].default_blocks(shapes)
        for block_name, dim_name in DIVIDES[name].items():
            assert shapes[dim_name] % d[block_name] == 0, (name, d)
    # regression: axpy at m=768 used to model a phantom block_rows=512
    assert pp.KERNELS["axpy"].default_blocks({"m": 768, "n": 128}) == \
        {"block_rows": 384}


def test_traffic_streamed_at_least_ideal():
    for name, shapes in SHAPES.items():
        defn = pp.KERNELS[name]
        t = defn.traffic(shapes, defn.default_blocks(shapes), 4)
        assert t.hbm_bytes >= t.ideal_bytes - 1e-9, name
        assert t.flops > 0 and t.grid_steps >= 1, name


def test_locality_penalty_monotone():
    """Less reuse (more re-streaming) must never score better."""
    local = pp.Traffic(flops=1e9, hbm_bytes=1e6, ideal_bytes=1e6,
                       grid_steps=8, vmem_bytes=1 << 20)
    remote = pp.Traffic(flops=1e9, hbm_bytes=4e6, ideal_bytes=1e6,
                        grid_steps=8, vmem_bytes=1 << 20)
    f_local, p_local = pp.locality_factor(local)
    f_remote, p_remote = pp.locality_factor(remote)
    assert p_local == pytest.approx(1.0) and p_remote == pytest.approx(0.25)
    assert f_remote > f_local >= 1.0
    assert pp.score(remote).total_s > pp.score(local).total_s


def test_matmul_bigger_output_tile_raises_p_local():
    """MemPool's register-blocking story: bigger (bm, bn) -> fewer
    re-streams of A and B -> higher modeled p_local."""
    shapes = {"m": 1024, "n": 1024, "k": 1024}
    defn = pp.KERNELS["matmul"]
    small = pp.score(defn.traffic(shapes, {"bm": 128, "bn": 128, "bk": 128}, 4))
    big = pp.score(defn.traffic(shapes, {"bm": 512, "bn": 512, "bk": 128}, 4))
    assert big.p_local > small.p_local
    assert big.total_s < small.total_s


def test_vmem_budget_respected_by_autotuner():
    """With a tiny budget the tuner must fall back to small, valid blocks."""
    shapes = {"m": 1024, "n": 1024, "k": 1024}
    r = pp.autotune("matmul", shapes, vmem_budget=1 << 20,
                    register_record=False)
    t = pp.KERNELS["matmul"].traffic(shapes, r.blocks, 4)
    assert t.vmem_bytes <= 1 << 20
    for bname, dim in (("bm", "m"), ("bn", "n"), ("bk", "k")):
        assert shapes[dim] % r.blocks[bname] == 0


def test_pipeline_vmem_accounting_double_buffers():
    from repro.kernels import matmul as mm
    pipe = mm.build_pipeline(256, 256, 256, jnp.float32,
                             bm=128, bn=128, bk=128)
    # 2 slots x (a + b + out tiles) x 4B + f32 accumulator scratch
    expect = 2 * (128 * 128 * 3) * 4 + 128 * 128 * 4
    assert pipe.vmem_bytes(4) == expect
    assert pipe.grid_steps == 2 * 2 * 2
    assert pipe.dimension_semantics() == ("parallel", "parallel", "arbitrary")


def test_block_candidates_properties():
    cands = pp.block_candidates(1024, align=128, cap=5)
    assert len(cands) <= 5
    assert all(1024 % c == 0 and c % 128 == 0 for c in cands)
    assert pp.block_candidates(7, align=8) == [7]       # fallback: [dim]
    assert pp.block_candidates(1024, align=8, max_block=64)[-1] <= 64
