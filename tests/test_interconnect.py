"""Interconnect models: paper Fig. 4/5 trends + collective cost algebra."""

import pytest

from repro.core import mesh as hw
from repro.core.interconnect import (TOP_1, TOP_4, TOP_H, CollectiveModel,
                                     TopologyModel)


def test_topology_saturation_ordering():
    """Paper Fig. 4: Top_1 saturates ~0.10, Top_4 ~0.37, Top_H ~0.40."""
    t1 = TopologyModel(TOP_1)
    t4 = TopologyModel(TOP_4)
    th = TopologyModel(TOP_H)
    load = 0.5
    a1 = t1.accepted_load(load)
    a4 = t4.accepted_load(load)
    ah = th.accepted_load(load)
    assert a1 < a4 <= ah
    assert a1 == pytest.approx(0.105, abs=0.02)
    assert ah == pytest.approx(0.41, abs=0.05)


def test_latency_blows_up_near_saturation():
    th = TopologyModel(TOP_H)
    assert th.avg_latency(0.05) < 6.0            # paper: <6 cycles @ light load
    assert th.avg_latency(0.39) > th.avg_latency(0.10) * 2


def test_hybrid_addressing_raises_throughput():
    """Paper Fig. 5: raising p_local raises accepted load + cuts latency."""
    th = TopologyModel(TOP_H)
    load = 2.0                      # deep in saturation for every p_local
    acc = [th.accepted_load(load, p_local=p) for p in (0.0, 0.25, 0.5, 0.75)]
    assert all(b > a for a, b in zip(acc, acc[1:]))
    lat = [th.avg_latency(0.3, p_local=p) for p in (0.0, 0.25, 0.5, 0.75)]
    assert all(b < a for a, b in zip(lat, lat[1:]))


def test_paper_fig5_quantitative_claim():
    """Paper §3.3.2: 25% stack accesses -> up to ~27% throughput gain."""
    th = TopologyModel(TOP_H)
    load = 0.5
    gain = th.accepted_load(load, 0.25) / th.accepted_load(load, 0.0) - 1
    assert 0.15 < gain < 0.40, gain


def test_collective_model_algebra():
    topo = hw.v5e_topology((16, 16), ("data", "model"))
    cm = CollectiveModel(topo)
    n = 16
    shard = 1e6
    ag = cm.all_gather(shard, "model")
    assert ag.bytes_on_wire == shard * (n - 1)
    rs = cm.reduce_scatter(shard * n, "model")
    assert rs.bytes_on_wire == pytest.approx(shard * (n - 1))
    ar = cm.all_reduce(shard * n, "model")
    assert ar.seconds == pytest.approx(rs.seconds + cm.all_gather(
        shard, "model").seconds)
    assert cm.all_gather(shard, "model").seconds > 0


def test_single_axis_degenerate():
    topo = hw.v5e_topology((1, 4), ("data", "model"))
    cm = CollectiveModel(topo)
    assert cm.all_gather(1e6, "data").seconds == 0.0
    assert cm.all_reduce(1e6, "data").bytes_on_wire == 0.0
