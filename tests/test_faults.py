"""Fault injection + recovery: the session's robustness contract.

MemPool's robustness claim is architectural — one stalled core never
wedges the cluster, a dead core only costs its own lanes. The serving
analogue under test here: a scripted `FaultPlan` (kill / NaN-corrupt /
wedge / refill-error) fires against a live `ServeSession`, and every
request that survives must produce tokens bit-identical to a fault-free
run. Preemption rides the same checkpoint machinery, so its resume is
pinned bit-exact too. The wedge path is the watchdog contract:
`poll(timeout_s=...)` / `watchdog_s` raises `SessionWedged` instead of
blocking forever, and `recover_wedged()` rebuilds the pool.

The scripted decode emits the same row for every slot (tokens depend
only on the request's position, never its slot), so kill-restarts,
preempt-resumes, and wedge-rebuilds that land work in different slots
still have one right answer to compare against.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.cluster import Cluster, ServeSessionProgram
from repro.runtime import engine
from repro.runtime.faults import (Fault, FaultPlan, InjectedFault,
                                  SessionWedged)
from repro.runtime.scheduler import RequestFailed
from repro.runtime.serve_loop import ServeSession
from test_serve_session import scripted_step


# ----------------------------------------------------------------------------
# Scripted harness: slot-uniform token rows + a rebuildable pool
# ----------------------------------------------------------------------------


BASE = np.array([3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 8], np.int32)


def make_chaos_session(*, n_slots=3, chunk=2, eos_id=None, max_prompt=4,
                       **kw):
    """A ServeSession over the slot-uniform script, with a state_factory
    so wedge recovery can rebuild the pool."""
    script = np.tile(BASE[:, None], (1, n_slots))
    chunk_fn = engine.make_session_chunk(scripted_step(script), chunk,
                                         eos_id=eos_id)
    refill_fn = engine.make_session_refill()

    def factory():
        return engine.init_session_state(
            {"kv": jnp.zeros((n_slots, 4), jnp.float32)}, n_slots,
            max_prompt)

    return ServeSession(chunk_fn, refill_fn, None, factory(),
                        n_slots=n_slots, chunk=chunk, max_prompt=max_prompt,
                        eos_id=eos_id, state_factory=factory, **kw)


def reference_tokens(prompts, max_news, **kw):
    """Fault-free isolated runs: the one right answer per request."""
    out = []
    for p, n in zip(prompts, max_news):
        sess = make_chaos_session(**kw)
        h = sess.submit(p, n)
        sess.drain()
        out.append(h.tokens)
    return out


def run_to_completion(sess, handles, max_polls=500):
    """Drive poll() to quiescence, recovering from any wedge."""
    wedges = 0
    for _ in range(max_polls):
        if all(h.done for h in handles):
            return wedges
        try:
            sess.poll()
        except SessionWedged:
            sess.recover_wedged()
            wedges += 1
    raise AssertionError("session did not drain within the poll budget")


# ----------------------------------------------------------------------------
# FaultPlan semantics
# ----------------------------------------------------------------------------


def test_fault_plan_validation():
    with pytest.raises(ValueError):
        Fault("melt_down", 0)
    with pytest.raises(ValueError):
        Fault("wedge", -1)
    with pytest.raises(ValueError):
        Fault("kill_slot", 2)               # slot-targeted without a slot
    with pytest.raises(ValueError):
        Fault("wedge", 2, slot=1)           # wedge does not take a slot


def test_fault_plan_fires_exactly_once():
    plan = (FaultPlan().kill_slot(at_chunk=3, slot=1).wedge(at_chunk=5)
            .refill_error(at_chunk=2))
    assert plan.kills(2) == []              # wrong chunk: nothing fires
    assert plan.kills(3) == [1]
    assert plan.kills(3) == []              # consumed
    assert plan.pending_wedge and not plan.wedged(4)
    assert plan.wedged(5) and not plan.wedged(5)
    assert not plan.pending_wedge
    with pytest.raises(InjectedFault):
        plan.check_refill(2)
    plan.check_refill(2)                    # consumed: no raise
    assert plan.exhausted
    s = plan.summary()
    assert s["planned"] == s["fired"] == 3
    assert s["by_kind"]["kill_slot"] == 1
    assert [k for k, _, _ in plan.fired] == ["kill_slot", "wedge",
                                             "refill_error"]


# ----------------------------------------------------------------------------
# Checkpoint/resume: the slot snapshot is bit-exact
# ----------------------------------------------------------------------------


def test_slot_snapshot_restore_bit_exact():
    sess = make_chaos_session(n_slots=3)
    for size in (1, 2, 3):
        sess.submit(list(range(size)), 8)
    sess.poll()                             # admit + one chunk: live rows
    state = sess.state
    state["cache"]["kv"] = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
    snap = engine.make_slot_snapshot()(state, np.int32(1))
    fresh = engine.init_session_state(
        {"kv": jnp.zeros((3, 4), jnp.float32)}, 3, 4)
    restored = engine.make_slot_restore(donate=False)(
        fresh, np.int32(1), snap)
    for k in engine.SLOT_FIELDS:
        np.testing.assert_array_equal(np.asarray(restored[k][1]),
                                      np.asarray(state[k][1]), err_msg=k)
    np.testing.assert_array_equal(np.asarray(restored["cache"]["kv"][1]),
                                  np.asarray(state["cache"]["kv"][1]))
    assert bool(restored["active"][1]) and int(restored["age"][1]) == 1
    # untouched neighbours stay zeroed
    assert int(np.asarray(restored["pos"])[[0, 2]].sum()) == 0


def test_preemption_resume_is_bit_identical():
    prompts, max_news = [[0], [0, 1], [0]], [8, 8, 4]
    ref = reference_tokens(prompts, max_news, n_slots=2)
    sess = make_chaos_session(n_slots=2, aging_rounds=10_000)
    tp = [sess.submit(prompts[i], max_news[i], klass="throughput")
          for i in (0, 1)]
    sess.poll()                             # pool full, one chunk decoded
    lat = sess.submit(prompts[2], max_news[2], klass="latency")
    sess.drain()
    st = sess.stats()
    assert st["preemptions"] == 1
    assert st["classes"]["throughput"]["preempted"] == 1
    assert lat.ok and all(h.ok for h in tp)
    for h, want in zip(tp + [lat], ref):
        np.testing.assert_array_equal(h.tokens, want)


# ----------------------------------------------------------------------------
# Kill: quarantine + retry; NaN: sentinel scan + recycle
# ----------------------------------------------------------------------------


def test_kill_fault_quarantines_slot_and_retries_bit_identical():
    prompts, max_news = [[0]] * 3, [8] * 3
    ref = reference_tokens(prompts, max_news)
    plan = FaultPlan().kill_slot(at_chunk=2, slot=1)
    sess = make_chaos_session(retry_backoff_s=0.001, faults=plan)
    handles = [sess.submit(p, n) for p, n in zip(prompts, max_news)]
    sess.drain()
    st = sess.stats()
    assert plan.exhausted
    assert st["quarantined_slots"] == [1] and st["usable_slots"] == 2
    assert st["retries"] == 1 and st["requests_failed"] == 0
    assert st["faults"]["by_kind"]["kill_slot"] == 1
    for h, want in zip(handles, ref):
        assert h.ok
        np.testing.assert_array_equal(h.tokens, want)


def test_nan_corruption_detected_and_slot_recycled():
    prompts, max_news = [[0]] * 3, [8] * 3
    ref = reference_tokens(prompts, max_news)
    plan = FaultPlan().corrupt_nan(at_chunk=1, slot=0)
    sess = make_chaos_session(retry_backoff_s=0.001, faults=plan)
    handles = [sess.submit(p, n) for p, n in zip(prompts, max_news)]
    sess.drain()
    st = sess.stats()
    assert plan.exhausted and st["retries"] == 1
    # transient corruption never costs pool capacity
    assert st["quarantined_slots"] == [] and st["usable_slots"] == 3
    for h, want in zip(handles, ref):
        assert h.ok
        np.testing.assert_array_equal(h.tokens, want)


# ----------------------------------------------------------------------------
# Wedge: the watchdog raises instead of blocking forever
# ----------------------------------------------------------------------------


def test_wedge_raises_session_wedged_then_recovers():
    prompts, max_news = [[0], [0, 1]], [6, 6]
    ref = reference_tokens(prompts, max_news, n_slots=2)
    plan = FaultPlan().wedge(at_chunk=1)
    sess = make_chaos_session(n_slots=2, retry_backoff_s=0.001, faults=plan)
    handles = [sess.submit(p, n) for p, n in zip(prompts, max_news)]
    sess.poll(timeout_s=0.2)                # chunk 0 completes
    with pytest.raises(SessionWedged) as exc:
        sess.poll(timeout_s=0.2)
    assert exc.value.chunk == 1 and exc.value.timeout_s == 0.2
    assert "host_syncs" in exc.value.stall
    with pytest.raises(RuntimeError, match="recover_wedged"):
        sess.poll()                         # latched until recovery
    sess.recover_wedged()
    sess.drain()
    st = sess.stats()
    assert st["retries"] == 2               # both running slots restarted
    for h, want in zip(handles, ref):
        assert h.ok
        np.testing.assert_array_equal(h.tokens, want)


def test_session_watchdog_s_applies_to_drain_and_stream():
    plan = FaultPlan().wedge(at_chunk=0)
    sess = make_chaos_session(watchdog_s=0.2, faults=plan)
    sess.submit([0], 6)
    with pytest.raises(SessionWedged):
        sess.drain()
    sess.recover_wedged()
    sess2 = make_chaos_session(faults=FaultPlan().wedge(at_chunk=0))
    sess2.submit([0], 6)
    with pytest.raises(SessionWedged):
        for _ in sess2.stream(timeout_s=0.2):
            pass


def test_scripted_wedge_without_watchdog_is_a_config_error():
    sess = make_chaos_session(faults=FaultPlan().wedge(at_chunk=0))
    sess.submit([0], 6)
    with pytest.raises(RuntimeError, match="bounds the device wait"):
        sess.poll()


# ----------------------------------------------------------------------------
# Refill faults: un-admit + retry, bounded
# ----------------------------------------------------------------------------


def test_refill_error_is_retried_and_completes():
    ref = reference_tokens([[0]], [6])
    plan = FaultPlan().refill_error(at_chunk=0)
    sess = make_chaos_session(faults=plan)
    h = sess.submit([0], 6)
    sess.drain()
    assert plan.exhausted and h.ok
    np.testing.assert_array_equal(h.tokens, ref[0])


def test_persistent_refill_failure_surfaces():
    class RefillBroken(RuntimeError):
        pass

    def broken_refill(*a, **k):
        raise RefillBroken("device refill rejected")

    script = np.tile(BASE[:, None], (1, 2))
    chunk_fn = engine.make_session_chunk(scripted_step(script), 2)
    state = engine.init_session_state({"kv": jnp.zeros((2, 4), jnp.float32)},
                                      2, 4)
    sess = ServeSession(chunk_fn, broken_refill, None, state, n_slots=2,
                        chunk=2, max_prompt=4, max_retries=1)
    sess.submit([0], 4)
    with pytest.raises(RefillBroken):
        for _ in range(8):
            sess.poll()


# ----------------------------------------------------------------------------
# Typed failure reasons on the handle
# ----------------------------------------------------------------------------


def test_shed_request_raises_typed_failure():
    sess = make_chaos_session(n_slots=1, shed_watermark=1)
    running = sess.submit([0], 8)
    sess.poll()                             # occupy the only slot
    shed = sess.submit([0], 4, klass="best_effort")   # queued, within depth
    sess.submit([0], 4)                     # latency overflow sheds the be
    assert shed.done and shed.failed and shed.fail_reason == "shed"
    with pytest.raises(RequestFailed) as exc:
        shed.result()
    assert exc.value.reason == "shed" and exc.value.rid == shed.id
    # the shed event surfaces exactly once, with an empty payload
    ev = [e for e in sess.poll() if e[0] is shed]
    assert len(ev) == 1 and ev[0][1].size == 0 and ev[0][2]
    sess.drain()
    assert running.ok and sess.stats()["classes"]["best_effort"]["shed"] == 1


def test_retries_exhausted_raises_typed_failure():
    plan = FaultPlan().kill_slot(at_chunk=0, slot=0)
    sess = make_chaos_session(n_slots=1, max_retries=0, faults=plan)
    h = sess.submit([0], 8)
    sess.drain()
    assert h.failed and h.fail_reason == "retries_exhausted"
    with pytest.raises(RequestFailed) as exc:
        h.result()
    assert exc.value.reason == "retries_exhausted"
    st = sess.stats()
    assert st["requests_failed"] == 1 and st["usable_slots"] == 0


# ----------------------------------------------------------------------------
# The acceptance chaos run, scripted: kill + NaN + wedge in one stream
# ----------------------------------------------------------------------------


def test_scripted_chaos_run_is_bit_identical():
    prompts = [[0], [0, 1], [0], [0, 1, 2], [0], [0, 1]]
    max_news = [6, 6, 8, 4, 6, 6]
    ref = reference_tokens(prompts, max_news)
    plan = (FaultPlan()
            .kill_slot(at_chunk=2, slot=1)
            .corrupt_nan(at_chunk=3, slot=0)
            .wedge(at_chunk=5))
    sess = make_chaos_session(watchdog_s=0.25, max_retries=3,
                              retry_backoff_s=0.001, faults=plan)
    handles = [sess.submit(p, n) for p, n in zip(prompts, max_news)]
    wedges = run_to_completion(sess, handles)
    st = sess.stats()
    assert plan.exhausted and wedges == 1
    assert st["quarantined_slots"] == [1]
    assert st["retries"] >= 2 and st["requests_failed"] == 0
    for i, (h, want) in enumerate(zip(handles, ref)):
        assert h.ok, f"request {i} did not survive chaos"
        np.testing.assert_array_equal(
            h.tokens, want,
            err_msg=f"request {i} diverged from the fault-free run")


# ----------------------------------------------------------------------------
# Model path: the stacked-layer cache takes/puts slot rows correctly
# ----------------------------------------------------------------------------


@pytest.mark.slow
def test_model_path_preemption_and_kill_bit_identical():
    """qwen3's KV cache has stacked layer axes, so the model-path
    snapshot/restore goes through steps.take/put_cache_slot — pin that a
    preempted *and* a killed request both resume bit-identically on the
    real decode step."""
    cluster = Cluster("qwen3-14b-smoke")
    program = cluster.compile(ServeSessionProgram(
        slots=2, max_seq=32, max_prompt=4, chunk=2, preempt=True,
        max_retries=2, retry_backoff_s=0.001))
    params = program.init_params()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cluster.arch.vocab, size=3).astype(np.int32)
               for _ in range(3)]

    ref_sess = program.open(params=params)
    ref = [ref_sess.submit(p, 8, klass="throughput") for p in prompts]
    ref_sess.drain()

    plan = FaultPlan().kill_slot(at_chunk=1, slot=0)
    sess = program.open(params=params, faults=plan)
    tp = [sess.submit(p, 8, klass="throughput") for p in prompts[:2]]
    sess.poll()                             # pool full, one chunk decoded
    lat = sess.submit(prompts[2], 8, klass="latency")
    sess.drain()
    st = sess.stats()
    assert plan.exhausted
    assert st["preemptions"] >= 1 and st["retries"] >= 1
    for h, want in zip(tp + [lat], ref):
        assert h.ok
        np.testing.assert_array_equal(h.tokens, want.tokens)
