"""The fused producer–consumer kernel path (kernels/fused.py).

Numerics: every fused kernel against its unfused jnp composition from
kernels/ref.py, at fp32 (<= 1e-5) and bf16 (<= 2e-2), interpret mode.
Mechanics: check_fusable compatibility, saved-bytes accounting, the
autotune-on-miss path of tuned_call, the fused roofline, the model-stack
routing behind the "fused" KernelPolicy, and the ServeLoop.stats guard.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.kernels import fused, ops, ref, pipeline as pp
from repro.launch.roofline import fused_roofline
from repro.runtime.serve_loop import ServeLoop

TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def rand(seed, shape, dtype=jnp.float32):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


def _assert_close(got, want, dtype):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


# ----------------------------------------------------------------------------
# fused kernels vs unfused composition
# ----------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,bm,bn", [
    (128, 64, 128, 64, 64),
    (96, 256, 64, 32, 32),
])
def test_rmsnorm_matmul(dtype, m, k, n, bm, bn):
    x = rand(0, (m, k), dtype)
    s = rand(1, (k,)) * 0.1
    w = rand(2, (k, n), dtype)
    got = ops.rmsnorm_matmul(x, s.astype(dtype), w, bm=bm, bn=bn)
    want = ref.matmul(ref.rmsnorm(x, s.astype(dtype)), w)
    _assert_close(got, want, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["none", "gelu", "silu"])
def test_matmul_bias_act(dtype, act):
    m, k, n = 64, 96, 128
    a = rand(3, (m, k), dtype)
    b = rand(4, (k, n), dtype)
    bias = rand(5, (n,), dtype)
    got = ops.matmul_bias_act(a, b, bias, act=act, bm=32, bn=64, bk=32)
    h = jnp.dot(a, b, preferred_element_type=jnp.float32) \
        + bias.astype(jnp.float32)
    want = fused.ACTIVATIONS[act](h).astype(dtype)
    _assert_close(got, want, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_residual_add(dtype):
    m, k, n = 96, 64, 96
    a = rand(6, (m, k), dtype)
    b = rand(7, (k, n), dtype)
    res = rand(8, (m, n), dtype)
    got = ops.matmul_residual_add(a, b, res, bm=32, bn=32, bk=32)
    want = (jnp.dot(a, b, preferred_element_type=jnp.float32)
            + res.astype(jnp.float32)).astype(dtype)
    _assert_close(got, want, dtype)


def test_flash_attention_proj_smoke():
    """One small fp32 case in the fast lane; the dtype/GQA grid is slow."""
    _flash_attention_proj_case(jnp.float32, 1, 4, 2, 64, 16, 32)


@pytest.mark.slow
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,s,hd,dm", [
    (2, 4, 4, 128, 32, 64),       # MHA
    (1, 4, 2, 128, 32, 48),       # GQA group 2
])
def test_flash_attention_proj(dtype, b, h, kv, s, hd, dm):
    _flash_attention_proj_case(dtype, b, h, kv, s, hd, dm)


def _flash_attention_proj_case(dtype, b, h, kv, s, hd, dm):
    q = rand(9, (b, h, s, hd), dtype)
    k = rand(10, (b, kv, s, hd), dtype)
    v = rand(11, (b, kv, s, hd), dtype)
    wo = rand(12, (h, hd, dm), dtype) * 0.1
    got = ops.flash_attention_proj(q, k, v, wo, bq=32, bk=32)
    g = h // kv
    o = ref.flash_attention(q, jnp.repeat(k, g, axis=1),
                            jnp.repeat(v, g, axis=1))
    want = jnp.einsum("bhsk,hkd->bsd", o.astype(jnp.float32),
                      wo.astype(jnp.float32)).astype(dtype)
    _assert_close(got, want, dtype)


@pytest.mark.slow
def test_fused_grads_match_reference():
    """The custom-VJP backward equals grads of the jnp composition."""
    x = rand(13, (32, 48))
    s = rand(14, (48,)) * 0.1
    w = rand(15, (48, 64))

    g = jax.grad(lambda *a: jnp.sum(ops.rmsnorm_matmul(*a) ** 2),
                 argnums=(0, 1, 2))(x, s, w)
    gr = jax.grad(lambda x, s, w: jnp.sum(
        jnp.dot(ref.rmsnorm(x, s), w) ** 2), argnums=(0, 1, 2))(x, s, w)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


# ----------------------------------------------------------------------------
# fusion mechanics
# ----------------------------------------------------------------------------


def test_check_fusable_rejects_mismatches():
    a = pp.TileSpec((64, 128), lambda i: (i, 0))
    b = pp.TileSpec((64, 64), lambda i: (i, 0))
    with pytest.raises(pp.FusionError):
        pp.check_fusable(a, b)
    smem = pp.TileSpec((64, 128), lambda i: (i, 0), memory_space="smem")
    with pytest.raises(pp.FusionError):
        pp.check_fusable(a, smem)
    # partial residency of a full-dim axis: producer tile not fully consumed
    with pytest.raises(pp.FusionError):
        pp.check_fusable(a, a, full_dims=(1,), dims=(256,))
    pp.check_fusable(a, a, full_dims=(1,), dims=(128,))   # ok


def test_fuse_hooks_compose():
    """Two epilogues stack (innermost first); prologues chain in order."""
    m = n = k = 64
    from repro.kernels import matmul as mm
    base = mm.build_pipeline(m, n, k, jnp.float32, bm=32, bn=32, bk=32)
    p1 = base.fuse(epilogue=lambda o: o + 1.0)
    p2 = p1.fuse(epilogue=lambda o: o * 2.0)
    a = rand(16, (m, k))
    b = rand(17, (k, n))
    got = p2(a, b, interpret=True)
    # composition order: new epilogue runs closest to the register tile
    want = (jnp.dot(a, b) * 2.0) + 1.0
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fuse_stacked_extras_are_isolated():
    """Two fusions each carrying extra tiles compose: every hook is bound
    to its own operand slice (norm prologue + residual epilogue stacked)."""
    m, k, n = 64, 48, 64
    pipe = fused.build_rmsnorm_matmul(m, n, k, jnp.float32, bm=32, bn=32)
    stacked = pipe.fuse(
        epilogue=lambda o, r_ref: o.astype(jnp.float32) + r_ref[...],
        extra_tiles=[pp.TileSpec((32, 32), lambda i, j, s: (i, j))])
    x = rand(26, (m, k))
    s = rand(27, (k,)) * 0.1
    w = rand(28, (k, n))
    r = rand(29, (m, n))
    got = stacked(x, w, s, r, interpret=True)
    want = jnp.dot(ref.rmsnorm(x, s), w) + r
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fused_traffic_saves_intermediate():
    for name, shapes in [
        ("rmsnorm_matmul", {"m": 512, "k": 512, "n": 512}),
        ("matmul_bias_act", {"m": 512, "k": 512, "n": 512}),
        ("matmul_residual_add", {"m": 512, "k": 512, "n": 512}),
        ("flash_attention_proj",
         {"b": 1, "h": 4, "kv": 2, "s": 512, "hd": 64, "dm": 256}),
    ]:
        defn = pp.KERNELS[name]
        t = defn.traffic(shapes, defn.default_blocks(shapes), 4)
        assert t.saved_bytes > 0, name
        assert t.hbm_bytes >= t.ideal_bytes - 1e-9, name
        model = fused.fused_vs_unfused(name, shapes)
        assert model["unfused_bytes"] == pytest.approx(
            t.hbm_bytes + t.saved_bytes)
        assert model["reduction"] > 1.0, name


def test_transformer_block_traffic_halved():
    """Acceptance: the fused transformer block moves >= 2x fewer modeled
    HBM bytes than the unfused composition."""
    t = fused.transformer_block_traffic(1, 4096, 4096, 32, 8, 128, 14336)
    assert t["reduction"] >= 2.0, t["reduction"]
    assert t["fused_bytes"] > 0


def test_fused_roofline_drops_saved_terms():
    r = fused_roofline(1e12, 1e9, 1e9)
    assert r["traffic_reduction"] == pytest.approx(2.0)
    assert r["unfused_memory_s"] == pytest.approx(2 * r["memory_s"])
    assert r["saved_s"] > 0


def test_autotune_registers_fused_record_with_saved_bytes():
    registry.KERNEL_TUNES.clear()
    shapes = {"m": 256, "k": 256, "n": 256}
    r = pp.autotune("rmsnorm_matmul", shapes)
    rec = registry.get_kernel_tune("rmsnorm_matmul", pp.shape_key(shapes))
    assert rec is not None
    assert rec.saved_bytes > 0
    assert dict(rec.blocks) == r.blocks


# ----------------------------------------------------------------------------
# tuned_call autotune-on-miss (satellite)
# ----------------------------------------------------------------------------


def test_tuned_call_autotunes_on_registry_miss():
    """A shape with no registry record must tune, register, and still be
    numerically correct — for an unfused and a fused kernel."""
    registry.KERNEL_TUNES.clear()
    x = rand(18, (72, 40))
    s = rand(19, (40,)) * 0.1
    w = rand(20, (40, 56))

    got = ops.tuned_call("rmsnorm_matmul", x, s, w)
    _assert_close(got, ref.matmul(ref.rmsnorm(x, s), w), jnp.float32)
    key = pp.shape_key({"m": 72, "k": 40, "n": 56})
    assert registry.get_kernel_tune("rmsnorm_matmul", key) is not None

    a = rand(21, (72, 40))
    b = rand(22, (40, 56))
    got = ops.tuned_call("matmul", a, b)
    _assert_close(got, ref.matmul(a, b), jnp.float32)
    assert registry.get_kernel_tune("matmul", key) is not None
    # second call is a registry hit returning the same blocks
    blocks = pp.tuned_blocks("matmul", {"m": 72, "k": 40, "n": 56})
    assert blocks == dict(
        registry.get_kernel_tune("matmul", key).blocks)


# ----------------------------------------------------------------------------
# model-stack routing behind the "fused" KernelPolicy
# ----------------------------------------------------------------------------


@pytest.mark.slow
def test_model_fused_route_matches_unfused():
    """Forward loss and greedy decode agree between the fused and unfused
    routes on a smoke config (rms norm + swiglu + GQA)."""
    from repro.cluster import use_policy
    from repro.models import steps
    cfg = dataclasses.replace(registry.get("yi-34b-smoke"), n_layers=2)
    params = steps.init_params(cfg, jax.random.PRNGKey(0), max_seq=32)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    l0, _ = steps.loss_fn(cfg, params, batch)
    with use_policy("fused"):
        l1, _ = steps.loss_fn(cfg, params, batch)
    assert abs(float(l0) - float(l1)) < 2e-2

    dec_u = steps.make_decode_step(cfg, max_seq=16)
    dec_f = steps.make_decode_step(cfg, max_seq=16, policy="fused")
    cache = steps.init_cache(cfg, 2, 16)
    b1 = {"tokens": jnp.zeros((2, 1), jnp.int32),
          "pos": jnp.asarray(0, jnp.int32)}
    _, t_u = dec_u(params, cache, b1)
    _, t_f = dec_f(params, cache, b1)
    assert (np.asarray(t_u) == np.asarray(t_f)).all()


def test_pallas_attention_schedule_adapter():
    from repro.models import attention as attn_lib
    q = rand(23, (1, 64, 4, 16))
    k = rand(24, (1, 64, 2, 16))
    v = rand(25, (1, 64, 2, 16))
    got = attn_lib.attention(q, k, v, n_kv=2, causal=True, chunk=32,
                             schedule="pallas")
    want = attn_lib.attention(q, k, v, n_kv=2, causal=True, chunk=32,
                              schedule="direct")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ----------------------------------------------------------------------------
# ServeLoop.stats guard (satellite)
# ----------------------------------------------------------------------------


def _dummy_loop(n_latencies: int) -> ServeLoop:
    loop = ServeLoop(decode_step=lambda p, c, b: (c, b["tokens"]),
                     params=None, cache=None, batch_size=1)
    loop.latencies = [0.01] * n_latencies
    return loop


def test_serve_stats_empty_and_single_step():
    for n in (0, 1):
        st = _dummy_loop(n).stats()
        assert st["decode_steps"] == 0
        assert st["tokens_per_s_per_slot"] == 0.0
        assert st["p50_ms"] == 0.0 and st["p99_ms"] == 0.0


def test_serve_stats_counts_warmup_dropped_steps():
    st = _dummy_loop(5).stats()
    assert st["decode_steps"] == 4            # first step dropped as warmup
    assert st["tokens_per_s_per_slot"] == pytest.approx(100.0, rel=1e-6)
    assert st["p50_ms"] == pytest.approx(10.0, rel=1e-6)
