"""Durable serving: journal, snapshots, crash recovery, page integrity.

MemPool's shared L1 concentrates every PE's working state in one
structure; the serving analogue (`ServeSession` + the paged KV pool)
concentrates every in-flight request in one process. The durability
layer under test here is the contract that makes that concentration
safe:

* the **journal** (runtime/journal.py) is a crash-consistent WAL —
  torn tails never raise, replay is idempotent, and a token is
  delivered only after its commit record is fsync-durable;
* **crash at any chunk boundary** -> restore -> drain completes with
  bit-identical, exactly-once outputs (journal-committed tokens count
  as delivered; greedy decode regenerates them and harvest suppresses
  the duplicates), with or without a snapshot to resume from;
* **page integrity**: a scripted `bit_flip` on a shared KV page is
  caught by the publish-time checksum before a new request attaches,
  the page is quarantined, and the prefix recomputes — outputs stay
  bit-identical, nothing crashes;
* `FaultPlan` consumption is thread-safe (watchdog + driver threads).
"""

import shutil
import tempfile
import threading

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from hypothesis_fallback import given, settings, strategies as st

from repro.runtime.faults import FaultPlan, SessionCrashed
from repro.runtime.journal import (Journal, read_events, replay)
from test_faults import BASE, make_chaos_session

ARCH = "qwen3-14b-smoke"


# ----------------------------------------------------------------------------
# Journal: format, torn tails, replay
# ----------------------------------------------------------------------------


def _submit_ev(rid, prompt, max_new=4, klass="latency"):
    return {"ev": "submit", "rid": rid, "prompt": list(prompt),
            "max_new": max_new, "klass": klass, "deadline_s": None}


def test_journal_round_trip(tmp_path):
    p = tmp_path / "j.jsonl"
    j = Journal(p)
    j.append(_submit_ev(0, [1, 2]))
    j.append({"ev": "admit", "rid": 0, "slot": 1, "chunk": 0})
    j.append({"ev": "commit", "rid": 0, "tokens": [7, 8], "chunk": 0})
    j.append({"ev": "finish", "rid": 0, "status": "done", "reason": None})
    j.commit()
    j.close()
    evs = read_events(p)
    assert [e["seq"] for e in evs] == [0, 1, 2, 3]
    s = replay(evs)
    assert s.requests[0].committed == [7, 8]
    assert s.requests[0].status == "done"
    assert s.requests[0].slot == 1


def test_journal_reopen_continues_seq(tmp_path):
    p = tmp_path / "j.jsonl"
    j = Journal(p)
    j.append(_submit_ev(0, [1]))
    j.close()
    j2 = Journal(p)
    assert j2.append({"ev": "commit", "rid": 0, "tokens": [9],
                      "chunk": 0}) == 1
    j2.close()
    assert len(read_events(p)) == 2


def test_journal_rejects_unknown_event(tmp_path):
    j = Journal(tmp_path / "j.jsonl")
    with pytest.raises(ValueError):
        j.append({"ev": "explode", "rid": 0})


def test_torn_tail_ends_the_log(tmp_path):
    p = tmp_path / "j.jsonl"
    j = Journal(p)
    j.append(_submit_ev(0, [1]))
    j.append({"ev": "commit", "rid": 0, "tokens": [5], "chunk": 0})
    j.commit()
    j.close()
    with open(p, "a") as f:             # process died mid-write
        f.write('{"seq": 2, "ev": "fin')
    evs = read_events(p)
    assert len(evs) == 2                # torn line dropped, prefix intact
    assert replay(evs).requests[0].committed == [5]
    # reopening appends after the durable prefix with the right seq
    j2 = Journal(p)
    assert j2.seq == 2
    j2.close()


def test_corrupt_header_is_a_cold_start(tmp_path):
    p = tmp_path / "j.jsonl"
    p.write_text("not a journal\n")
    assert read_events(p) == []
    j = Journal(p)                      # truncates + rewrites the header
    j.append(_submit_ev(0, [1]))
    j.commit()
    j.close()
    assert len(read_events(p)) == 1


def test_compact_rewrites_atomically(tmp_path):
    p = tmp_path / "j.jsonl"
    j = Journal(p)
    for i in range(4):
        j.append(_submit_ev(i, [i]))
    j.commit()
    evs = read_events(p)
    j.compact(evs[2:])
    j.close()
    kept = read_events(p)
    # seq continuity was preserved verbatim from the kept suffix
    assert [e["rid"] for e in kept] == [2, 3]


@settings(deadline=None, max_examples=30)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n_events=st.integers(min_value=0, max_value=60))
def test_replay_is_idempotent_and_prefix_monotone(seed, n_events):
    """replay(replay-input) of the same stream is deterministic, and a
    request's committed stream under any prefix of the log is a prefix
    of its committed stream under the full log (no reordering, no
    retraction — the property exactly-once recovery rests on)."""
    rng = np.random.default_rng(seed)
    events, seq = [], 0
    for _ in range(n_events):
        rid = int(rng.integers(0, 4))
        kind = rng.choice(["submit", "admit", "commit", "finish"])
        ev = {"seq": seq, "ev": kind, "rid": rid}
        if kind == "submit":
            ev.update(prompt=[1, 2], max_new=4, klass="latency",
                      deadline_s=None)
        elif kind == "admit":
            ev.update(slot=int(rng.integers(0, 4)), chunk=seq)
        elif kind == "commit":
            ev.update(tokens=[int(t) for t in rng.integers(0, 9, 2)],
                      chunk=seq)
        else:
            ev.update(status="done", reason=None)
        events.append(ev)
        seq += 1
    full = replay(events)
    again = replay(events)
    assert full.committed_counts() == again.committed_counts()
    cut = int(rng.integers(0, n_events + 1))
    part = replay(events[:cut])
    for rid, r in part.requests.items():
        whole = full.requests[rid].committed
        assert whole[:len(r.committed)] == r.committed


# ----------------------------------------------------------------------------
# Crash at any boundary -> restore -> exactly-once, bit-identical
# ----------------------------------------------------------------------------

_PROMPTS = [BASE[:3], BASE[:1], BASE[:4], BASE[2:4], BASE[:2]]
_MAX_NEW = [6, 8, 4, 7, 5]
_REFERENCE = None


def _reference():
    """Fault-free delivered streams for the scripted workload (computed
    once; the scripted step's tokens depend only on request position)."""
    global _REFERENCE
    if _REFERENCE is None:
        sess = make_chaos_session(n_slots=3, chunk=2)
        hs = [sess.submit(p, n) for p, n in zip(_PROMPTS, _MAX_NEW)]
        sess.drain()
        _REFERENCE = {h.id: [int(t) for t in h.result()] for h in hs}
    return _REFERENCE


def _drive(sess, delivered, max_polls=500):
    """Poll to quiescence, folding delivered tokens per rid; returns
    True if a scripted crash fired."""
    for _ in range(max_polls):
        if not (sess.scheduler.busy or sess._pending_events):
            return False
        try:
            for h, toks, done in sess.poll():
                delivered.setdefault(h.id, []).extend(int(t) for t in toks)
        except SessionCrashed:
            return True
    raise AssertionError("session did not drain within the poll budget")


@settings(deadline=None, max_examples=10)
@given(crash_at=st.integers(min_value=0, max_value=12),
       snap=st.integers(min_value=0, max_value=3))
def test_crash_anywhere_restores_exactly_once(crash_at, snap):
    """Kill the session at an arbitrary chunk boundary (journal-only and
    snapshot-resume paths both covered), restore from the durable dir,
    drain, and require the union of journal-committed (pre-crash) and
    post-restore deliveries to equal the fault-free streams exactly —
    every token delivered once, bit-identically."""
    expected = _reference()
    d = tempfile.mkdtemp()
    try:
        sess = make_chaos_session(
            n_slots=3, chunk=2, durable_dir=d,
            snapshot_every=snap or None,
            faults=FaultPlan().crash(at_chunk=crash_at))
        hs = [sess.submit(p, n) for p, n in zip(_PROMPTS, _MAX_NEW)]
        delivered = {h.id: [] for h in hs}
        crashed = _drive(sess, delivered)
        if not crashed:                 # workload finished first: the
            assert delivered == expected        # no-crash case must hold
            return
        committed = {rid: r.committed for rid, r in
                     replay(read_events(d + "/journal.jsonl"))
                     .requests.items()}
        # commit-before-deliver: everything handed out is durable
        for rid, toks in delivered.items():
            assert committed.get(rid, [])[:len(toks)] == toks
        sess2 = make_chaos_session(n_slots=3, chunk=2, durable_dir=d,
                                   snapshot_every=snap or None, resume=True)
        final = {rid: list(toks) for rid, toks in committed.items()}
        assert not _drive(sess2, final)
        assert final == expected
        du = sess2.stats()["durability"]
        assert du["restore_s"] > 0.0    # measured MTTR, not a placeholder
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_restore_of_fully_drained_session_recovers_terminals():
    d = tempfile.mkdtemp()
    try:
        sess = make_chaos_session(n_slots=2, chunk=2, durable_dir=d)
        h = sess.submit(BASE[:2], 5)
        sess.drain()
        ref = h.result()
        sess.close()
        sess2 = make_chaos_session(n_slots=2, chunk=2, durable_dir=d,
                                   resume=True)
        assert not sess2.scheduler.busy         # nothing to re-run
        got = sess2.handle(h.id)
        assert got is not None and got.ok
        np.testing.assert_array_equal(got.result(), ref)
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_double_restore_is_idempotent():
    """Crash -> restore -> abandon -> restore again: the second recovery
    sees the first one's journal (including its restore event) and still
    converges to the same exactly-once streams."""
    expected = _reference()
    d = tempfile.mkdtemp()
    try:
        sess = make_chaos_session(n_slots=3, chunk=2, durable_dir=d,
                                  snapshot_every=2,
                                  faults=FaultPlan().crash(at_chunk=3))
        hs = [sess.submit(p, n) for p, n in zip(_PROMPTS, _MAX_NEW)]
        assert _drive(sess, {h.id: [] for h in hs})
        # first restore crashes again two chunks later
        sess2 = make_chaos_session(n_slots=3, chunk=2, durable_dir=d,
                                   snapshot_every=2, resume=True,
                                   faults=FaultPlan().crash(at_chunk=6))
        crashed_again = _drive(sess2, {})
        committed = {rid: r.committed for rid, r in
                     replay(read_events(d + "/journal.jsonl"))
                     .requests.items()}
        final = {rid: list(toks) for rid, toks in committed.items()}
        if crashed_again:
            sess3 = make_chaos_session(n_slots=3, chunk=2, durable_dir=d,
                                       snapshot_every=2, resume=True)
            assert not _drive(sess3, final)
        assert final == expected
    finally:
        shutil.rmtree(d, ignore_errors=True)


# ----------------------------------------------------------------------------
# FaultPlan thread safety (watchdog + driver threads share the plan)
# ----------------------------------------------------------------------------


def test_fault_plan_consumption_is_thread_safe():
    """Concurrent queries against one chunk's faults: every scripted
    fault fires exactly once across all threads (no double-fire from a
    racy read-modify-write, no lost fault)."""
    n_faults, n_threads = 64, 8
    plan = FaultPlan()
    for s in range(n_faults):
        plan.add("kill_slot", at_chunk=5, slot=s)
    barrier = threading.Barrier(n_threads)
    got: list[list[int]] = [[] for _ in range(n_threads)]

    def worker(i):
        barrier.wait()
        for _ in range(16):
            got[i].extend(plan.kills(5))

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    fired = [s for g in got for s in g]
    assert sorted(fired) == list(range(n_faults))   # once each, none lost
    assert plan.exhausted


# ----------------------------------------------------------------------------
# Paged integrity + measured prefix-overlap admission (model-level)
# ----------------------------------------------------------------------------


@pytest.fixture(scope="module")
def paged_program():
    from repro.cluster.session import Cluster, ServeSessionProgram
    cl = Cluster(ARCH)
    prog = cl.compile(ServeSessionProgram(
        slots=4, max_seq=64, max_prompt=16, chunk=4, paged=True,
        page_size=4, admission="longest_prefix", snapshot_every=2))
    return prog, prog.init_params()


_PRE = np.arange(1, 13, dtype=np.int32)        # 12 tokens: 3 full pages


def _wave(sess, tails, max_new=8):
    hs = [sess.submit(np.concatenate([_PRE, np.asarray(t, np.int32)]),
                      max_new) for t in tails]
    sess.drain()
    return {h.id: h.result() for h in hs}


def test_bit_flip_on_shared_page_is_detected_and_repaired(paged_program):
    """Perturb a published (checksummed) page between two waves that
    share its prefix: the admit-time verify must catch it before the
    page is shared, quarantine it, and recompute the prefix — second
    wave bit-identical to a fault-free run, violations and repairs
    counted, no NaN escape, no crash."""
    prog, params = paged_program
    ref = prog.open(params=params)
    ref_all = {**_wave(ref, [[21], [22]]), **_wave(ref, [[23], [24]])}

    sess = prog.open(params=params)
    w1 = _wave(sess, [[21], [22]])
    plan = FaultPlan().bit_flip(at_chunk=sess._chunk_index)
    sess.attach_faults(plan)
    w2 = _wave(sess, [[23], [24]])
    for rid, toks in {**w1, **w2}.items():
        np.testing.assert_array_equal(toks, ref_all[rid])
    du = sess.stats()["durability"]
    assert du["integrity_checks"] >= 1
    assert du["integrity_violations"] >= 1
    assert du["integrity_repairs"] >= 1
    assert du["quarantined_pages"] >= 1
    assert plan.exhausted


def test_background_scrub_catches_idle_corruption(paged_program):
    """A flip while nothing is being admitted: the round-robin scrub —
    not an admission — must find and quarantine the page within a few
    polls."""
    prog, params = paged_program
    sess = prog.open(params=params)
    _wave(sess, [[31], [32]])                   # publish + stamp pages
    assert sess.kv.checksums
    sess.attach_faults(FaultPlan().bit_flip(at_chunk=sess._chunk_index))
    # keep the pool busy with a request sharing nothing
    h = sess.submit(np.array([91, 92, 93], np.int32), 8)
    sess.drain()
    assert h.ok
    du = sess.stats()["durability"]
    assert du["integrity_violations"] >= 1
    assert du["quarantined_pages"] >= 1


def test_prefix_pages_expected_matches_measured_reuse(paged_program):
    """`longest_prefix` admission ranks by *measured* page overlap: the
    pages the scheduler predicted at admission must equal the pages the
    pool actually shared, and correlate with prefix-cache hits."""
    prog, params = paged_program
    sess = prog.open(params=params)
    _wave(sess, [[41], [42]])                   # wave 1: nothing published
    st1 = sess.stats()["kv"]
    assert st1["prefix_pages_expected"] == st1["pages_shared"] == 0
    _wave(sess, [[43], [44]])                   # wave 2: 3 pages each
    st2 = sess.stats()["kv"]
    assert st2["prefix_pages_expected"] == st2["pages_shared"] == 6
    assert st2["prefix_hits"] >= 2


@pytest.mark.slow
def test_model_session_crash_restore_bit_identical(paged_program):
    """Full-model (paged qwen3 smoke) crash + restore: kill the session
    mid-decode with snapshots on, restore from the durable dir, and
    require exactly-once bit-identical streams — the scripted-session
    property, re-proved against the real session cell + paged pool
    snapshot (kv.snapshot/load_snapshot round-trip on device state)."""
    prog, params = paged_program
    prompts = [np.concatenate([_PRE, np.array([t], np.int32)])
               for t in (51, 52, 53)]
    ref_sess = prog.open(params=params)
    hs = [ref_sess.submit(p, 8) for p in prompts]
    ref_sess.drain()
    expected = {h.id: [int(t) for t in h.result()] for h in hs}

    d = tempfile.mkdtemp()
    try:
        sess = prog.open(params=params, durable_dir=d,
                         faults=FaultPlan().crash(at_chunk=3))
        hs = [sess.submit(p, 8) for p in prompts]
        delivered = {h.id: [] for h in hs}
        assert _drive(sess, delivered)
        committed = {rid: r.committed for rid, r in
                     replay(read_events(d + "/journal.jsonl"))
                     .requests.items()}
        sess2 = prog.restore(d, params=params)
        final = {rid: list(toks) for rid, toks in committed.items()}
        assert not _drive(sess2, final)
        assert final == expected
        assert sess2.stats()["durability"]["restore_s"] > 0.0
    finally:
        shutil.rmtree(d, ignore_errors=True)
