"""Attention schedules: fwd + flash-VJP vs direct reference, decode paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A

B, S, H, KV, HD = 2, 64, 4, 2, 16


@pytest.fixture(scope="module")
def qkv():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    return (jax.random.normal(ks[0], (B, S, H, HD), jnp.float32),
            jax.random.normal(ks[1], (B, S, KV, HD), jnp.float32),
            jax.random.normal(ks[2], (B, S, KV, HD), jnp.float32))


@pytest.mark.parametrize("schedule,window", [
    ("masked", None), ("folded", None), ("banded", 24), ("masked", 24),
])
def test_schedule_forward(qkv, schedule, window):
    q, k, v = qkv
    want = A.direct_attention(q, k, v, n_kv=KV, window=window)
    got = A.attention(q, k, v, n_kv=KV, chunk=8, schedule=schedule,
                      window=window)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.slow
@pytest.mark.parametrize("schedule,window", [
    ("masked", None), ("folded", None), ("banded", 24),
])
def test_flash_vjp_matches_direct(qkv, schedule, window):
    q, k, v = qkv

    def l_direct(q, k, v):
        return (A.direct_attention(q, k, v, n_kv=KV, window=window) ** 2).sum()

    def l_flash(q, k, v):
        return (A.attention(q, k, v, n_kv=KV, chunk=8, schedule=schedule,
                            window=window) ** 2).sum()

    gd = jax.grad(l_direct, argnums=(0, 1, 2))(q, k, v)
    gf = jax.grad(l_flash, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gd, gf, "qkv"):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3,
                                   err_msg=f"{schedule} d{name}")


def test_decode_matches_prefill_last_token(qkv):
    """Decoding token t over a cache == row t of full causal attention."""
    q, k, v = qkv
    full = A.direct_attention(q, k, v, n_kv=KV)
    pos = S - 1
    out = A.decode_attention(q[:, pos:pos + 1], k, v, pos + 1, n_kv=KV)
    np.testing.assert_allclose(out[:, 0], full[:, pos], rtol=2e-4, atol=2e-4)


def test_decode_windowed(qkv):
    q, k, v = qkv
    w = 16
    full = A.direct_attention(q, k, v, n_kv=KV, window=w)
    pos = S - 1
    out = A.decode_attention(q[:, pos:pos + 1], k, v, pos + 1, n_kv=KV,
                             window=w)
    np.testing.assert_allclose(out[:, 0], full[:, pos], rtol=2e-4, atol=2e-4)


def test_rolling_cache_equivalence():
    """A rolling buffer of size w must reproduce windowed attention."""
    w, steps = 16, 40
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    qs = jax.random.normal(ks[0], (B, steps, H, HD), jnp.float32)
    knew = jax.random.normal(ks[1], (B, steps, KV, HD), jnp.float32)
    vnew = jax.random.normal(ks[2], (B, steps, KV, HD), jnp.float32)
    kc = jnp.zeros((B, w, KV, HD))
    vc = jnp.zeros((B, w, KV, HD))
    outs = []
    for t in range(steps):
        kc, vc = A.update_cache(kc, vc, knew[:, t:t + 1], vnew[:, t:t + 1],
                                t, rolling=True)
        outs.append(A.decode_attention(qs[:, t:t + 1], kc, vc, t + 1,
                                       n_kv=KV, rolling=True)[:, 0])
    got = jnp.stack(outs, axis=1)
    want = A.direct_attention(qs, knew, vnew, n_kv=KV, window=w)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_cross_attention_chunked():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, 64, H, HD), jnp.float32)
    k = jax.random.normal(ks[1], (B, 24, KV, HD), jnp.float32)
    v = jax.random.normal(ks[2], (B, 24, KV, HD), jnp.float32)
    got = A.cross_attention(q, k, v, n_kv=KV, chunk=16)
    want = A.direct_attention(q, k, v, n_kv=KV, causal=False)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
