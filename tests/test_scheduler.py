"""Slot-scheduler invariants (runtime/scheduler.py) — property-tested.

The scheduler is the host half of the continuous-batching session: a
bounded request queue plus a slot table. Whatever the workload shape,
it must never double-assign a slot, must admit FIFO submissions in
order, must terminate every admitted request (given slots drain), and
must free slots on cancel. Backpressure: a bounded queue raises
QueueFull instead of growing without limit.

The SLO layer adds three properties (the ones the serving claims rest
on): at equal age a latency request is never admitted behind a
throughput request (and throughput never behind best-effort); under a
constant stream of fresh latency traffic, aging still gets every queued
best-effort request admitted within a bounded number of rounds (no
starvation); and overload shedding only ever fails best-effort work.
"""

import random

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from hypothesis_fallback import given, settings, strategies as st

from repro.runtime.scheduler import (CANCELLED, CLASSES, DONE, FAILED,
                                     QUEUED, QueueFull, REASON_SHED,
                                     RUNNING, SlotScheduler)


def _submit_n(sched, n, rng, max_prompt=6, max_new=8):
    return [sched.submit(rng.integers(0, 100, size=rng.integers(1, max_prompt + 1)),
                         int(rng.integers(1, max_new + 1)))
            for _ in range(n)]


# ----------------------------------------------------------------------------
# Property: random admit/release churn never double-assigns a slot and
# terminates every request
# ----------------------------------------------------------------------------


@settings(deadline=None, max_examples=25)
@given(n_slots=st.integers(1, 4), n_req=st.integers(0, 16),
       seed=st.integers(0, 10))
def test_churn_no_double_assignment_and_termination(n_slots, n_req, seed):
    rng = np.random.default_rng(seed)
    pyrng = random.Random(seed)
    sched = SlotScheduler(n_slots)
    reqs = _submit_n(sched, n_req, rng)
    remaining = {r.rid: r.max_new for r in reqs}
    for _ in range(10_000):
        if not sched.busy:
            break
        for slot, req in sched.admit():
            assert req.state == RUNNING and req.slot == slot
        # a slot maps to exactly one running request and vice versa
        slots = [s for s, _ in sched.running_requests()]
        rids = [r.rid for _, r in sched.running_requests()]
        assert len(set(slots)) == len(slots) <= n_slots
        assert len(set(rids)) == len(rids)
        # simulate a chunk: every running request makes progress; some finish
        for slot, req in list(sched.running_requests()):
            remaining[req.rid] -= pyrng.randint(1, 3)
            if remaining[req.rid] <= 0:
                req.state = DONE
                sched.release(slot)
    assert not sched.busy
    assert all(r.state == DONE for r in reqs)
    # each request was admitted exactly once
    assert sorted(sched.admitted_order) == sorted(r.rid for r in reqs)
    assert len(sched.admitted_order) == len(set(sched.admitted_order))


# ----------------------------------------------------------------------------
# Property: FIFO fairness — admission order is submit order
# ----------------------------------------------------------------------------


@settings(deadline=None, max_examples=25)
@given(n_slots=st.integers(1, 4), n_req=st.integers(1, 12),
       seed=st.integers(0, 10))
def test_fifo_admits_in_submit_order(n_slots, n_req, seed):
    rng = np.random.default_rng(seed)
    sched = SlotScheduler(n_slots, policy="fifo")
    reqs = _submit_n(sched, n_req, rng)
    while sched.busy:
        sched.admit()
        for slot, req in list(sched.running_requests()):
            req.state = DONE
            sched.release(slot)
    assert list(sched.admitted_order) == [r.rid for r in reqs]


def test_longest_prefix_admits_longest_prompt_first():
    sched = SlotScheduler(1, policy="longest_prefix")
    a = sched.submit([1], 4)                  # P=1
    b = sched.submit([1, 2, 3], 4)            # P=3 — admitted first
    c = sched.submit([1, 2, 3], 4)            # P=3 — ties break by rid
    assert [r for _, r in sched.admit()] == [b]
    sched._slots[0].state = DONE
    sched.release(0)
    assert [r for _, r in sched.admit()] == [c]
    sched._slots[0].state = DONE
    sched.release(0)
    assert [r for _, r in sched.admit()] == [a]


# ----------------------------------------------------------------------------
# Cancel frees the slot (and removes queued work)
# ----------------------------------------------------------------------------


@settings(deadline=None, max_examples=25)
@given(n_req=st.integers(1, 8), cancel_i=st.integers(0, 7),
       seed=st.integers(0, 5))
def test_cancel_frees_slot_or_dequeues(n_req, cancel_i, seed):
    rng = np.random.default_rng(seed)
    sched = SlotScheduler(2)
    reqs = _submit_n(sched, n_req, rng)
    sched.admit()
    victim = reqs[min(cancel_i, n_req - 1)]
    was_running = victim.state == RUNNING
    assert sched.cancel(victim)
    assert victim.state == CANCELLED
    if was_running:
        # the driver frees the slot at the chunk boundary
        slot = victim.slot
        sched.release(slot)
        assert slot in sched.free_slots()
    else:
        assert victim.rid not in [r.rid for _, r in sched.running_requests()]
    # everyone else still terminates
    while sched.busy:
        sched.admit()
        for slot, req in list(sched.running_requests()):
            req.state = DONE
            sched.release(slot)
    assert all(r.state in (DONE, CANCELLED) for r in reqs)
    assert sched.cancel(victim) is False      # idempotent: already over


# ----------------------------------------------------------------------------
# Backpressure + validation
# ----------------------------------------------------------------------------


def test_bounded_queue_raises_queue_full():
    sched = SlotScheduler(1, max_queue=2)
    sched.submit([1], 1)
    sched.submit([1], 1)
    with pytest.raises(QueueFull):
        sched.submit([1], 1)
    sched.admit()                             # pops one from the queue
    # note: admit drains the queue into the slot — room again
    sched.submit([1], 1)


# ----------------------------------------------------------------------------
# SLO properties: class ordering, anti-starvation aging, shed targeting
# ----------------------------------------------------------------------------


def _drain_order(sched):
    """Admit + instantly finish until idle; the admission order is the
    scheduling decision under test."""
    for _ in range(10_000):
        if not sched.busy:
            break
        sched.admit()
        for slot, req in list(sched.running_requests()):
            req.state = DONE
            sched.release(slot)
    return list(sched.admitted_order)


@settings(deadline=None, max_examples=25)
@given(n_slots=st.integers(1, 3), n_req=st.integers(2, 12),
       seed=st.integers(0, 10))
def test_equal_age_latency_never_behind_throughput(n_slots, n_req, seed):
    # aging disabled-in-practice (huge aging_rounds): pure class order
    rng = np.random.default_rng(seed)
    sched = SlotScheduler(n_slots, aging_rounds=10_000)
    by_class = {k: [] for k in CLASSES}
    for _ in range(n_req):
        k = CLASSES[rng.integers(0, 3)]
        by_class[k].append(sched.submit([1], 2, klass=k).rid)
    order = _drain_order(sched)
    pos = {rid: i for i, rid in enumerate(order)}
    for hi, lo in (("latency", "throughput"), ("throughput", "best_effort")):
        for h in by_class[hi]:
            for l in by_class[lo]:
                assert pos[h] < pos[l], (
                    f"{hi} rid {h} admitted behind {lo} rid {l}")
    # same-class FIFO: submit order preserved within each class
    for k in CLASSES:
        assert [p for p in order if p in set(by_class[k])] == by_class[k]


@settings(deadline=None, max_examples=10)
@given(aging=st.integers(1, 6), seed=st.integers(0, 5))
def test_no_starvation_under_constant_latency_pressure(aging, seed):
    """A queued best-effort request outranks fresh latency traffic after
    rank_gap * aging_rounds waited rounds — it must be admitted within a
    bounded number of rounds no matter how much latency work keeps
    arriving."""
    sched = SlotScheduler(1, aging_rounds=aging)
    be = sched.submit([1], 1, klass="best_effort")
    bound = 2 * aging + 4                       # rank gap 2, plus slack
    for round_i in range(10 * bound):
        sched.submit([1], 1, klass="latency")   # fresh pressure every round
        for slot, req in sched.admit():
            req.state = DONE
            sched.release(slot)
        if be.state == DONE:
            break
    assert be.state == DONE, "best-effort request starved"
    assert round_i <= bound, (
        f"admitted after {round_i} rounds; bound is {bound}")


@settings(deadline=None, max_examples=25)
@given(watermark=st.integers(1, 6), n_req=st.integers(1, 20),
       seed=st.integers(0, 10))
def test_shed_only_touches_best_effort(watermark, n_req, seed):
    rng = np.random.default_rng(seed)
    sched = SlotScheduler(1, shed_watermark=watermark)
    reqs = []
    for _ in range(n_req):
        k = CLASSES[rng.integers(0, 3)]
        reqs.append(sched.submit([1], 2, klass=k))
    shed = [r for r in reqs if r.state == FAILED]
    assert all(r.klass == "best_effort" for r in shed)
    assert all(r.fail_reason == REASON_SHED for r in shed)
    # depth only exceeds the watermark when no best-effort is left to shed
    be_queued = [r for r in reqs
                 if r.state == QUEUED and r.klass == "best_effort"]
    if sched.queued > watermark:
        assert not be_queued
    assert sched.pop_shed() == shed             # driver sees every victim
    assert sched.pop_shed() == []               # ... exactly once
    # everything that wasn't shed still terminates
    order = _drain_order(sched)
    assert sorted(order) == sorted(r.rid for r in reqs if r not in shed)


def test_preempt_victim_picks_lowest_class_most_recent():
    sched = SlotScheduler(3, aging_rounds=10_000)
    tp1 = sched.submit([1], 8, klass="throughput")
    be = sched.submit([1], 8, klass="best_effort")
    tp2 = sched.submit([1], 8, klass="throughput")
    sched.admit()
    slot, victim = sched.preempt_victim(for_rank=0)
    assert victim is be                         # lowest class first
    _, for_tp = sched.preempt_victim(for_rank=1)
    assert for_tp is be                         # a tp claimant only evicts be
    victim.state = DONE
    sched.release(slot)
    slot, victim = sched.preempt_victim(for_rank=0)
    assert victim is tp2                        # then most recently started
    assert sched.preempt_victim(for_rank=1) is None   # tp never evicts tp
    for s, r in list(sched.running_requests()):
        r.state = DONE
        sched.release(s)
    assert sched.preempt_victim(for_rank=0) is None


def test_quarantined_slot_never_reassigned():
    sched = SlotScheduler(2)
    a = sched.submit([1], 2)
    b = sched.submit([1], 2)
    sched.admit()
    bad = a.slot
    a.state = DONE
    sched.release(bad)
    sched.quarantine(bad)
    assert bad not in sched.free_slots()
    assert sched.usable_slots == 1
    c = sched.submit([1], 2)
    b.state = DONE
    sched.release(b.slot)
    admits = sched.admit()
    assert [s for s, _ in admits] != [bad] and c.slot != bad


def test_scheduler_validation():
    with pytest.raises(ValueError):
        SlotScheduler(0)
    with pytest.raises(ValueError):
        SlotScheduler(2, policy="round-robin")
    with pytest.raises(ValueError):
        SlotScheduler(2, max_queue=0)
    sched = SlotScheduler(2)
    with pytest.raises(ValueError):
        sched.submit([], 4)                   # empty prompt
    with pytest.raises(ValueError):
        sched.submit([1], 0)                  # no budget
    r = sched.submit([1, 2], 4)
    assert r.state == QUEUED and r.emitted == 0
