"""Cluster-of-clusters serving: groups, two-level placement, sharded API.

MemPool scales by hierarchy — tiles form groups, groups form the
cluster — and the paper's topology model prices a remote access above a
local one. The sharded serving layer under test mirrors that: N full
session cells behind one `submit/poll/stream/cancel/drain` surface with
a locality-aware placement level on top. The contracts pinned here:

* **placement invariants** (property-tested): a request lands in
  exactly one group; a quarantined or draining group receives nothing;
  equal-load cold placement balances; warm prefix-cache overlap
  attracts (the topology model scores cached traffic as local); when
  every group is ineligible, placement raises `QueueFull` instead of
  wedging;
* **single-session equivalence**: `groups=1` through the sharded
  program is token-for-token the plain `ServeSessionProgram` path —
  live and across a crash-restart through the group-tagged journal;
* **degradation**: a wedged group is quarantined (capacity shrinks by
  one group), the rest keep serving, and `recover_group` folds it back;
* **ledgers**: `StallClock.merge` sums counters without double-counting
  the shared wall; per-group KV pools roll up in `stats()["kv"]`; the
  prefix cache evicts cold cache-only pages LRU-first and counts them.
"""

import shutil
import tempfile

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from hypothesis_fallback import given, settings, strategies as st

from repro.runtime.engine import StallClock
from repro.runtime.faults import FaultPlan, SessionCrashed
from repro.runtime.groups import (GroupPlan, GroupRuntime, GroupView,
                                  MeshScheduler, ShardedServeSession)
from repro.runtime.journal import Journal, read_events, replay
from repro.runtime.kvpool import PagedKV
from repro.runtime.scheduler import QueueFull
from test_faults import BASE, make_chaos_session, reference_tokens

ARCH = "qwen3-14b-smoke"


def _view(gid, *, free=2, queued=0, usable=2, max_queue=4, overlap=0):
    return GroupView(gid=gid, free_slots=free, queued=queued,
                     usable_slots=usable, max_queue=max_queue,
                     overlap_pages=overlap)


# ----------------------------------------------------------------------------
# MeshScheduler: placement invariants (property-tested)
# ----------------------------------------------------------------------------


@settings(deadline=None, max_examples=40)
@given(n_groups=st.integers(min_value=1, max_value=5),
       n_reqs=st.integers(min_value=0, max_value=25),
       bad=st.integers(min_value=0, max_value=5))
def test_placement_single_group_and_quarantine(n_groups, n_reqs, bad):
    """Every placed request lands in exactly one group (the placed
    histogram sums to the placement count) and a quarantined group
    receives nothing; with nothing eligible, `place` raises QueueFull
    rather than silently double-placing or dropping."""
    ms = MeshScheduler(n_groups, page_size=4)
    if bad < n_groups:
        ms.quarantine_group(bad)
    running = [0] * n_groups
    for _ in range(n_reqs):
        views = [_view(g, free=max(2 - running[g], 0),
                       queued=max(running[g] - 2, 0))
                 for g in range(n_groups)]
        try:
            gid = ms.place(views, prompt_tokens=4)
        except QueueFull:
            assert not any(ms.eligible(v) for v in views)
            continue
        assert 0 <= gid < n_groups
        running[gid] += 1
    assert sum(ms.placed) == ms.placements
    if bad < n_groups:
        assert ms.placed[bad] == 0
        assert running[bad] == 0


@settings(deadline=None, max_examples=40)
@given(warm=st.integers(min_value=0, max_value=3),
       pages=st.integers(min_value=1, max_value=2),
       prompt=st.integers(min_value=2, max_value=16))
def test_locality_prefers_measured_overlap(warm, pages, prompt):
    """At equal load, the group whose prefix cache measurably overlaps
    the prompt wins placement — warm KV models as local traffic in the
    topology score, and local beats remote."""
    ms = MeshScheduler(4, page_size=4)
    views = [_view(g, overlap=pages if g == warm else 0) for g in range(4)]
    assert ms.place(views, prompt_tokens=prompt) == warm
    assert ms.locality_hits == 1


def test_cold_placement_balances():
    """With no locality signal, placement spreads across equal groups
    (tie-break on lifetime placements round-robins deterministically)
    and prefers a less-loaded group over a busier one."""
    ms = MeshScheduler(3, page_size=4)
    for _ in range(9):
        ms.place([_view(g) for g in range(3)], prompt_tokens=4)
    assert ms.placed == [3, 3, 3]
    gid = ms.place([_view(0, free=0, queued=3),
                    _view(1, free=2, queued=0),
                    _view(2, free=0, queued=1)], prompt_tokens=4)
    assert gid == 1


def test_score_monotone_in_load_and_overlap():
    ms = MeshScheduler(2, page_size=4)
    idle = ms.score(_view(0), 8)
    busy = ms.score(_view(0, free=0, queued=3), 8)
    warm = ms.score(_view(0, overlap=2), 8)
    assert busy > idle > warm


def test_drain_blocks_placement_until_undrained():
    ms = MeshScheduler(2, page_size=4)
    ms.drain_group(0)
    views = [_view(0), _view(1)]
    assert ms.place(views, prompt_tokens=4) == 1
    ms.drain_group(1)
    with pytest.raises(QueueFull):
        ms.place(views, prompt_tokens=4)
    ms.undrain_group(0)
    assert ms.place(views, prompt_tokens=4) == 0
    assert ms.stats()["draining_groups"] == [1]


def test_group_lifecycle_validates_gid():
    ms = MeshScheduler(2)
    with pytest.raises(ValueError):
        ms.quarantine_group(2)
    with pytest.raises(ValueError):
        ms.drain_group(-1)


def test_group_plan_wraps_devices():
    plan = GroupPlan.build(4, devices=["d0", "d1"])
    assert plan.devices == ("d0", "d1", "d0", "d1")
    assert plan.degraded
    assert not GroupPlan.build(2, devices=["d0", "d1"]).degraded
    with pytest.raises(ValueError):
        GroupPlan.build(0)


# ----------------------------------------------------------------------------
# ShardedServeSession over scripted cells
# ----------------------------------------------------------------------------


def _sharded(n_groups, **kw):
    groups = [GroupRuntime(gid=g, session=make_chaos_session(**kw))
              for g in range(n_groups)]
    return ShardedServeSession(groups)


def test_sharded_drain_matches_isolated_reference():
    """Tokens delivered through the sharded front-end equal each
    request's isolated fault-free run, regardless of which group served
    it; every handle carries its placement."""
    prompts = [BASE[:3], BASE[:1], BASE[:4], BASE[2:4], BASE[:2],
               BASE[:3], BASE[1:4]]
    max_news = [6, 8, 4, 7, 5, 3, 6]
    expected = reference_tokens(prompts, max_news)
    sh = _sharded(3)
    hs = [sh.submit(p, n) for p, n in zip(prompts, max_news)]
    st_ = sh.drain()
    assert not sh.busy
    for h, exp in zip(hs, expected):
        assert h.group is not None
        assert [int(t) for t in h.result()] == [int(t) for t in exp]
    assert st_["requests_done"] == len(prompts)
    assert st_["n_groups"] == 3
    assert sum(st_["placement"]["placed"]) == len(prompts)
    assert set(st_["groups"]) == {0, 1, 2}
    sh.close()


def test_wedged_group_quarantines_not_the_session():
    """A group whose chunk wedges is quarantined: its poll stops, the
    other groups keep serving, placement skips it, and `recover_group`
    returns it to rotation with its in-flight work intact."""
    groups = [GroupRuntime(gid=0, session=make_chaos_session()),
              GroupRuntime(gid=1, session=make_chaos_session(
                  watchdog_s=0.05, max_retries=5,
                  faults=FaultPlan().wedge(at_chunk=0)))]
    sh = ShardedServeSession(groups)
    # one request per group (round-robin places across both)
    hs = [sh.submit(BASE[:2], 4) for _ in range(2)]
    delivered = {h.id: [] for h in hs}
    for _ in range(60):
        for h, toks, done in sh.poll():
            delivered[h.id].extend(int(t) for t in toks)
        if not sh.busy:
            break
    assert sh.mesh.stats()["quarantined_groups"] == [1]
    # the healthy group's request completed; new work avoids group 1
    done_groups = {h.group for h in hs if h.done}
    assert 0 in done_groups
    h2 = sh.submit(BASE[:2], 2)
    assert h2.group == 0
    sh.recover_group(1)
    assert sh.mesh.stats()["quarantined_groups"] == []
    sh.drain()
    assert all(h.done for h in hs) and h2.done
    sh.close()


def test_cancel_routes_to_the_placed_group():
    sh = _sharded(2)
    h = sh.submit(BASE[:2], 6)
    assert sh.cancel(h)
    sh.drain()
    assert h.cancelled
    sh.close()


def test_drain_group_runs_one_group_dry():
    sh = _sharded(2)
    hs = [sh.submit(BASE[:2], 4) for _ in range(4)]
    gid = hs[0].group
    sh.drain_group(gid)
    assert all(h.done for h in hs if h.group == gid)
    # still draining: placement avoids it
    h2 = sh.submit(BASE[:1], 2)
    assert h2.group != gid
    sh.undrain_group(gid)
    sh.drain()
    sh.close()


def test_sharded_stats_roll_up():
    sh = _sharded(2)
    hs = [sh.submit(BASE[:2], 4) for _ in range(4)]
    st_ = sh.drain()
    assert st_["emitted_total"] == sum(
        g["emitted_total"] for g in st_["groups"].values())
    assert st_["slots"] == sum(g["slots"] for g in st_["groups"].values())
    assert st_["stall"]["host_syncs"] == sum(
        g["stall"]["host_syncs"] for g in st_["groups"].values())
    # one shared wall: N concurrent ledgers can stall at most N walls'
    # worth (load-average style), never more
    assert 0.0 <= st_["stall"]["stall_pct"] <= 100.0 * 2 + 1e-6
    assert all(h.done for h in hs)
    sh.close()


# ----------------------------------------------------------------------------
# StallClock.merge: counters sum, the wall does not
# ----------------------------------------------------------------------------


def test_stall_merge_sums_counters_over_one_wall():
    a, b = StallClock(), StallClock()
    a.host_syncs, a.dispatch_gap_s, a.device_wait_s = 3, 0.2, 0.1
    b.host_syncs, b.dispatch_gap_s, b.device_wait_s = 5, 0.3, 0.4
    m = StallClock.merge([a, b])
    assert m.host_syncs == 8
    assert m.dispatch_gap_s == pytest.approx(0.5)
    assert m.device_wait_s == pytest.approx(0.5)
    # wall spans from the earliest member start — one wall, not two
    assert m._t_start == min(a._t_start, b._t_start)
    r = m.report()
    assert r["wall_s"] <= a.report()["wall_s"] + b.report()["wall_s"]


def test_stall_merge_empty_is_fresh():
    m = StallClock.merge([])
    assert m.host_syncs == 0
    assert m.report()["stall_pct"] == 0.0


# ----------------------------------------------------------------------------
# Journal group tags
# ----------------------------------------------------------------------------


def test_journal_tag_round_trips_group(tmp_path):
    p = tmp_path / "j.jsonl"
    j = Journal(p, tag={"group": 2})
    j.append({"ev": "submit", "rid": 0, "prompt": [1, 2], "max_new": 4,
              "klass": "latency", "deadline_s": None})
    j.append({"ev": "commit", "rid": 0, "tokens": [7], "chunk": 0})
    j.commit()
    j.close()
    evs = read_events(p)
    assert all(e["group"] == 2 for e in evs)
    assert replay(evs).requests[0].group == 2


def test_untagged_journal_replays_group_none(tmp_path):
    p = tmp_path / "j.jsonl"
    j = Journal(p)
    j.append({"ev": "submit", "rid": 0, "prompt": [1], "max_new": 2,
              "klass": "latency", "deadline_s": None})
    j.commit()
    j.close()
    assert replay(read_events(p)).requests[0].group is None


# ----------------------------------------------------------------------------
# KV page eviction under pressure (LRU, cache-only first)
# ----------------------------------------------------------------------------


def test_evict_prefers_cold_cache_only_chains():
    """Pages referenced only by the prefix cache go first, coldest
    chain first; `stats()["evictions"]` counts every dropped entry."""
    kv = PagedKV(n_pages=9, page_size=2, n_slots=4, pages_per_slot=2)
    # two published single-page chains: A (cold) then B (warm)
    for slot, toks in ((0, [1, 2]), (1, [3, 4])):
        kv.admit(slot, np.array(toks, np.int32), max_new=1)
        kv.publish(slot)
        kv.release(slot)
    kv.prefix.match(np.array([3, 4], np.int32))     # warm B
    freed = kv.prefix.evict(1)
    assert len(freed) == 1
    assert kv.stats()["evictions"] == 1
    # the cold chain (A) died; B still matches
    assert kv.match_len(np.array([3, 4], np.int32)) == 2
    assert kv.match_len(np.array([1, 2], np.int32)) == 0


def test_admit_under_pressure_evicts_and_counts():
    """When alloc would shed, admission evicts cold cache-only pages
    and proceeds; the eviction surfaces in stats()["evictions"]."""
    kv = PagedKV(n_pages=3, page_size=2, n_slots=2, pages_per_slot=2)
    kv.admit(0, np.array([1, 2], np.int32), max_new=1)
    kv.publish(0)
    kv.release(0)                       # 1 page now cache-only
    # needs 2 fresh pages; only 1 free + 1 cache-only -> must evict
    kv.admit(1, np.array([5, 6, 7], np.int32), max_new=1)
    assert kv.stats()["evictions"] >= 1
    kv.release(1)


def test_eviction_spares_pages_shared_with_slots():
    """A page a live slot still references is deprioritized: eviction
    drops it from the cache (so the chain is gone) but the page itself
    survives for the slot."""
    kv = PagedKV(n_pages=6, page_size=2, n_slots=2, pages_per_slot=2)
    kv.admit(0, np.array([1, 2], np.int32), max_new=1)
    kv.publish(0)                       # page shared: slot 0 + cache
    shared = kv.slot_pages(0)[0]
    kv.admit(1, np.array([8, 9], np.int32), max_new=1)
    kv.publish(1)
    kv.release(1)                       # cache-only page
    kv.prefix.match(np.array([8, 9], np.int32))  # cache-only is WARMER
    freed = kv.prefix.evict(1)
    # the cache-only page freed first despite being warmer? No: the
    # slot-shared page is deprioritized, so the cache-only one goes
    assert shared not in freed
    assert int(kv.pool.refcount[shared]) >= 1
    kv.release(0)


def test_eviction_counter_survives_snapshot_and_reset():
    kv = PagedKV(n_pages=5, page_size=2, n_slots=2, pages_per_slot=2)
    kv.admit(0, np.array([1, 2], np.int32), max_new=1)
    kv.publish(0)
    kv.release(0)
    kv.prefix.evict(1)
    snap = kv.snapshot()
    kv2 = PagedKV(n_pages=5, page_size=2, n_slots=2, pages_per_slot=2)
    kv2.load_snapshot(snap)
    assert kv2.stats()["evictions"] == 1
    kv2.reset()
    assert kv2.stats()["evictions"] == 1


# ----------------------------------------------------------------------------
# Cluster path: groups=1 is the plain session, bit for bit
# ----------------------------------------------------------------------------


def _cluster_progs():
    from repro.cluster import (Cluster, ServeSessionProgram,
                               ShardedServeSessionProgram)
    cl = Cluster(ARCH)
    base = dict(slots=2, max_seq=16, max_prompt=8, chunk=4,
                paged=True, page_size=4)
    return (cl.compile(ServeSessionProgram(**base)),
            cl.compile(ShardedServeSessionProgram(groups=1, **base)),
            cl)


_PROMPTS = [[1, 2, 3, 4], [1, 2, 3, 5], [9, 8, 7], [1, 2, 3, 4, 5, 6]]


def test_one_group_bit_identical_to_plain_session():
    plain, sharded, _ = _cluster_progs()
    ref, sh = plain.open(), sharded.open()
    hr = [ref.submit(p, 6) for p in _PROMPTS]
    hs = [sh.submit(p, 6) for p in _PROMPTS]
    ref.drain()
    sh.drain()
    for a, b in zip(hr, hs):
        assert np.array_equal(a.tokens, b.tokens)
    assert isinstance(sh.recovered, dict)       # group-0 map passthrough
    ref.close()
    sh.close()


def test_one_group_crash_restart_bit_identical():
    """Crash the 1-group sharded session mid-flight (SIGKILL stand-in),
    restore through the group-tagged journal, and require the union of
    pre-crash committed and post-restore deliveries to equal the plain
    session's streams exactly-once."""
    plain, sharded, _ = _cluster_progs()
    ref = plain.open()
    hr = [ref.submit(p, 6) for p in _PROMPTS]
    ref.drain()
    expected = {h.id: [int(t) for t in h.result()] for h in hr}
    ref.close()

    d = tempfile.mkdtemp()
    try:
        sh = sharded.open(durable_dir=d,
                          faults=FaultPlan().crash(at_chunk=2))
        hs = [sh.submit(p, 6) for p in _PROMPTS]
        delivered = {h.id: [] for h in hs}
        crashed = False
        for _ in range(200):
            try:
                for h, toks, done in sh.poll():
                    delivered[h.id].extend(int(t) for t in toks)
            except SessionCrashed:
                crashed = True
                break
            if not sh.busy:
                break
        assert crashed
        evs = read_events(d + "/journal.jsonl")
        assert evs and all(e.get("group") == 0 for e in evs
                           if e.get("ev") != "restore")
        committed = {rid: list(r.committed)
                     for rid, r in replay(evs).requests.items()}
        for rid, toks in delivered.items():
            assert committed.get(rid, [])[:len(toks)] == toks
        sh2 = sharded.restore(d)
        final = {rid: list(t) for rid, t in committed.items()}
        for h, toks, done in sh2.stream():
            final.setdefault(h.id, []).extend(int(t) for t in toks)
        assert final == expected
        sh2.close()
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_sharded_durable_dir_guards_group_count():
    _, _, cl = _cluster_progs()
    from repro.cluster import ShardedServeSessionProgram
    d = tempfile.mkdtemp()
    try:
        p1 = cl.compile(ShardedServeSessionProgram(
            groups=1, slots=2, max_seq=16, chunk=4))
        p1.open(durable_dir=d).close()
        p2 = cl.compile(ShardedServeSessionProgram(
            groups=2, slots=2, max_seq=16, chunk=4))
        with pytest.raises(ValueError):
            p2.open(durable_dir=d, resume=True)
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_sharded_run_is_not_defined():
    _, sharded, _ = _cluster_progs()
    with pytest.raises(NotImplementedError):
        sharded.run()
