"""Checkpoint manager + runtime (train loop, straggler, elastic) tests."""

import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import compat
from repro.configs import get
from repro.models import steps
from repro.runtime import TrainLoop, TrainLoopConfig, CompileCache
from repro.runtime.coordination import Coordinator, replan_mesh_shape
from repro.runtime.train_loop import StragglerDetector


def small_state():
    return {"params": {"w": jnp.arange(8, dtype=jnp.float32),
                       "b": jnp.ones((2, 3), jnp.bfloat16)},
            "opt": {"step": jnp.asarray(5, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    state = small_state()
    mgr.save(10, state)
    assert mgr.latest_step() == 10
    restored = mgr.restore(10, jax.tree.map(jnp.zeros_like, state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float64),
                                      np.asarray(b, np.float64))


def test_checkpoint_async_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=True)
    for s in (1, 2, 3, 4):
        mgr.save(s, small_state())
    mgr.wait()
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async_write_failure_surfaces(tmp_path, monkeypatch):
    """A failed background write must raise on wait() (once) and on the
    next save() — a dropped checkpoint is never silent."""
    mgr = CheckpointManager(tmp_path, async_save=True)

    def boom(step, snapshot):
        raise OSError("disk full")

    monkeypatch.setattr(mgr, "_write_step", boom)
    mgr.save(1, small_state())
    with pytest.raises(OSError, match="disk full"):
        mgr.wait()
    mgr.wait()                              # raised once, then cleared
    mgr.save(2, small_state())              # fails in the background again
    with pytest.raises(OSError, match="disk full"):
        mgr.save(3, small_state())          # surfaced before the new write
    monkeypatch.undo()
    mgr.save(4, small_state())              # recovered: a real write lands
    mgr.wait()
    assert mgr.latest_step() == 4


def test_checkpoint_atomicity(tmp_path):
    """A stale tmp dir must never be visible as a checkpoint."""
    mgr = CheckpointManager(tmp_path, async_save=False)
    (tmp_path / ".tmp-99").mkdir()
    (tmp_path / ".tmp-99" / "garbage").write_text("x")
    mgr.save(1, small_state())
    assert mgr.all_steps() == [1]


def test_elastic_restore_resharding(tmp_path):
    """Save with one layout, restore onto explicit shardings (new mesh)."""
    mgr = CheckpointManager(tmp_path, async_save=False)
    state = small_state()
    mgr.save(3, state)
    mesh = compat.make_mesh((1,), ("data",))
    sh = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec()), state)
    restored = mgr.restore(3, state, sh)
    assert restored["params"]["w"].sharding.mesh.shape["data"] == 1


@pytest.mark.slow
def test_train_loop_end_to_end_with_resume(tmp_path):
    cfg = get("xlstm-125m-smoke")
    state = steps.init_train_state(cfg, jax.random.PRNGKey(0), max_seq=16)
    ts = jax.jit(steps.make_train_step(cfg))

    def batches():
        k = jax.random.PRNGKey(1)
        while True:
            yield {"tokens": jax.random.randint(k, (2, 16), 0, cfg.vocab),
                   "labels": jax.random.randint(k, (2, 16), 0, cfg.vocab)}

    loop_cfg = TrainLoopConfig(total_steps=6, checkpoint_every=3,
                               log_every=2, checkpoint_dir=str(tmp_path))
    loop = TrainLoop(loop_cfg, ts, state, batches())
    report = loop.run(start_step=0)
    assert report["final_step"] == 6
    # resume continues from latest checkpoint
    loop2 = TrainLoop(TrainLoopConfig(total_steps=8, checkpoint_every=3,
                                      checkpoint_dir=str(tmp_path)),
                      ts, jax.tree.map(jnp.zeros_like, state), batches())
    report2 = loop2.run()
    assert report2["final_step"] == 8


def test_straggler_detector():
    det = StragglerDetector(z=3.0, warmup=5)
    for i in range(20):
        det.observe(i, 0.1 + 0.001 * (i % 3))
    assert not det.events
    assert det.observe(20, 1.5)
    assert det.events[0]["step"] == 20


def test_compile_cache_hits():
    cache = CompileCache()
    calls = []
    for _ in range(3):
        cache.get(("step", "a"), lambda: calls.append(1) or "exe")
    assert cache.hits == 2 and cache.misses == 1 and len(calls) == 1


def test_coordinator_and_replan():
    coord = Coordinator(n_hosts=64)
    seen = []
    coord.subscribe(lambda ev: seen.append(ev.kind))
    coord.emit("leave", "host-3")
    assert coord.n_hosts == 63 and seen == ["leave"]
    assert replan_mesh_shape(256, model_parallel=16) == (16, 16)
    assert replan_mesh_shape(240, model_parallel=16) == (8, 16)
    assert replan_mesh_shape(512, model_parallel=16, pods=2) == (2, 16, 16)
    with pytest.raises(ValueError):
        replan_mesh_shape(8, model_parallel=16)
