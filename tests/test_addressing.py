"""Hybrid addressing scheme: paper-faithful scrambler + sharding planner."""

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:              # bare env without the [test] extra
    from hypothesis_fallback import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core.addressing import AddressMap, AxisRules, default_rules

AM = AddressMap(tile_bits=6, bank_bits=4, seq_rows_bits=4)   # paper config


@settings(max_examples=200, deadline=None)
@given(addr=st.integers(0, (1 << 20) - 1))
def test_scramble_bijection(addr):
    """The address permutation must be a bijection (paper: wire crossing)."""
    a = np.int64(addr)
    assert AM.descramble(AM.scramble(a)) == a
    assert AM.scramble(AM.descramble(a)) == a


def test_scramble_is_permutation_full_region():
    """Exhaustive over the sequential region: a true permutation."""
    n = AM.seq_region_bytes
    addrs = np.arange(n, dtype=np.int64)
    scr = AM.scramble(addrs)
    assert len(np.unique(scr)) == n
    np.testing.assert_array_equal(AM.descramble(scr), addrs)


def test_sequential_region_locality():
    """Within the sequential region, each tile's 2^(s+b+2) contiguous bytes
    map to a single tile — the paper's key property (Fig. 3)."""
    per_tile = 1 << (AM.seq_rows_bits + AM.bank_bits + 2)
    for tile in range(4):
        addrs = tile * per_tile + np.arange(per_tile, dtype=np.int64)
        tiles = AM.tile_of(AM.scramble(addrs))
        assert (tiles == tile).all(), f"tile {tile} leaked: {set(tiles)}"


def test_interleaved_region_spreads():
    """Outside the sequential region, consecutive words hit distinct tiles."""
    base = AM.seq_region_bytes
    word_addrs = base + 4 * (1 << AM.bank_bits) * np.arange(
        1 << AM.tile_bits, dtype=np.int64)
    tiles = AM.tile_of(AM.scramble(word_addrs))
    assert len(np.unique(tiles)) == 1 << AM.tile_bits


def test_scramble_outside_region_identity():
    addrs = AM.seq_region_bytes + np.arange(4096, dtype=np.int64)
    np.testing.assert_array_equal(AM.scramble(addrs), addrs)


# ----------------------------------------------------------------------------
# Region-policy sharding planner
# ----------------------------------------------------------------------------

def amesh(*shape_axes):
    shape = tuple(n for n, _ in shape_axes)
    axes = tuple(a for _, a in shape_axes)
    return compat.abstract_mesh(shape, axes)


@pytest.fixture(scope="module")
def mesh():
    return amesh((1, "data"), (1, "model"))


def test_planner_divisibility_fallback(mesh):
    rules = default_rules(mesh)
    # 40 heads on a 1-wide model axis divides; fake a rule with missing axis
    spec = rules.spec_for(("embed", "heads", None), (64, 40, 128), mesh)
    assert isinstance(spec, P)


def test_planner_axis_conflict():
    mesh = amesh((2, "data"), (2, "model"))
    rules = AxisRules(rules={"a": "model", "b": "model"})
    spec = rules.spec_for(("a", "b"), (4, 4), mesh)
    # model axis used once only — second dim must drop it
    flat = [x for x in spec if x is not None]
    assert flat.count("model") <= 1


def test_planner_drops_indivisible():
    mesh = amesh((2, "data"), (2, "model"))
    rules = AxisRules(rules={"v": "model"})
    spec = rules.spec_for(("v",), (7,), mesh)        # 7 % 2 != 0
    assert spec == P()


def test_planner_multi_axis_batch():
    mesh = amesh((2, "pod"), (2, "data"), (2, "model"))
    rules = default_rules(mesh)
    spec = rules.spec_for(("batch", "seq"), (8, 128), mesh)
    assert spec[0] == ("pod", "data")


def test_rules_overrides():
    mesh = amesh((2, "data"), (2, "model"))
    rules = default_rules(mesh, overrides=(("ffn", None),))
    spec = rules.spec_for(("embed", "ffn"), (8, 8), mesh)
    assert spec == P("data")   # ffn override suppressed the model axis
