"""Optimizer + gradient compression correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import AdamConfig, adam_init, adam_update, warmup_cosine
from repro.optim.compress import (compress_decompress, compressed_psum,
                                  quantize_int8, dequantize_int8, wire_bytes)


def manual_adam(p, g, m, v, t, cfg):
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m / (1 - cfg.b1 ** t)
    vh = v / (1 - cfg.b2 ** t)
    return p - cfg.lr * (mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * p), m, v


def test_adam_matches_reference():
    cfg = AdamConfig(lr=1e-2, grad_clip=0.0, weight_decay=0.1)
    params = {"w": jnp.asarray([1.0, -2.0, 3.0], jnp.float32)}
    grads = {"w": jnp.asarray([0.1, 0.2, -0.3], jnp.float32)}
    opt = adam_init(params, cfg)
    p_np = np.asarray(params["w"], np.float64)
    m_np = np.zeros(3)
    v_np = np.zeros(3)
    for t in range(1, 5):
        params, opt, _ = adam_update(params, grads, opt, cfg)
        p_np, m_np, v_np = manual_adam(p_np, np.asarray(grads["w"]), m_np,
                                       v_np, t, cfg)
        np.testing.assert_allclose(params["w"], p_np, rtol=1e-5, atol=1e-6)


def test_grad_clip_limits_update():
    cfg = AdamConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    grads = {"w": jnp.full((4,), 100.0)}
    _, _, metrics = adam_update(params, grads, adam_init(params, cfg), cfg)
    assert float(metrics["grad_norm"]) > 100


def test_bf16_moments_roundtrip():
    cfg = AdamConfig(moment_dtype="bfloat16")
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    opt = adam_init(params, cfg)
    assert opt["m"]["w"].dtype == jnp.bfloat16
    new_p, new_opt, _ = adam_update(params, {"w": jnp.ones(8, jnp.bfloat16)},
                                    opt, cfg)
    assert new_opt["v"]["w"].dtype == jnp.bfloat16
    assert new_p["w"].dtype == jnp.bfloat16


def test_warmup_cosine_shape():
    assert float(warmup_cosine(0, warmup=10, total=100)) == 0.0
    assert float(warmup_cosine(10, warmup=10, total=100)) == pytest.approx(1.0)
    assert float(warmup_cosine(100, warmup=10, total=100)) == pytest.approx(
        0.1, abs=1e-3)


# ----------------------------------------------------------------------------
# compression
# ----------------------------------------------------------------------------

def test_int8_quantization_bounded_error():
    g = jax.random.normal(jax.random.PRNGKey(0), (1024,), jnp.float32)
    q, s = quantize_int8(g)
    err = np.abs(np.asarray(dequantize_int8(q, s) - g))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_time():
    """With EF, the *accumulated* compressed sum tracks the true sum."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros(64)
    sent_sum = np.zeros(64)
    err = jnp.zeros(64)
    for t in range(50):
        g = jnp.asarray(rng.normal(size=64) * 0.01, jnp.float32)
        corrected = g + err
        sent = compress_decompress(corrected, "int8_ef")
        err = corrected - sent
        true_sum += np.asarray(g)
        sent_sum += np.asarray(sent)
    # residual is bounded by one quantization step, not growing with t
    assert np.abs(true_sum - sent_sum).max() < 0.01


def test_compressed_psum_under_shard_map():
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.core.compat import make_mesh, shard_map
    mesh = make_mesh((1,), ("data",))
    grads = {"w": jnp.ones((4,), jnp.float32)}

    @partial(shard_map, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
             check_vma=False)
    def run(g):
        return compressed_psum(g, "data", "bf16")

    red, err = run(grads)
    np.testing.assert_allclose(red["w"], grads["w"], rtol=1e-2)


def test_wire_bytes_accounting():
    grads = {"w": jnp.zeros((1000,)), "b": jnp.zeros((24,))}
    assert wire_bytes(grads, "none") == 4096.0
    assert wire_bytes(grads, "bf16") == 2048.0
    assert wire_bytes(grads, "int8_ef") == 1024.0
